//! Evaluation harness against the trained nt-tiny: the float model must
//! actually possess the capabilities the quantization experiments measure.

mod common;

use normtweak::coordinator::FloatModel;
use normtweak::eval::{generate, lambada, ppl, subjective, tasks};

#[test]
fn float_model_scores_well_on_lambada_syn() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let set = lambada::LambadaSet::generate(0x1A3B, 64, w.config.seq);
    let acc = lambada::accuracy(&fm, &set, 8).unwrap();
    // trained tiny model reached ~70% in training logs; quantization tests
    // rely on a real capability being present
    assert!(acc > 40.0, "nt-tiny fp32 lambada-syn acc {acc}");
}

#[test]
fn eval_is_deterministic() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let set = lambada::LambadaSet::generate(0x1A3B, 32, w.config.seq);
    let a = lambada::accuracy(&fm, &set, 8).unwrap();
    let b = lambada::accuracy(&fm, &set, 16).unwrap(); // batch split must not matter
    assert_eq!(a, b);
}

#[test]
fn ppl_finite_and_better_than_uniform() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    for corpus in ["wiki-syn", "ptb-syn", "c4-syn"] {
        let p = ppl::perplexity(&fm, corpus, 2048, 8).unwrap();
        assert!(p.is_finite() && p > 1.0);
        assert!(p < w.config.vocab as f32 / 4.0, "{corpus}: ppl {p}");
    }
}

#[test]
fn task_suite_scores_above_chance() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    // 4-way task: chance 25; 2-way: chance 50 — the trained model should
    // beat chance on the successor-based tasks
    let t = tasks::build_task("hellaswag-syn", 48, 0xBEE);
    let acc = tasks::score_task(&fm, &t, 8).unwrap();
    assert!(acc > 35.0, "hellaswag-syn acc {acc}");
    let t2 = tasks::build_task("boolq-syn", 48, 0xBEF);
    let acc2 = tasks::score_task(&fm, &t2, 8).unwrap();
    assert!(acc2 > 55.0, "boolq-syn acc {acc2}");
}

#[test]
fn generation_is_grammatical() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let reports = subjective::subjective_eval(&fm, &[1, 42], 2, 32).unwrap();
    for (text, rep) in &reports {
        assert!(!text.is_empty());
        // the float model should mostly follow its grammar
        assert!(rep.successor_rate > 0.3, "rate {} in {text}", rep.successor_rate);
    }
}

#[test]
fn batched_generation_rows_are_independent() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let cfg = generate::SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 };
    let solo = generate::generate(&fm, &[vec![1, 50]], 16, &cfg).unwrap();
    let batch = generate::generate(
        &fm,
        &[vec![1, 50], vec![1, 300], vec![1, 210]],
        16,
        &cfg,
    )
    .unwrap();
    assert_eq!(solo[0], batch[0], "row 0 must not be affected by other rows");
}

#[test]
fn kv_cached_decode_matches_recompute_on_real_model() {
    use normtweak::error::Result;
    use normtweak::eval::LanguageModel;
    use normtweak::model::ModelConfig;
    use normtweak::tensor::Tensor;

    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    if !fm.supports_decode() {
        eprintln!("[skip] artifacts carry no decode record (exported --no-decode)");
        return;
    }

    /// Wrapper that hides the decode override, forcing the trait's
    /// full-context recompute fallback through the same XLA model.
    struct NoDecode<'a>(&'a dyn LanguageModel);
    impl LanguageModel for NoDecode<'_> {
        fn config(&self) -> &ModelConfig {
            self.0.config()
        }
        fn logits(&self, t: &Tensor) -> Result<Tensor> {
            self.0.logits(t)
        }
        fn max_batch(&self) -> Option<usize> {
            self.0.max_batch()
        }
    }

    let cfg = generate::SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 };
    let prompts = vec![vec![1, 50], vec![1, 300, 17]];
    let cached = generate::generate(&fm, &prompts, 10, &cfg).unwrap();
    let recompute = generate::generate(&NoDecode(&fm), &prompts, 10, &cfg).unwrap();

    // The step graphs run the jnp oracle kernels while the full-context
    // graphs run Pallas (matched to ~2e-4); a *near-tie* argmax flip is
    // therefore legitimate, but a divergence at a decisive logit gap is a
    // real cache/position bug.  Strict token equality holds on matched
    // kernels (pinned offline by decode_parity.rs).
    if cached != recompute {
        let seq = fm.config().seq;
        let vocab = fm.config().vocab;
        for (row, (a, b)) in cached.iter().zip(&recompute).enumerate() {
            let Some(p) = a.iter().zip(b.iter()).position(|(x, y)| x != y) else {
                continue;
            };
            // logits of the shared prefix, from the recompute path
            let mut padded = b[..p].to_vec();
            padded.resize(seq, 0);
            let logits = fm.logits(&Tensor::i32(&[1, seq], padded)).unwrap();
            let lv = logits.as_f32().unwrap();
            let mut sorted: Vec<f32> = lv[(p - 1) * vocab..][..vocab].to_vec();
            sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
            let gap = sorted[0] - sorted[1];
            assert!(
                gap < 1e-2,
                "decode path diverged from recompute at row {row} pos {p} \
                 despite a decisive top-2 logit gap of {gap} — not a kernel \
                 near-tie; cached={a:?} recompute={b:?}"
            );
        }
    }
}

//! Algorithm 1 end-to-end on nt-tiny through the real PJRT runtime:
//! GPTQ ± norm tweaking, metric collection, checkpoint round-trip, and the
//! paper's core claim (tweaking shrinks the activation drift).

mod common;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{build_calib, quantize_model, PipelineConfig, QuantModel};
use normtweak::eval::LanguageModel;
use normtweak::model::{ModelConfig, QuantizedModel};
use normtweak::quant::QuantScheme;
use normtweak::tensor::Tensor;
use normtweak::tweak::TweakConfig;

fn calib_from_corpus(rt: &normtweak::runtime::Runtime, seq: usize) -> CalibSet {
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        rt.manifest.calib_batch * seq,
    );
    CalibSet::from_stream(&stream, rt.manifest.calib_batch, seq, "wiki-syn").unwrap()
}

#[test]
fn gptq_plus_tweak_runs_and_reduces_drift() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    let scheme = QuantScheme::w2_g64();

    let plain = PipelineConfig::new("gptq", scheme);
    let (_, m_plain) = quantize_model(&rt, &w, &calib, &plain).unwrap();

    let tweaked = PipelineConfig::new("gptq", scheme)
        .with_tweak(TweakConfig::default());
    let (qm, m_tweak) = quantize_model(&rt, &w, &calib, &tweaked).unwrap();

    assert_eq!(m_plain.layers.len(), w.config.n_layer);
    assert!(m_tweak.tweaked && !m_plain.tweaked);

    // the paper's Figure-1 claim: mean drift is smaller with tweaking
    let mean = |m: &normtweak::coordinator::PipelineMetrics| {
        m.layers.iter().map(|l| l.delta_mu).sum::<f32>() / m.layers.len() as f32
    };
    assert!(
        mean(&m_tweak) < mean(&m_plain),
        "tweaked drift {} should be below plain {}",
        mean(&m_tweak),
        mean(&m_plain)
    );

    // tweak loss decreased within layers (first vs last iteration)
    for l in &m_tweak.layers {
        let (Some(b), Some(a)) = (l.loss_before, l.loss_after) else { panic!() };
        assert!(a <= b * 1.05, "layer {} loss went {b} -> {a}", l.layer);
    }

    // 2-bit packing delivers the memory reduction
    assert!(m_tweak.compression_ratio < 0.2, "{}", m_tweak.compression_ratio);

    // checkpoint round-trip preserves the quantized model exactly
    let dir = std::env::temp_dir().join("nt_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ntz");
    qm.save(&path).unwrap();
    let back = QuantizedModel::load(ModelConfig::builtin("nt-tiny").unwrap(), &path).unwrap();
    assert_eq!(back.blocks[0].qkv.packed, qm.blocks[0].qkv.packed);
    assert_eq!(back.blocks[0].ln1_g, qm.blocks[0].ln1_g);

    // the reloaded model runs
    let qr = QuantModel::new(&rt, &back).unwrap();
    let toks = Tensor::i32(&[2, w.config.seq], vec![1; 2 * w.config.seq]);
    let logits = qr.logits(&toks).unwrap();
    assert_eq!(logits.shape, vec![2, w.config.seq, w.config.vocab]);
}

#[test]
fn all_methods_run_on_tiny() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    // every registered plugin plus a composed spec (smoothing pre-stage,
    // GPTQ reconstruction) must dispatch through the registry end-to-end
    for method in ["rtn", "smoothquant", "awq", "omniquant", "smoothquant+gptq"] {
        let cfg = PipelineConfig::new(method, QuantScheme::w4_perchannel());
        let (qm, metrics) = quantize_model(&rt, &w, &calib, &cfg)
            .unwrap_or_else(|e| panic!("{method}: {e}"));
        assert_eq!(qm.blocks.len(), w.config.n_layer);
        assert_eq!(metrics.method, method);
        // every method must produce a runnable model
        let qr = QuantModel::new(&rt, &qm).unwrap();
        let toks = Tensor::i32(&[1, w.config.seq], vec![2; w.config.seq]);
        qr.logits(&toks).unwrap();
    }
}

#[test]
fn non_g64_grains_run_end_to_end_or_fail_at_startup() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    // the acceptance contract: a g32/g128 scheme either resolves real
    // exported graphs end-to-end, or fails at pipeline startup listing the
    // manifest's exported grains — never at mid-run graph lookup
    for scheme in [QuantScheme::w2_g32(), QuantScheme::w4_g128()] {
        let tag = scheme.group_tag();
        let cfg = PipelineConfig::new("rtn", scheme)
            .with_tweak(TweakConfig::default());
        match quantize_model(&rt, &w, &calib, &cfg) {
            Ok((qm, metrics)) => {
                assert!(rt.manifest.has_grain(&tag), "{tag} ran but unexported?");
                assert_eq!(metrics.group, scheme.group_size);
                let qr = QuantModel::new(&rt, &qm).unwrap();
                let toks = Tensor::i32(&[1, w.config.seq], vec![2; w.config.seq]);
                let logits = qr.logits(&toks).unwrap();
                assert_eq!(logits.shape, vec![1, w.config.seq, w.config.vocab]);
            }
            Err(e) => {
                assert!(!rt.manifest.has_grain(&tag), "{tag} exported but failed: {e}");
                let msg = format!("{e}");
                assert!(
                    msg.contains(&tag) && msg.contains("exported"),
                    "startup error must list exported grains: {msg}"
                );
            }
        }
    }
}

#[test]
fn ablation_loss_on_model_without_its_graph_fails_at_startup() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    // nt-tiny has no Mse/Kl ablation graphs (nt-small only): requesting
    // --loss mse must error up front naming the missing graph, not at PJRT
    // argument-count mismatch mid-tweak
    let cfg = PipelineConfig::new("rtn", QuantScheme::w2_g64()).with_tweak(
        normtweak::tweak::TweakConfig {
            loss: normtweak::tweak::LossKind::Mse,
            ..Default::default()
        },
    );
    let err = quantize_model(&rt, &w, &calib, &cfg).unwrap_err();
    let msg = format!("{err}");
    // (either the missing ablation graph, or — under a re-export that
    // dropped g64 entirely — the missing grain; both are startup errors)
    assert!(
        msg.contains("tweak_step_mse.g64") || msg.contains("no exported graphs"),
        "{msg}"
    );
}

#[test]
fn unknown_method_fails_loudly() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    let cfg = PipelineConfig::new("zap", QuantScheme::w4_perchannel());
    let err = quantize_model(&rt, &w, &calib, &cfg).unwrap_err();
    assert!(format!("{err}").contains("unknown quantizer"));
}

#[test]
fn per_layer_scheme_override_runs() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    // first layer kept at 8 bits, rest at the base 2-bit g64 grain
    let base = QuantScheme::w2_g64();
    let cfg = PipelineConfig::new("rtn", base)
        .with_layer_scheme(0, QuantScheme { bits: 8, group_size: Some(64) });
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    assert_eq!(qm.blocks[0].qkv.packed.bits, 8);
    assert_eq!(qm.blocks[1].qkv.packed.bits, 2);
    // mixed-precision checkpoints round-trip the per-linear pack width
    let dir = std::env::temp_dir().join("nt_mixed_precision");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ntz");
    qm.save(&path).unwrap();
    let back = QuantizedModel::load(ModelConfig::builtin("nt-tiny").unwrap(), &path).unwrap();
    assert_eq!(back.blocks[0].qkv.packed.bits, 8);
    assert_eq!(back.blocks[0].qkv.packed, qm.blocks[0].qkv.packed);
    let qr = QuantModel::new(&rt, &back).unwrap();
    let toks = Tensor::i32(&[1, w.config.seq], vec![4; w.config.seq]);
    qr.logits(&toks).unwrap();
}

#[test]
fn generated_calibration_feeds_pipeline() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    // gen-v2 self-generation (short: target len = seq is the contract)
    let calib = build_calib(&rt, &w, "gen-v2", rt.manifest.calib_batch, 7).unwrap();
    assert_eq!(calib.n_samples(), rt.manifest.calib_batch);
    assert_eq!(calib.source, "gen-v2");
    // first content token of every sample is in the top-language buckets
    let toks = calib.tokens.as_i32().unwrap();
    let seq = calib.seq();
    let top_hi = normtweak::calib::vocab::LANGS[4].hi as i32;
    for i in 0..calib.n_samples() {
        let first = toks[i * seq + 1];
        assert!(first >= 8 && first < top_hi, "sample {i}: first token {first}");
    }
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel())
        .with_tweak(TweakConfig::default());
    let (_, metrics) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    assert_eq!(metrics.calib_source, "gen-v2");
}

#[test]
fn act_quant_mode_runs() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_from_corpus(&rt, w.config.seq);
    let cfg = PipelineConfig::new("smoothquant", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    let qr = QuantModel::new(&rt, &qm).unwrap().with_act_bits(Some(8));
    let toks = Tensor::i32(&[1, w.config.seq], vec![3; w.config.seq]);
    let l8 = qr.logits(&toks).unwrap();
    let qr4 = QuantModel::new(&rt, &qm).unwrap().with_act_bits(Some(4));
    let l4 = qr4.logits(&toks).unwrap();
    // A4 must differ from A8 (the fake-quant path is actually active)
    let d = normtweak::tensor::max_abs_diff(&l8, &l4).unwrap();
    assert!(d > 1e-3, "activation quantization had no effect: {d}");
}

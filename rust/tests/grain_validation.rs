//! Offline (no PJRT, no artifacts) coverage of the grain-generic AOT
//! contract: manifests with multiple grains load with their `groups` record,
//! and the pipeline's graph-resolution path accepts exactly the exported
//! grains — failing at startup with the exported-grain list, never at
//! mid-run graph lookup.

use normtweak::coordinator::{validate_scheme_artifacts, PipelineConfig};
use normtweak::quant::QuantScheme;
use normtweak::runtime::ArtifactManifest;
use normtweak::tweak::{LossKind, TweakConfig};

/// A manifest exporting pc/g32/g128 (note: no g64) for nt-tiny, with the
/// per-grain tweak graphs plus the pc-only Mse ablation graph.
/// `unique` keeps concurrently running tests off each other's fixture file.
fn multigrain_manifest(unique: &str) -> ArtifactManifest {
    let dir = std::env::temp_dir().join(format!("nt_grain_validation_{unique}"));
    std::fs::create_dir_all(&dir).unwrap();
    let graph = |name: &str| {
        format!(
            r#"{{"model": "nt-tiny", "name": "{name}",
                 "file": "nt-tiny.{name}.hlo.txt",
                 "inputs": [{{"name": "x", "shape": [32, 128, 128],
                             "dtype": "f32"}}]}}"#
        )
    };
    let graphs = ["tweak_step.pc", "tweak_step.g32", "tweak_step.g128",
                  "tweak_step_mse.pc"]
        .map(graph)
        .join(",\n");
    let json = format!(
        r#"{{
        "format": 1, "calib_batch": 32, "buckets": [8, 32],
        "groups": {{"pc": 0, "g32": 32, "g128": 128}},
        "models": {{"nt-tiny": {{"n_layer": 2, "d_model": 128, "n_head": 4,
                    "d_ff": 512, "vocab": 2048, "seq": 128,
                    "norm": "layernorm"}}}},
        "graphs": [{graphs}]
    }}"#
    );
    std::fs::write(dir.join("manifest.json"), json).unwrap();
    ArtifactManifest::load(&dir).unwrap()
}

#[test]
fn manifest_records_multiple_grains() {
    let m = multigrain_manifest("records");
    assert_eq!(m.grain_tags(), vec!["g128", "g32", "pc"]);
    assert_eq!(m.groups["g32"], 32);
    assert_eq!(m.groups["g128"], 128);
    m.validate_grain("g32").unwrap();
    m.validate_grain("g128").unwrap();
    assert!(m.validate_grain("g64").is_err());
}

#[test]
fn exported_grains_pass_pipeline_graph_resolution() {
    let m = multigrain_manifest("resolution");
    // the ISSUE's two sweep schemes resolve their graphs up front
    for scheme in [QuantScheme::w2_g32(), QuantScheme::w4_g128()] {
        let plain = PipelineConfig::new("rtn", scheme);
        validate_scheme_artifacts(&m, "nt-tiny", &plain).unwrap();
        let tweaked = PipelineConfig::new("gptq", scheme)
            .with_tweak(TweakConfig::default());
        validate_scheme_artifacts(&m, "nt-tiny", &tweaked).unwrap();
    }
}

#[test]
fn unexported_grain_fails_fast_listing_exports() {
    let m = multigrain_manifest("unexported");
    // g64 is not in this manifest: both plain and tweaked runs must die at
    // startup with the exported-grain list, not at graph lookup mid-run
    for cfg in [
        PipelineConfig::new("rtn", QuantScheme::w2_g64()),
        PipelineConfig::new("gptq", QuantScheme::w2_g64())
            .with_tweak(TweakConfig::default()),
    ] {
        let err = validate_scheme_artifacts(&m, "nt-tiny", &cfg).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("`g64`"), "{msg}");
        assert!(msg.contains("g128, g32, pc"), "{msg}");
    }
}

#[test]
fn ablation_loss_requires_its_grain_specific_graph() {
    let m = multigrain_manifest("ablation");
    let mse = TweakConfig { loss: LossKind::Mse, ..TweakConfig::default() };
    // pc has the exported Mse ablation graph...
    let pc = PipelineConfig::new("rtn", QuantScheme::w4_perchannel())
        .with_tweak(mse);
    validate_scheme_artifacts(&m, "nt-tiny", &pc).unwrap();
    // ...grouped grains do not: error up front, naming the missing graph
    let g32 = PipelineConfig::new("rtn", QuantScheme::w2_g32()).with_tweak(mse);
    let msg = format!("{}", validate_scheme_artifacts(&m, "nt-tiny", &g32).unwrap_err());
    assert!(msg.contains("tweak_step_mse.g32"), "{msg}");
    let kl = TweakConfig { loss: LossKind::Kl, ..TweakConfig::default() };
    let g128 = PipelineConfig::new("rtn", QuantScheme::w4_g128()).with_tweak(kl);
    let msg = format!("{}", validate_scheme_artifacts(&m, "nt-tiny", &g128).unwrap_err());
    assert!(msg.contains("tweak_step_kl.g128"), "{msg}");
}

#!/usr/bin/env python3
"""Regenerate the `normtweak check` graph-lint fixtures.

    python3 rust/tests/fixtures/analysis/gen_fixtures.py

Two fixture trees are (re)written next to this script:

* `good/` — a complete, self-consistent nt-tiny export (grains pc + g64,
  buckets 8/32, incremental-decode set included).  The manifest is built
  from the *real* exporter inventory (`compile.aot.graph_defs`) with the
  real recorded `outputs` (`compile.aot.output_specs`), so it tracks the
  exporter byte-for-byte; the HLO files are signature-only stubs — a
  single `HloModule ..., entry_computation_layout={...}` header derived
  from the same specs, which is all the static `--graphs` pass reads.
  `normtweak check --graphs --deny-warnings` over this tree must be clean.

* `bad_graphs/` — the same tree with one seeded contract violation per
  NT05xx diagnostic (drifted HLO header -> NT0502, truncated quantized
  arg list + per-channel scales at a grouped grain -> NT0503, unexported
  bucket -> NT0504, shrunken prefill KV caches -> NT0505, float `pos` ->
  NT0506, non-scalar tweak loss -> NT0507, unknown family -> NT0508, a
  signature-free entry -> NT0509, garbage/empty HLO text -> NT0501).
  The golden set lives in rust/tests/analysis_lint.rs; CI greps the same
  codes out of `check --graphs --format json`.

Stubs, not real lowerings, on purpose: lowering all ~32 graphs through
XLA takes minutes and bloats the repo by megabytes, while the lint only
ever parses the ENTRY signature line.  `test_aot.py` separately pins that
real lowerings agree with the recorded specs, so the stub grammar cannot
drift from what XLA emits without that suite failing.
"""

import copy
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.abspath(os.path.join(HERE, "..", "..", "..", ".."))
sys.path.insert(0, os.path.join(REPO, "python"))

from compile import aot  # noqa: E402
from compile.configs import CALIB_BATCH, MODELS  # noqa: E402

# manifest dtype spelling -> HLO text spelling
_HLO_DTYPE = {"f32": "f32", "i8": "s8", "i32": "s32", "u8": "u8", "i64": "s64"}

MODEL = "nt-tiny"
GROUPS = {"pc": 0, "g64": 64}


def hlo_shape(spec):
    """`{"shape": [8, 128], "dtype": "i32"}` -> `s32[8,128]{1,0}`."""
    dims = ",".join(str(d) for d in spec["shape"])
    text = f"{_HLO_DTYPE[spec['dtype']]}[{dims}]"
    rank = len(spec["shape"])
    if rank:  # row-major layout suffix, as XLA prints it
        text += "{" + ",".join(str(i) for i in reversed(range(rank))) + "}"
    return text


def hlo_stub(entry):
    """A signature-only HLO header for one manifest graph entry."""
    params = ", ".join(hlo_shape(s) for s in entry["inputs"])
    results = ", ".join(hlo_shape(s) for s in entry["outputs"])
    mod = f"{entry['model']}.{entry['name']}".replace(".", "_").replace("-", "_")
    return (f"HloModule {mod}, entry_computation_layout="
            f"{{({params})->({results})}}\n")


def manifest_header(cfg):
    return {
        "format": 1,
        "calib_batch": CALIB_BATCH,
        "buckets": aot.EXPORT_BUCKETS,
        "groups": GROUPS,
        "decode": {
            "buckets": aot.EXPORT_BUCKETS,
            "slots": max(aot.EXPORT_BUCKETS),
            "caches": {cfg.name: {
                "n_layer": cfg.n_layer,
                "shape": [cfg.n_head, cfg.seq, cfg.d_head],
            }},
        },
        "models": {cfg.name: {
            "n_layer": cfg.n_layer, "d_model": cfg.d_model,
            "n_head": cfg.n_head, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "seq": cfg.seq, "norm": cfg.norm,
        }},
        "graphs": [],
    }


def write_tree(dirname, manifest, hlo_files):
    out = os.path.join(HERE, dirname)
    os.makedirs(out, exist_ok=True)
    for stale in os.listdir(out):
        if stale.endswith(".hlo.txt"):
            os.remove(os.path.join(out, stale))
    for fname, text in hlo_files.items():
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.write("\n")
    print(f"[gen] {dirname}: {len(manifest['graphs'])} graphs, "
          f"{len(hlo_files)} HLO stubs")


def build_good():
    cfg = MODELS[MODEL]
    manifest = manifest_header(cfg)
    hlo_files = {}
    for name, fn, in_args in aot.graph_defs(cfg, GROUPS, decode=True):
        entry = {
            "model": cfg.name, "name": name,
            "file": f"{cfg.name}.{name}.hlo.txt",
            "inputs": in_args,
            "outputs": aot.output_specs(fn, in_args),
        }
        manifest["graphs"].append(entry)
        hlo_files[entry["file"]] = hlo_stub(entry)
    write_tree("good", manifest, hlo_files)
    return manifest


def build_bad_graphs(good):
    by_name = {g["name"]: g for g in good["graphs"]}

    def take(name):
        return copy.deepcopy(by_name[name])

    graphs = []
    hlo_files = {}

    # NT0502: the HLO lowered `tokens` as s32[8,64] — exporter-intent drift
    g = take("embed.b8")
    drifted = copy.deepcopy(g)
    drifted["inputs"][0]["shape"] = [8, 64]
    hlo_files[g["file"]] = hlo_stub(drifted)
    graphs.append(g)

    # NT0503: quantized arg list truncated, and the g64 scales recorded
    # with the per-channel geometry ([1, 384] where [2, 384] is promised)
    g = take("block_fwd_q.g64.b8")
    g["inputs"] = g["inputs"][:5]
    g["inputs"][4]["shape"] = [1, 384]
    graphs.append(g)

    # NT0505: prefill caches shrunk to seq 64 against the decode record's
    # [n_head, seq, d_head] = [4, 128, 32]
    g = take("block_fwd_kv.b8")
    for out in g["outputs"][1:]:
        out["shape"] = [8, 4, 64, 32]
    graphs.append(g)

    # NT0506: per-row decode position recorded as f32, contract says i32[B]
    g = take("block_dec.b8")
    next(i for i in g["inputs"] if i["name"] == "pos")["dtype"] = "f32"
    graphs.append(g)

    # NT0501 (garbage HLO text) + NT0507 (loss result is not f32[1])
    g = take("tweak_step.g64")
    g["outputs"][-1]["shape"] = [32]
    hlo_files[g["file"]] = "this file is not HLO text\n"
    graphs.append(g)

    # NT0504: bucket 16 was never exported (buckets are 8 and 32)
    g = take("head.b8")
    g["name"] = "head.b16"
    g["file"] = f"{MODEL}.head.b16.hlo.txt"
    g["inputs"][0]["shape"][0] = 16
    g["outputs"][0]["shape"][0] = 16
    graphs.append(g)

    # NT0508 (unknown family, info) + NT0509 (no recorded outputs, warn)
    graphs.append({"model": MODEL, "name": "mystery.b8",
                   "file": f"{MODEL}.mystery.b8.hlo.txt", "inputs": []})

    # NT0501: present-but-empty HLO file
    g = take("channel_stats.b32")
    hlo_files[g["file"]] = ""
    graphs.append(g)

    manifest = manifest_header(MODELS[MODEL])
    manifest["graphs"] = graphs
    write_tree("bad_graphs", manifest, hlo_files)


if __name__ == "__main__":
    good = build_good()
    build_bad_graphs(good)

//! Python ↔ Rust corpus generator lock-step: the Rust mirror must reproduce
//! the Python goldens token-for-token (the foundation of every cross-language
//! experiment in the repo).

mod common;

use normtweak::calib::corpus::{c4_syn, lambada_syn, ptb_syn, token_stream, train_spec, wiki_syn};
use normtweak::tensor::load_ntz;

#[test]
fn streams_match_python_goldens() {
    let dir = common::artifacts_dir();
    let path = dir.join("corpus_golden.ntz");
    if !path.exists() {
        eprintln!("[skip] corpus_golden.ntz missing — run `make artifacts`");
        return;
    }
    let goldens = load_ntz(path).unwrap();
    for spec in [train_spec(), wiki_syn(), ptb_syn(), c4_syn()] {
        let golden = goldens
            .get(&format!("golden.{}", spec.name))
            .unwrap_or_else(|| panic!("golden for {}", spec.name));
        let want = golden.as_i32().unwrap();
        let got = token_stream(&spec, want.len());
        assert_eq!(got.len(), want.len(), "{}: length mismatch", spec.name);
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g, w, "{}: divergence at token {i}", spec.name);
        }
    }
}

#[test]
fn lambada_set_matches_python_golden() {
    let dir = common::artifacts_dir();
    let path = dir.join("lambada_syn.ntz");
    if !path.exists() {
        eprintln!("[skip] lambada_syn.ntz missing — run `make artifacts`");
        return;
    }
    let t = load_ntz(path).unwrap();
    let tokens = t.get("tokens").unwrap();
    let pos = t.get("answer_pos").unwrap();
    let n = tokens.shape[0];
    let seq = tokens.shape[1];
    let (got_items, got_pos) = lambada_syn(0x1A3B, n, seq);
    assert_eq!(got_items, tokens.as_i32().unwrap());
    let want_pos: Vec<usize> = pos.as_i32().unwrap().iter().map(|&p| p as usize).collect();
    assert_eq!(got_pos, want_pos);
}

//! Offline suite for the sensitivity profiler + bit-budget planner: static
//! taps and CPU Gram matrices only — no AOT artifacts, no runtime.
//!
//! Covers the ISSUE-3 acceptance list: deterministic allocation on a fixed
//! synthetic profile, budget-infeasible and single-layer edge cases, the
//! `sensitivity.json` round-trip, and a `PipelineConfig::validate` pass
//! over every emitted plan (grain + pack-width legality).

use std::collections::BTreeMap;

use normtweak::coordinator::PipelineConfig;
use normtweak::model::BlockWeights;
use normtweak::policy::{
    score_layer, BitBudgetPlanner, LayerSensitivity, SensitivityConfig, SensitivityProfile,
};
use normtweak::quant::quantizer::{resolve, QuantizerParams};
use normtweak::quant::QuantScheme;
use normtweak::tensor::Tensor;
use normtweak::tweak::LossKind;

const D: usize = 16;
const FF: usize = 32;
const ROWS: usize = 64;

/// Owned block weights in `BlockWeights` field order; `scale` exaggerates
/// the weight magnitude so per-layer sensitivity differs measurably.
fn fixture_weights(seed: u64, scale: f32) -> Vec<Tensor> {
    vec![
        Tensor::ones(&[D]),                          // ln1_g
        Tensor::zeros(&[D]),                         // ln1_b
        Tensor::randn(&[D, 3 * D], seed + 1, scale), // wqkv
        Tensor::zeros(&[3 * D]),                     // bqkv
        Tensor::randn(&[D, D], seed + 2, scale),     // wproj
        Tensor::zeros(&[D]),                         // bproj
        Tensor::ones(&[D]),                          // ln2_g
        Tensor::zeros(&[D]),                         // ln2_b
        Tensor::randn(&[D, FF], seed + 3, scale),    // wfc1
        Tensor::zeros(&[FF]),                        // bfc1
        Tensor::randn(&[FF, D], seed + 4, scale),    // wfc2
        Tensor::zeros(&[D]),                         // bfc2
    ]
}

fn block_view(w: &[Tensor]) -> BlockWeights<'_> {
    BlockWeights {
        ln1_g: &w[0],
        ln1_b: Some(&w[1]),
        wqkv: &w[2],
        bqkv: &w[3],
        wproj: &w[4],
        bproj: &w[5],
        ln2_g: &w[6],
        ln2_b: Some(&w[7]),
        wfc1: &w[8],
        bfc1: &w[9],
        wfc2: &w[10],
        bfc2: &w[11],
    }
}

fn fixture_taps(seed: u64) -> Vec<Tensor> {
    vec![
        Tensor::randn(&[ROWS, D], seed + 11, 1.0),
        Tensor::randn(&[ROWS, D], seed + 12, 1.0),
        Tensor::randn(&[ROWS, D], seed + 13, 1.0),
        Tensor::randn(&[ROWS, FF], seed + 14, 1.0),
    ]
}

/// Synthetic profile: `layers[i]` lists (bits, score) pairs for layer i.
fn profile_fixture(layers: &[&[(u8, f32)]], group_tag: &str, cands: &[u8]) -> SensitivityProfile {
    SensitivityProfile {
        model: "nt-tiny".into(),
        method: "gptq".into(),
        group_tag: group_tag.into(),
        calib_source: "gen-v2".into(),
        loss: "dist".into(),
        candidate_bits: cands.to_vec(),
        layers: layers
            .iter()
            .enumerate()
            .map(|(i, scores)| LayerSensitivity {
                layer: i,
                scores: scores.iter().copied().collect(),
            })
            .collect(),
        ckpt_hash: None,
    }
}

#[test]
fn score_layer_is_monotone_in_bit_width() {
    let weights = fixture_weights(7, 0.5);
    let taps = fixture_taps(7);
    let q = resolve("rtn", &QuantizerParams::default()).unwrap();
    let mut scores = BTreeMap::new();
    for bits in [2u8, 4, 8] {
        let scheme = QuantScheme { bits, group_size: Some(16) };
        let s = score_layer(block_view(&weights), &taps, scheme, q.as_ref(), LossKind::Dist)
            .unwrap();
        assert!(s.is_finite() && s >= 0.0, "{bits}-bit score {s}");
        scores.insert(bits, s);
    }
    assert!(
        scores[&2] > scores[&4] && scores[&4] > scores[&8],
        "divergence must shrink with width: {scores:?}"
    );
}

#[test]
fn score_layer_supports_every_loss_kind() {
    let weights = fixture_weights(9, 0.5);
    let taps = fixture_taps(9);
    let q = resolve("rtn", &QuantizerParams::default()).unwrap();
    let scheme = QuantScheme { bits: 2, group_size: Some(16) };
    for loss in [LossKind::Dist, LossKind::Mse, LossKind::Kl] {
        let s = score_layer(block_view(&weights), &taps, scheme, q.as_ref(), loss).unwrap();
        assert!(s.is_finite() && s > 0.0, "{loss:?} score {s}");
    }
}

#[test]
fn deterministic_allocation_on_fixed_profile() {
    // worked example: 4 layers, candidates {2,4,8}, budget 3.5 avg bits
    // (total 14). greedy by gain-per-bit: L0 2→4 (ratio 3.5), L1 2→4
    // (1.5), then L0 4→8 no longer fits and L2 2→4 (0.1) does; L3 stays.
    let p = profile_fixture(
        &[
            &[(2, 8.0), (4, 1.0), (8, 0.5)],
            &[(2, 4.0), (4, 1.0), (8, 0.9)],
            &[(2, 1.0), (4, 0.8), (8, 0.7)],
            &[(2, 0.5), (4, 0.4), (8, 0.35)],
        ],
        "g64",
        &[2, 4, 8],
    );
    let base = QuantScheme::w2_g64();
    let plan = BitBudgetPlanner::new(base, 3.5).plan(&p).unwrap();
    let bits: Vec<u8> = plan.schemes.values().map(|s| s.bits).collect();
    assert_eq!(bits, vec![4, 4, 4, 2]);
    assert_eq!(plan.mean_bits, 3.5);
    assert_eq!(plan.layer_bits_string(), "0:4,1:4,2:4,3:2");
    assert!(plan.schemes.values().all(|s| s.group_size == Some(64)));
    // provenance survives into the plan
    assert!(plan.provenance.contains("method=gptq"), "{}", plan.provenance);
    // re-planning the same profile is bit-identical
    assert_eq!(BitBudgetPlanner::new(base, 3.5).plan(&p).unwrap(), plan);
}

#[test]
fn infeasible_budget_is_a_config_error() {
    let p = profile_fixture(&[&[(2, 1.0), (4, 0.1)]], "g64", &[2, 4]);
    let err = BitBudgetPlanner::new(QuantScheme::w2_g64(), 1.5)
        .plan(&p)
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("infeasible") && msg.contains("2"), "{msg}");
}

#[test]
fn single_layer_edge_cases() {
    let p = profile_fixture(&[&[(2, 4.0), (3, 2.0), (4, 1.0), (8, 0.1)]], "g64",
                            &[2, 3, 4, 8]);
    let base = QuantScheme::w2_g64();
    // a generous budget climbs all the way to 8 bits
    let plan = BitBudgetPlanner::new(base, 8.0).plan(&p).unwrap();
    assert_eq!(plan.schemes[&0].bits, 8);
    assert_eq!(plan.mean_bits, 8.0);
    // a budget below the next step stays at the floor
    let plan = BitBudgetPlanner::new(base, 2.9).plan(&p).unwrap();
    assert_eq!(plan.schemes[&0].bits, 2);
    assert_eq!(plan.mean_bits, 2.0);
    // an exact-step budget takes exactly that step
    let plan = BitBudgetPlanner::new(base, 3.0).plan(&p).unwrap();
    assert_eq!(plan.schemes[&0].bits, 3);
}

#[test]
fn sensitivity_json_roundtrip_on_disk() {
    let p = profile_fixture(
        &[&[(2, 1.5), (4, 0.25)], &[(2, 0.375), (4, 0.0625)]],
        "g64",
        &[2, 4],
    );
    let dir = std::env::temp_dir().join("nt_policy_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sensitivity.json");
    p.save(&path).unwrap();
    let back = SensitivityProfile::load(&path).unwrap();
    assert_eq!(p, back);
    // and planning from the reloaded profile matches the original
    let planner = BitBudgetPlanner::new(QuantScheme::w2_g64(), 3.0);
    assert_eq!(planner.plan(&p).unwrap(), planner.plan(&back).unwrap());
}

#[test]
fn every_emitted_plan_passes_pipeline_validation() {
    let p = profile_fixture(
        &[
            &[(2, 5.0), (3, 2.0), (4, 1.0), (8, 0.2)],
            &[(2, 3.0), (3, 1.5), (4, 0.8), (8, 0.15)],
            &[(2, 1.0), (3, 0.6), (4, 0.4), (8, 0.1)],
        ],
        "g64",
        &[2, 3, 4, 8],
    );
    let base = QuantScheme::w2_g64();
    for target in [2.0f32, 2.25, 2.5, 3.0, 4.0, 8.0] {
        let plan = BitBudgetPlanner::new(base, target).plan(&p).unwrap();
        assert!(
            plan.mean_bits <= target + 1e-5,
            "target {target}: mean {} over budget",
            plan.mean_bits
        );
        let mut cfg = PipelineConfig::new("rtn", base);
        for (layer, scheme) in &plan.schemes {
            // every override is pack-width legal on its own...
            scheme.pack_bits().unwrap();
            cfg = cfg.with_layer_scheme(*layer, *scheme);
        }
        // ...and the whole plan passes the pipeline's grain + range check
        cfg.validate(p.layers.len()).unwrap();
    }
}

#[test]
fn duplicate_profile_layers_are_rejected() {
    let mut p = profile_fixture(&[&[(2, 1.0), (4, 0.1)]], "g64", &[2, 4]);
    let dup = p.layers[0].clone();
    p.layers.push(dup);
    let err = BitBudgetPlanner::new(QuantScheme::w2_g64(), 4.0)
        .plan(&p)
        .unwrap_err();
    assert!(format!("{err}").contains("twice"), "{err}");
}

#[test]
fn profile_grain_must_match_planner_base() {
    let p = profile_fixture(&[&[(2, 1.0), (4, 0.1)]], "g64", &[2, 4]);
    // per-channel base against a g64 profile: schemes would be grain-illegal
    let err = BitBudgetPlanner::new(QuantScheme::w4_perchannel(), 4.0)
        .plan(&p)
        .unwrap_err();
    assert!(format!("{err}").contains("grain"), "{err}");
}

#[test]
fn offline_profile_to_plan_flow_prefers_the_fragile_layer() {
    // two synthetic "layers": layer 1 has 8x larger weights, so its
    // quantization divergence dominates and the planner must upgrade it
    // first — the full profile → plan flow with no runtime involved
    let q = resolve("rtn", &QuantizerParams::default()).unwrap();
    let cfg = SensitivityConfig::new("rtn", QuantScheme { bits: 2, group_size: Some(16) });
    let candidates = cfg.normalized_candidates().unwrap();
    let mut layers = Vec::new();
    for (layer, scale) in [(0usize, 0.25f32), (1usize, 2.0f32)] {
        let weights = fixture_weights(100 + layer as u64, scale);
        let taps = fixture_taps(200 + layer as u64);
        let mut scores = BTreeMap::new();
        for &bits in &candidates {
            let scheme = QuantScheme { bits, group_size: Some(16) };
            let s = score_layer(block_view(&weights), &taps, scheme, q.as_ref(),
                                LossKind::Dist)
                .unwrap();
            scores.insert(bits, s);
        }
        layers.push(LayerSensitivity { layer, scores });
    }
    let profile = SensitivityProfile {
        model: "synthetic".into(),
        method: "rtn".into(),
        group_tag: "g16".into(),
        calib_source: "static-taps".into(),
        loss: "dist".into(),
        candidate_bits: candidates,
        layers,
        ckpt_hash: None,
    };
    let base = QuantScheme { bits: 2, group_size: Some(16) };
    // room for exactly one 2→3 upgrade: it must land on the fragile layer
    let plan = BitBudgetPlanner::new(base, 2.5).plan(&profile).unwrap();
    assert!(
        plan.schemes[&1].bits > plan.schemes[&0].bits,
        "fragile layer should win the budget: {:?}",
        plan.schemes
    );
}

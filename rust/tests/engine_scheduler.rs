//! Engine scheduler correctness — fully offline, mock models only.
//!
//! Determinism trick: [`normtweak::engine::Engine::client`] hands out
//! submission handles *before* `start()`, and those submissions buffer in
//! the engine channel.  Tests queue all traffic first, then start the
//! scheduler: the ingest/dispatch order is then exactly reproducible (no
//! timing races), so fairness, cancellation, and deadline ordering can be
//! asserted precisely.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use normtweak::engine::{Engine, GenRequest, ModelTuning, SampleConfig};
use normtweak::error::{Error, Result};
use normtweak::eval::LanguageModel;
use normtweak::model::ModelConfig;
use normtweak::obs::trace::{Phase, TraceCollector, DEFAULT_CAPACITY};
use normtweak::tensor::Tensor;

/// One observed generation call: (model tag, batch size, second token of
/// row 0 — enough to identify which request led the batch).
type CallLog = Arc<Mutex<Vec<(&'static str, usize, i32)>>>;

/// Deterministic mock: always prefers (last_token + 1) % vocab; records
/// every logits call into a shared log.
struct Mock {
    cfg: ModelConfig,
    tag: &'static str,
    cap: Option<usize>,
    warm: Vec<usize>,
    log: CallLog,
    calls: Arc<AtomicUsize>,
}

impl Mock {
    fn new(tag: &'static str, log: CallLog) -> Self {
        Mock {
            cfg: ModelConfig::builtin("nt-tiny").unwrap(),
            tag,
            cap: None,
            warm: Vec::new(),
            log,
            calls: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Boxing closure for the engine builder.
    fn factory(self) -> impl FnOnce() -> Result<Box<dyn LanguageModel>> + Send + 'static {
        move || {
            let lm: Box<dyn LanguageModel> = Box::new(self);
            Ok(lm)
        }
    }
}

impl LanguageModel for Mock {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        let tv = tokens.as_i32()?;
        let lead = if s >= 2 { tv[1] } else { tv[0] };
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.log.lock().unwrap().push((self.tag, b, lead));
        let v = self.cfg.vocab;
        let mut out = vec![0.0f32; b * s * v];
        for i in 0..b {
            for t in 0..s {
                let next = ((tv[i * s + t] + 1) as usize) % v;
                out[(i * s + t) * v + next] = 10.0;
            }
        }
        Ok(Tensor::f32(&[b, s, v], out))
    }

    fn max_batch(&self) -> Option<usize> {
        self.cap
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.warm.clone()
    }
}

fn log() -> CallLog {
    Arc::new(Mutex::new(Vec::new()))
}

#[test]
fn two_models_served_fairly_under_contention() {
    let log = log();
    let ma = Mock::new("a", log.clone());
    let mb = Mock::new("b", log.clone());
    let tuning = ModelTuning { max_batch: 2, batch_window: Duration::from_millis(5) };
    let mut engine = Engine::builder()
        .model_with("a", tuning, ma.factory())
        .model_with("b", tuning, mb.factory())
        .warmup(false)
        .build()
        .unwrap();

    // saturate both queues before the scheduler exists
    let client = engine.client();
    let mut tickets = Vec::new();
    for i in 0..6 {
        tickets.push(("a", client.submit("a", GenRequest::greedy(vec![1, 10 + i], 1)).unwrap()));
        tickets.push(("b", client.submit("b", GenRequest::greedy(vec![1, 20 + i], 1)).unwrap()));
    }
    engine.start().unwrap();
    for (key, t) in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.model, key);
        assert_eq!(r.batch_size, 2, "contended lanes must batch fully");
        assert_eq!(r.prompt_len, 2);
        assert_eq!(r.new_tokens().len(), 1);
        // deterministic mock: next token = last prompt token + 1
        assert_eq!(r.tokens[2], r.tokens[1] + 1);
    }
    let stats = engine.shutdown().unwrap();
    for lane in ["a", "b"] {
        let m = stats.model(lane).unwrap();
        assert_eq!(m.served, 6);
        assert_eq!(m.batches, 3);
        assert_eq!(m.max_batch_seen, 2);
    }

    // with both queues full before start, round-robin is exact: a,b,a,b,...
    let order = log.lock().unwrap().clone();
    assert_eq!(order.len(), 6);
    for (i, (tag, bs, _)) in order.iter().enumerate() {
        assert_eq!(*bs, 2);
        assert_eq!(*tag, if i % 2 == 0 { "a" } else { "b" },
                   "lane order not fair-share round-robin: {order:?}");
    }
}

#[test]
fn cancelled_ticket_never_consumes_a_batch_slot() {
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 8, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let t1 = client.submit("m", GenRequest::greedy(vec![1, 5], 1)).unwrap();
    let t2 = client.submit("m", GenRequest::greedy(vec![1, 6], 1)).unwrap();
    let t3 = client.submit("m", GenRequest::greedy(vec![1, 7], 1)).unwrap();
    drop(t2); // dropping the ticket cancels the not-yet-scheduled request
    engine.start().unwrap();
    assert_eq!(t1.wait().unwrap().batch_size, 2, "cancelled rider must free its slot");
    assert_eq!(t3.wait().unwrap().batch_size, 2);
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 2);
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.max_batch_seen, 2);
    assert_eq!(log.lock().unwrap().len(), 1, "exactly one batch, without the cancelled rider");
}

#[test]
fn deadline_miss_answered_with_serve_error() {
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model("m", mock.factory())
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let doomed = client
        .submit("m", GenRequest::greedy(vec![1, 5], 1).with_deadline(Duration::from_millis(1)))
        .unwrap();
    let fine = client.submit("m", GenRequest::greedy(vec![1, 6], 1)).unwrap();
    std::thread::sleep(Duration::from_millis(15));
    engine.start().unwrap();
    let err = doomed.wait().unwrap_err();
    assert!(matches!(err, Error::Serve(_)), "deadline miss must be Error::Serve: {err}");
    assert!(format!("{err}").contains("deadline"), "{err}");
    fine.wait().unwrap();
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.deadline_missed, 1);
    assert_eq!(m.served, 1);
}

#[test]
fn deadline_requests_jump_the_queue() {
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 1, batch_window: Duration::from_millis(1) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    // FIFO would serve 50 first; oldest-deadline-first serves 60 first
    // (the 300ms deadline is tighter than the FIFO aging horizon)
    let relaxed = client.submit("m", GenRequest::greedy(vec![1, 50], 1)).unwrap();
    let urgent = client
        .submit(
            "m",
            GenRequest::greedy(vec![1, 60], 1).with_deadline(Duration::from_millis(300)),
        )
        .unwrap();
    engine.start().unwrap();
    relaxed.wait().unwrap();
    urgent.wait().unwrap();
    engine.shutdown().unwrap();
    let order = log.lock().unwrap().clone();
    assert_eq!(order.len(), 2);
    assert_eq!(order[0].2, 60, "deadline'd request must dispatch first: {order:?}");
    assert_eq!(order[1].2, 50);
}

#[test]
fn tight_deadline_dispatches_before_window_closes() {
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            // window far longer than the deadline: waiting it out would
            // expire a request the engine could trivially serve in time
            // (margins are huge so CI scheduler stalls can't flake this)
            ModelTuning { max_batch: 8, batch_window: Duration::from_secs(30) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.start().unwrap();
    let t0 = std::time::Instant::now();
    let r = client
        .generate(
            "m",
            // dispatch-due = half the 2s budget: served at ~1s, expired at
            // 2s if the window were (wrongly) waited out
            GenRequest::greedy(vec![1, 7], 1).with_deadline(Duration::from_secs(2)),
        )
        .expect("a tight deadline must pre-empt the batch window, not expire");
    assert!(!r.cached);
    assert!(
        t0.elapsed() < Duration::from_millis(1800),
        "request sat out the batch window despite its deadline"
    );
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 1);
    assert_eq!(m.deadline_missed, 0);
}

#[test]
fn repeated_greedy_prompt_hits_cache() {
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 4, batch_window: Duration::from_millis(1) },
            mock.factory(),
        )
        .cache(8)
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.start().unwrap();

    let fresh = client.generate("m", GenRequest::greedy(vec![1, 9], 2)).unwrap();
    assert!(!fresh.cached);
    let hit = client.generate("m", GenRequest::greedy(vec![1, 9], 2)).unwrap();
    assert!(hit.cached, "repeat greedy prompt must be a cache hit");
    assert_eq!(hit.tokens, fresh.tokens, "cache must replay the generated tokens");
    assert_eq!(hit.gen_micros, 0);
    assert_eq!(hit.batch_size, 0);

    // a different max_new is a different cache entry
    let other = client.generate("m", GenRequest::greedy(vec![1, 9], 1)).unwrap();
    assert!(!other.cached);

    // sampled requests bypass the cache in both directions
    let sampled = SampleConfig { temperature: 1.0, stochastic_prefix: 2, seed: 7 };
    let req = GenRequest { prompt: vec![1, 9], max_new: 2, sample: sampled, deadline: None };
    let s1 = client.generate("m", req.clone()).unwrap();
    let s2 = client.generate("m", req).unwrap();
    assert!(!s1.cached && !s2.cached, "sampled requests must never be cached");
    assert_eq!(s1.tokens, s2.tokens, "same seed, same solo batch: still deterministic");

    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.cache_hits, 1);
    assert_eq!(m.cache_misses, 2, "only greedy traffic counts toward the cache");
    assert_eq!(m.served, 5);
    assert_eq!(m.batches, 4, "the cache hit rode no batch");
    assert!((m.cache_hit_rate() - 1.0 / 3.0).abs() < 1e-9);
}

#[test]
fn shutdown_drains_queued_requests_and_reports_served() {
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model("m", mock.factory())
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let tickets: Vec<_> = (0..5)
        .map(|i| client.submit("m", GenRequest::greedy(vec![1, 10 + i], 1)).unwrap())
        .collect();
    engine.start().unwrap();
    // immediate shutdown: graceful drain still answers every queued rider
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total_served(), 5, "shutdown stats must count every answered rider");
    assert_eq!(stats.model("m").unwrap().served, 5);
    for t in tickets {
        assert!(t.wait().is_ok(), "drained riders get real answers");
    }
    // the engine is gone: later submits fail cleanly instead of hanging
    let err = client.submit("m", GenRequest::greedy(vec![1], 1)).unwrap_err();
    assert!(matches!(err, Error::Serve(_)), "{err}");
}

#[test]
fn warmup_primes_each_declared_bucket() {
    let log = log();
    let mut mock = Mock::new("m", log.clone());
    mock.warm = vec![2, 1, 2]; // duplicated + unsorted on purpose
    let calls = mock.calls.clone();
    // warm-up on (builder default)
    let mut engine = Engine::builder().model("m", mock.factory()).build().unwrap();
    engine.start().unwrap();
    // start() returns only after warm-up: counts are already final
    assert_eq!(calls.load(Ordering::SeqCst), 2, "one priming batch per distinct bucket");
    let order = log.lock().unwrap().clone();
    assert_eq!(order, vec![("m", 1, 0), ("m", 2, 0)]);
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.warmup_batches, 2);
    assert_eq!(m.served, 0, "warm-up is not traffic");
    assert_eq!(m.batches, 0);
}

#[test]
fn oversized_group_chunked_to_model_bucket() {
    let log = log();
    let mut mock = Mock::new("m", log.clone());
    mock.cap = Some(2); // largest "exported bucket"
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 8, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let tickets: Vec<_> = (0..5)
        .map(|i| client.submit("m", GenRequest::greedy(vec![1, 30 + i], 1)).unwrap())
        .collect();
    engine.start().unwrap();
    let mut queue_times = Vec::new();
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.batch_size <= 2);
        queue_times.push(r.queue_micros);
    }
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 5);
    assert_eq!(m.batches, 3, "drain of 5 must chunk 2/2/1");
    assert_eq!(m.max_batch_seen, 2);
    let sizes: Vec<usize> = log.lock().unwrap().iter().map(|e| e.1).collect();
    assert_eq!(sizes, vec![2, 2, 1]);
    // every rider of the drain shares one dispatch instant: queue times may
    // differ only by submit skew, never by a chunk's generation time
    assert_eq!(m.total_queue_micros, queue_times.iter().sum::<u128>());
}

#[test]
fn riders_with_different_lengths_retire_independently() {
    // continuous batching: the short rider leaves at prefill, the long one
    // keeps stepping alone — nobody waits for a batch-mate to finish
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 4, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let long = client.submit("m", GenRequest::greedy(vec![1, 10], 3)).unwrap();
    let short = client.submit("m", GenRequest::greedy(vec![1, 20], 1)).unwrap();
    engine.start().unwrap();

    let r_long = long.wait().unwrap();
    let r_short = short.wait().unwrap();
    assert_eq!(r_long.tokens, vec![1, 10, 11, 12, 13]);
    assert_eq!(r_short.tokens, vec![1, 20, 21]);
    assert_eq!(r_long.new_tokens().len(), 3);
    assert_eq!(r_short.new_tokens().len(), 1);

    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 2);
    assert_eq!(m.batches, 1, "one shared prefill");
    assert_eq!(m.decode_steps, 2, "the long rider steps on alone");
    assert_eq!(m.prefill_tokens, 4, "both prompts prefilled");
    assert_eq!(m.decode_tokens, 2, "two tokens produced by decode steps");
    assert_eq!(m.max_batch_seen, 2);
    // prefill of 2, then decode steps of 1 (the short rider already left)
    let sizes: Vec<usize> = log.lock().unwrap().iter().map(|e| e.1).collect();
    assert_eq!(sizes, vec![2, 1, 1]);
}

#[test]
fn midstream_admission_joins_running_batch() {
    // a request arriving while the lane streams is admitted into a free
    // slot between steps and rides the running decode batch
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            // two slots: C must wait until B's slot frees, then join A
            ModelTuning { max_batch: 2, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let a = client.submit("m", GenRequest::greedy(vec![1, 10], 4)).unwrap();
    let b = client.submit("m", GenRequest::greedy(vec![1, 20], 1)).unwrap();
    let c = client.submit("m", GenRequest::greedy(vec![1, 30], 2)).unwrap();
    engine.start().unwrap();

    assert_eq!(a.wait().unwrap().tokens, vec![1, 10, 11, 12, 13, 14]);
    assert_eq!(b.wait().unwrap().tokens, vec![1, 20, 21]);
    assert_eq!(c.wait().unwrap().tokens, vec![1, 30, 31, 32]);

    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 3);
    assert_eq!(m.batches, 2, "A+B share a prefill; C gets its own on admission");
    assert_eq!(m.prefill_tokens, 6);
    // A decodes 3 tokens, C decodes 1 — one of those steps is shared
    assert_eq!(m.decode_tokens, 4);
    assert_eq!(m.decode_steps, 3);
    let sizes: Vec<usize> = log.lock().unwrap().iter().map(|e| e.1).collect();
    // prefill[A,B], step[A], prefill[C], step[A,C], step[A]
    assert_eq!(sizes, vec![2, 1, 1, 2, 1], "C must join A's running batch");
}

#[test]
fn chunked_admission_interleaves_with_decode_turns() {
    // a deep admission backlog (5 riders, bucket 2 -> 3 prefill chunks)
    // must not stall the stream that is already running: prefill and
    // decode turns strictly alternate while both kinds of work exist
    let log = log();
    let mut mock = Mock::new("m", log.clone());
    mock.cap = Some(2); // largest "exported bucket"
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 8, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    // A streams for a while; B..E retire at their prefill
    let a = client.submit("m", GenRequest::greedy(vec![1, 10], 4)).unwrap();
    let rest: Vec<_> = (0..4)
        .map(|i| client.submit("m", GenRequest::greedy(vec![1, 20 + i], 1)).unwrap())
        .collect();
    engine.start().unwrap();

    assert_eq!(a.wait().unwrap().tokens, vec![1, 10, 11, 12, 13, 14]);
    for (i, t) in rest.into_iter().enumerate() {
        let tok = 20 + i as i32;
        assert_eq!(t.wait().unwrap().tokens, vec![1, tok, tok + 1]);
    }

    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 5);
    assert_eq!(m.batches, 3, "5 riders cut to bucket 2 = 3 prefill chunks");
    assert_eq!(m.decode_steps, 3, "A decodes 3 tokens past its prefill");
    // one admission drain staged all 5 riders at once
    assert_eq!(m.admission_batch.count(), 1);
    assert_eq!(m.admission_batch.max(), 5);
    // the exact turn schedule: prefill[A,B], step[A], prefill[C,D],
    // step[A], prefill[E], step[A] — chunks interleave with decode
    let sizes: Vec<usize> = log.lock().unwrap().iter().map(|e| e.1).collect();
    assert_eq!(sizes, vec![2, 1, 2, 1, 1, 1], "prefill chunks must interleave with decode turns");
}

#[test]
fn deadline_rider_rides_the_first_prefill_chunk() {
    // chunking follows queue order, and the queue is deadline-sorted: an
    // urgent rider must land in the admission group's *first* chunk, not
    // wait out earlier FIFO chunks' prefills
    let log = log();
    let mut mock = Mock::new("m", log.clone());
    mock.cap = Some(2);
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 8, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let relaxed: Vec<_> = (0..3)
        .map(|i| client.submit("m", GenRequest::greedy(vec![1, 50 + i], 1)).unwrap())
        .collect();
    let urgent = client
        .submit(
            "m",
            GenRequest::greedy(vec![1, 60], 1).with_deadline(Duration::from_millis(300)),
        )
        .unwrap();
    engine.start().unwrap();
    urgent.wait().unwrap();
    for t in relaxed {
        t.wait().unwrap();
    }
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 4);
    assert_eq!(m.deadline_missed, 0);
    assert_eq!(m.batches, 2, "4 riders cut to bucket 2 = 2 prefill chunks");
    let order = log.lock().unwrap().clone();
    let sizes: Vec<usize> = order.iter().map(|e| e.1).collect();
    assert_eq!(sizes, vec![2, 2]);
    assert_eq!(order[0].2, 60, "urgent rider must lead the first chunk: {order:?}");
}

#[test]
fn mixed_sample_configs_ride_one_batch() {
    // per-request sampling streams: a greedy and a sampled request share
    // the same prefill and decode batches (the old scheduler split them)
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 4, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let greedy = client.submit("m", GenRequest::greedy(vec![1, 30], 2)).unwrap();
    let sampled_cfg = SampleConfig { temperature: 1.0, stochastic_prefix: 0, seed: 7 };
    let sampled = client
        .submit(
            "m",
            GenRequest { prompt: vec![1, 40], max_new: 2, sample: sampled_cfg, deadline: None },
        )
        .unwrap();
    engine.start().unwrap();

    // prefix 0 < prompt_len means the "sampled" request is greedy-effective:
    // both outputs are deterministic even though the configs differ
    assert_eq!(greedy.wait().unwrap().tokens, vec![1, 30, 31, 32]);
    assert_eq!(sampled.wait().unwrap().tokens, vec![1, 40, 41, 42]);

    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.batches, 1, "different sample configs must share one prefill");
    let sizes: Vec<usize> = log.lock().unwrap().iter().map(|e| e.1).collect();
    assert_eq!(sizes, vec![2, 2], "prefill and the one decode step both carry 2");
}

#[test]
fn zero_max_new_answered_without_generation() {
    // a degenerate request (nothing to generate) is answered directly and
    // never burns a prefill or occupies a slot
    let log = log();
    let mock = Mock::new("m", log.clone());
    let mut engine = Engine::builder()
        .model("m", mock.factory())
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let t = client.submit("m", GenRequest::greedy(vec![4, 5, 6], 0)).unwrap();
    engine.start().unwrap();
    let r = t.wait().unwrap();
    assert_eq!(r.tokens, vec![4, 5, 6]);
    assert!(r.new_tokens().is_empty());
    let stats = engine.shutdown().unwrap();
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 1);
    assert_eq!(m.batches, 0);
    assert!(log.lock().unwrap().is_empty(), "no generation call for max_new=0");
}

#[test]
fn unknown_model_and_empty_prompt_rejected_at_submit() {
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model("m", mock.factory())
        .warmup(false)
        .build()
        .unwrap();
    let client = engine.client();
    let err = client.submit("nope", GenRequest::greedy(vec![1], 1)).unwrap_err();
    assert!(format!("{err}").contains("unknown model"), "{err}");
    assert!(format!("{err}").contains("registered: m"),
            "listing registered models helps: {err}");
    let err = client.submit("m", GenRequest::greedy(vec![], 1)).unwrap_err();
    assert!(format!("{err}").contains("empty prompt"), "{err}");
    // never started: shutdown reports the misuse instead of hanging
    assert!(engine.shutdown().is_err());
}

#[test]
fn trace_records_request_lifecycle_and_gauges_stay_live() {
    let tc = Arc::new(TraceCollector::new(DEFAULT_CAPACITY));
    let mock = Mock::new("m", log());
    let mut engine = Engine::builder()
        .model_with(
            "m",
            ModelTuning { max_batch: 2, batch_window: Duration::from_millis(5) },
            mock.factory(),
        )
        .warmup(false)
        .trace(tc.clone())
        .build()
        .unwrap();
    let client = engine.client();

    // gauges are pollable before the scheduler even starts
    let pre = client.stats_snapshot();
    assert_eq!(pre.len(), 1);
    assert_eq!(pre[0].model, "m");
    assert_eq!(pre[0].max_slots, 2);
    assert_eq!(pre[0].served, 0);

    // long decodes past prefill, short retires at prefill: both lifecycle
    // shapes land in one trace
    let long = client.submit("m", GenRequest::greedy(vec![1, 10], 2)).unwrap();
    let short = client.submit("m", GenRequest::greedy(vec![1, 20], 1)).unwrap();
    engine.start().unwrap();
    long.wait().unwrap();
    short.wait().unwrap();
    let stats = engine.shutdown().unwrap();

    // engine-measured latency histograms: one sample per served request
    // for queue/e2e, one per dispatch for prefill/decode
    let m = stats.model("m").unwrap();
    assert_eq!(m.served, 2);
    assert_eq!(m.queue_us.count(), 2);
    assert_eq!(m.e2e_us.count(), 2);
    assert_eq!(m.prefill_us.count(), 1, "one shared prefill dispatch");
    assert_eq!(m.decode_step_us.count(), 1, "the long rider steps once alone");

    // the client's gauge handles are the scheduler's own cells: final
    // values survive shutdown, nothing left in flight
    let post = client.stats_snapshot();
    assert_eq!(post[0].served, 2);
    assert_eq!(post[0].in_flight(), 0, "drained engine must report empty lanes");

    // lifecycle tracks: scheduler instants plus a (prefill, decode) pair
    // per lane — the >= 3 named tracks trace_validate requires
    let tracks = tc.track_names();
    for name in ["scheduler", "lane:m/prefill", "lane:m/decode"] {
        assert!(tracks.contains_key(name), "missing track {name}: {tracks:?}");
    }

    let evs = tc.snapshot();
    let sched = tracks["scheduler"];
    let instants: Vec<&str> = evs
        .iter()
        .filter(|e| e.tid == sched && e.ph == Phase::Instant)
        .map(|e| e.name.as_str())
        .collect();
    assert_eq!(
        instants,
        ["submit", "submit", "admit", "admit", "retire", "retire"],
        "scheduler lifecycle out of order"
    );
    // every request's async begin pairs with exactly one end
    let begins: Vec<u64> = evs
        .iter()
        .filter(|e| e.ph == Phase::AsyncBegin && e.name == "request")
        .map(|e| e.id)
        .collect();
    let mut ends: Vec<u64> = evs
        .iter()
        .filter(|e| e.ph == Phase::AsyncEnd && e.name == "request")
        .map(|e| e.id)
        .collect();
    assert_eq!(begins.len(), 2);
    ends.sort_unstable();
    let mut sorted_begins = begins.clone();
    sorted_begins.sort_unstable();
    assert_eq!(sorted_begins, ends, "unbalanced request async pairs");
    // dispatch spans landed on their lane tracks
    let span_count = |tid: u64, name: &str| {
        evs.iter().filter(|e| e.tid == tid && e.ph == Phase::Complete && e.name == name).count()
    };
    assert_eq!(span_count(tracks["lane:m/prefill"], "prefill"), 1);
    assert_eq!(span_count(tracks["lane:m/decode"], "decode_step"), 1);
}

#[test]
fn factory_failure_surfaces_at_start() {
    let mut engine = Engine::builder()
        .model("broken", || Err(Error::Artifact("no such checkpoint".into())))
        .build()
        .unwrap();
    let err = engine.start().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("broken"), "{msg}");
    assert!(msg.contains("no such checkpoint"), "{msg}");
}

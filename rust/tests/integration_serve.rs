//! Serving loop: dynamic batching correctness under concurrent traffic.

mod common;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig, QuantModel};
use normtweak::quant::QuantScheme;
use normtweak::serve::{channel, serve_loop, ServeConfig};

#[test]
fn concurrent_requests_all_answered_and_batched() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    // quick RTN quantization to get a servable model
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        rt.manifest.calib_batch * w.config.seq,
    );
    let calib = CalibSet::from_stream(&stream, rt.manifest.calib_batch,
                                      w.config.seq, "wiki-syn").unwrap();
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    let model = QuantModel::new(&rt, &qm).unwrap();

    let (handle, rx) = channel();
    let n_clients = 4;
    let per_client = 6;
    let stats = std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = handle.clone();
            s.spawn(move || {
                for i in 0..per_client {
                    let prompt = vec![1, (8 + (c * 31 + i * 7) % 150) as i32];
                    let resp = h.submit(prompt.clone(), 8).expect("response");
                    assert_eq!(resp.tokens.len(), prompt.len() + 8);
                    assert_eq!(&resp.tokens[..2], &prompt[..]);
                    assert!(resp.batch_size >= 1);
                }
            });
        }
        drop(handle);
        serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: std::time::Duration::from_millis(20) },
            rx,
        )
    })
    .unwrap();

    assert_eq!(stats.served, n_clients * per_client);
    // with 4 concurrent clients and a 20ms window, some batching must occur
    assert!(stats.max_batch_seen >= 2, "never batched: {stats:?}");
    assert!(stats.batches < stats.served, "no batch ever had more than 1");
}

#[test]
fn serve_deterministic_per_prompt() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = normtweak::coordinator::FloatModel::new(&rt, &w).unwrap();

    let (handle, rx) = channel();
    let results = std::thread::scope(|s| {
        let h = handle.clone();
        let t = s.spawn(move || {
            let a = h.submit(vec![1, 42], 8).unwrap();
            let b = h.submit(vec![1, 42], 8).unwrap();
            (a.tokens, b.tokens)
        });
        drop(handle);
        serve_loop(&fm, ServeConfig::default(), rx).unwrap();
        t.join().unwrap()
    });
    assert_eq!(results.0, results.1, "greedy serving must be deterministic");
}

//! Serving: engine correctness over real quantized models (artifact-gated)
//! plus coverage of the deprecated `serve_loop` shim.

mod common;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig, QuantModel};
use normtweak::engine::{Engine, GenRequest, ServableModel};
use normtweak::eval::LanguageModel;
use normtweak::quant::QuantScheme;
#[allow(deprecated)]
use normtweak::serve::{channel, serve_loop, ServeConfig};

fn calib_for(
    rt: &normtweak::runtime::Runtime,
    w: &normtweak::model::ModelWeights,
) -> CalibSet {
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        rt.manifest.calib_batch * w.config.seq,
    );
    CalibSet::from_stream(&stream, rt.manifest.calib_batch, w.config.seq, "wiki-syn")
        .unwrap()
}

/// Two checkpoints (w4 and w8 RTN) registered under one engine, driven by
/// concurrent clients: every request is answered by the model it named,
/// warm-up primed the exported buckets, and shutdown stats account for
/// every rider.
#[test]
fn engine_serves_two_real_models_concurrently() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_for(&rt, &w);
    let mut ckpts = Vec::new();
    for (name, bits) in [("w4", 4u8), ("w8", 8u8)] {
        let cfg = PipelineConfig::new("rtn", QuantScheme { bits, group_size: None });
        let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
        let path = std::env::temp_dir().join(format!("engine_it_{name}.ntz"));
        qm.save(&path).unwrap();
        ckpts.push((name, path));
    }

    let mut builder = Engine::builder().cache(16);
    for (name, path) in &ckpts {
        let dir = common::artifacts_dir();
        let path = path.clone();
        builder = builder.model(*name, move || {
            let lm: Box<dyn LanguageModel> =
                Box::new(ServableModel::load(&dir, "nt-tiny", &path)?);
            Ok(lm)
        });
    }
    let mut engine = builder.build().unwrap();
    let client = engine.start().unwrap();

    let n_clients = 4;
    let per_client = 4;
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let client = client.clone();
            s.spawn(move || {
                for i in 0..per_client {
                    let key = if (c + i) % 2 == 0 { "w4" } else { "w8" };
                    let prompt = vec![1, (8 + (c * 31 + i * 7) % 150) as i32];
                    let resp = client
                        .generate(key, GenRequest::greedy(prompt.clone(), 8))
                        .expect("response");
                    assert_eq!(resp.model, key);
                    assert_eq!(resp.tokens.len(), prompt.len() + 8);
                    assert_eq!(&resp.tokens[..2], &prompt[..]);
                    assert_eq!(resp.prompt_len, 2);
                    assert_eq!(resp.new_tokens().len(), 8);
                }
            });
        }
    });

    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total_served(), n_clients * per_client);
    for key in ["w4", "w8"] {
        let m = stats.model(key).unwrap();
        assert_eq!(m.served, n_clients * per_client / 2);
        assert!(m.warmup_batches >= 1, "warm-up must prime the exported buckets");
        assert_eq!(m.cancelled, 0);
        assert_eq!(m.deadline_missed, 0);
    }
}

/// A repeated greedy prompt on a real model comes back from the cache,
/// token-identical to the generated answer.
#[test]
fn engine_cache_replays_real_greedy_generation() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_for(&rt, &w);
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    let path = std::env::temp_dir().join("engine_it_cache.ntz");
    qm.save(&path).unwrap();

    let dir = common::artifacts_dir();
    let mut engine = Engine::builder()
        .cache(8)
        .model("w4", move || {
            let lm: Box<dyn LanguageModel> =
                Box::new(ServableModel::load(&dir, "nt-tiny", &path)?);
            Ok(lm)
        })
        .build()
        .unwrap();
    let client = engine.start().unwrap();
    let fresh = client.generate("w4", GenRequest::greedy(vec![1, 42], 8)).unwrap();
    let hit = client.generate("w4", GenRequest::greedy(vec![1, 42], 8)).unwrap();
    assert!(!fresh.cached);
    assert!(hit.cached);
    assert_eq!(fresh.tokens, hit.tokens, "greedy serving must be deterministic");
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.model("w4").unwrap().cache_hits, 1);
}

#[test]
#[allow(deprecated)]
fn legacy_shim_concurrent_requests_all_answered_and_batched() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let calib = calib_for(&rt, &w);
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    let model = QuantModel::new(&rt, &qm).unwrap();

    let (handle, rx) = channel();
    let n_clients = 4;
    let per_client = 6;
    let stats = std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = handle.clone();
            s.spawn(move || {
                for i in 0..per_client {
                    let prompt = vec![1, (8 + (c * 31 + i * 7) % 150) as i32];
                    let resp = h.submit(prompt.clone(), 8).expect("response");
                    assert_eq!(resp.tokens.len(), prompt.len() + 8);
                    assert_eq!(&resp.tokens[..2], &prompt[..]);
                    assert_eq!(resp.new_tokens().len(), 8);
                    assert!(resp.batch_size >= 1);
                }
            });
        }
        drop(handle);
        serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: std::time::Duration::from_millis(20) },
            rx,
        )
    })
    .unwrap();

    assert_eq!(stats.served, n_clients * per_client);
    // with 4 concurrent clients and a 20ms window, some batching must occur
    assert!(stats.max_batch_seen >= 2, "never batched: {stats:?}");
    assert!(stats.batches < stats.served, "no batch ever had more than 1");
}

#[test]
#[allow(deprecated)]
fn legacy_shim_deterministic_per_prompt() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = normtweak::coordinator::FloatModel::new(&rt, &w).unwrap();

    let (handle, rx) = channel();
    let results = std::thread::scope(|s| {
        let h = handle.clone();
        let t = s.spawn(move || {
            let a = h.submit(vec![1, 42], 8).unwrap();
            let b = h.submit(vec![1, 42], 8).unwrap();
            (a.tokens, b.tokens)
        });
        drop(handle);
        serve_loop(&fm, ServeConfig::default(), rx).unwrap();
        t.join().unwrap()
    });
    assert_eq!(results.0, results.1, "greedy serving must be deterministic");
}

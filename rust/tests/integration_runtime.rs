//! Runtime integration: AOT artifacts load, compile, execute, and the
//! composed Rust pipeline (embed → blocks → head) reproduces the Python
//! golden logits — the end-to-end numeric parity proof for the whole stack.

mod common;

use normtweak::coordinator::FloatModel;
use normtweak::eval::LanguageModel;
use normtweak::tensor::{load_ntz, matmul, mean_var_channels, transpose2d, Tensor};

#[test]
fn golden_logits_parity() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let golden = load_ntz(common::artifacts_dir().join("golden_nt-tiny.ntz")).unwrap();
    let tokens = golden.get("tokens").unwrap();
    let want = golden.get("logits").unwrap();

    let fm = FloatModel::new(&rt, &w).unwrap();
    let got = fm.logits(tokens).unwrap();
    assert_eq!(got.shape, want.shape);
    let gv = got.as_f32().unwrap();
    let wv = want.as_f32().unwrap();
    let max_diff = gv
        .iter()
        .zip(wv)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 5e-3,
        "rust-composed logits deviate from python golden: {max_diff}"
    );
}

#[test]
fn channel_stats_graph_matches_cpu() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let cb = rt.manifest.calib_batch;
    let x = Tensor::randn(&[cb, w.config.seq, w.config.d_model], 3, 1.0);
    let (mu, var) = fm.channel_stats(&x).unwrap();
    let (mu_cpu, var_cpu) = mean_var_channels(&x).unwrap();
    for (a, b) in mu.as_f32().unwrap().iter().zip(&mu_cpu) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    for (a, b) in var.as_f32().unwrap().iter().zip(&var_cpu) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn xtx_graph_matches_cpu_matmul() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let cb = rt.manifest.calib_batch;
    let k = 128usize; // nt-tiny d_model
    let rows = cb * 128;
    let x = Tensor::randn(&[rows, k], 5, 0.5);
    let got = rt.run("nt-tiny", &format!("xtx.k{k}"), &[&x]).unwrap();
    let want = matmul(&transpose2d(&x).unwrap(), &x).unwrap();
    let gv = got[0].as_f32().unwrap();
    let wv = want.as_f32().unwrap();
    for (a, b) in gv.iter().zip(wv) {
        assert!((a - b).abs() <= 1e-2 + 1e-4 * b.abs(), "{a} vs {b}");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = common::runtime_or_skip() else { return };
    let Some(w) = common::weights_or_skip("nt-tiny") else { return };
    let fm = FloatModel::new(&rt, &w).unwrap();
    let toks = Tensor::i32(&[1, w.config.seq], vec![1; w.config.seq]);
    let _ = fm.logits(&toks).unwrap();
    let compiles_after_first = rt.stats().compiles;
    let _ = fm.logits(&toks).unwrap();
    assert_eq!(rt.stats().compiles, compiles_after_first, "no recompiles");
    assert!(rt.cached() >= 3); // embed + block_fwd + head at least
}

#[test]
fn arg_validation_catches_mistakes() {
    let Some(rt) = common::runtime_or_skip() else { return };
    // wrong arg count
    let x = Tensor::zeros(&[1, 1]);
    assert!(rt.run("nt-tiny", "channel_stats.b32", &[&x, &x]).is_err());
    // wrong shape
    assert!(rt.run("nt-tiny", "channel_stats.b32", &[&x]).is_err());
    // unknown graph
    assert!(rt.run("nt-tiny", "nope", &[&x]).is_err());
}

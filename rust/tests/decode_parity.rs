//! Prefill/decode parity — fully offline, mock models only.
//!
//! The contract under test: greedy generation through the
//! [`DecodeSession`] API (prefill once, then one `decode_step` per token)
//! is **token-identical** to the classic full-context recompute path,
//! whether the model serves sessions through the trait's recompute
//! fallback or through its own incremental cache.  The mock's next-token
//! preference depends on the *entire prefix and the position*, so any
//! cache-threading, masking, or position bug shows up as a token mismatch.

use normtweak::error::{Error, Result};
use normtweak::eval::decode::{self, lock_arena};
use normtweak::eval::generate::{generate, SampleConfig};
use normtweak::eval::{ArenaSlot, DecodeSession, KvArena, KvCache, LanguageModel, SharedKvArena};
use normtweak::model::ModelConfig;
use normtweak::tensor::Tensor;

/// Preferred next token after a prefix with running `sum` at 1-based
/// length `len` — both content- and position-dependent.
fn pref(sum: i64, len: usize, vocab: usize) -> usize {
    ((sum * 7 + len as i64 * 13).unsigned_abs() as usize) % vocab
}

/// Plain mock: full-context logits only; the session API runs through the
/// trait's recompute fallback.
struct Plain(ModelConfig);

fn mix_logits(cfg: &ModelConfig, tokens: &Tensor) -> Result<Tensor> {
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    let v = cfg.vocab;
    let tv = tokens.as_i32()?;
    let mut out = vec![0.0f32; b * s * v];
    for i in 0..b {
        let mut sum = 0i64;
        for t in 0..s {
            sum += tv[i * s + t] as i64;
            out[(i * s + t) * v + pref(sum, t + 1, v)] = 5.0;
        }
    }
    Ok(Tensor::f32(&[b, s, v], out))
}

impl LanguageModel for Plain {
    fn config(&self) -> &ModelConfig {
        &self.0
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        mix_logits(&self.0, tokens)
    }
}

/// Caching mock: overrides the session API with real incremental state —
/// the running prefix sum lives in the session's [`KvCache::Layers`] slot
/// (a 1-element tensor), exactly as an XLA runner would thread its KV
/// caches.  `logits()` stays available and must agree with the cache path.
struct Cached(ModelConfig);

fn one_hot(idx: usize, vocab: usize) -> Vec<f32> {
    let mut row = vec![0.0f32; vocab];
    row[idx] = 5.0;
    row
}

impl LanguageModel for Cached {
    fn config(&self) -> &ModelConfig {
        &self.0
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        mix_logits(&self.0, tokens)
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        let v = self.0.vocab;
        prompts
            .iter()
            .map(|p| {
                if p.is_empty() {
                    return Err(Error::Config("empty prompt".into()));
                }
                let sum: i64 = p.iter().map(|&t| t as i64).sum();
                let state = Tensor::f32(&[1, 1, 1, 1], vec![sum as f32]);
                Ok(DecodeSession {
                    tokens: p.clone(),
                    logits: one_hot(pref(sum, p.len(), v), v),
                    kv: KvCache::Layers(vec![(state.clone(), state)]),
                })
            })
            .collect()
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        let v = self.0.vocab;
        for s in sessions.iter_mut() {
            let last = *s.tokens.last().unwrap() as i64;
            let sum = match &s.kv {
                KvCache::Layers(l) => l[0].0.as_f32()?[0] as i64 + last,
                KvCache::Recompute => {
                    return Err(Error::Config("cached mock got a recompute session".into()))
                }
                KvCache::Slot(_) => {
                    return Err(Error::Config("stacked mock got a slot-resident session".into()))
                }
            };
            let state = Tensor::f32(&[1, 1, 1, 1], vec![sum as f32]);
            s.kv = KvCache::Layers(vec![(state.clone(), state)]);
            s.logits = one_hot(pref(sum, s.tokens.len(), v), v);
        }
        Ok(())
    }
}

/// Slot-arena mock: the same prefix-sum semantics as [`Cached`], but the
/// running sum lives inside a real [`KvArena`] — batched admission via
/// `try_reserve`/`write_row`, per-step in-place arena updates through
/// `take_layer`/`put_layer`, recompute fallback when the arena is full.
/// Exactly the cache discipline the XLA runners use, minus the graphs.
struct ArenaMock {
    cfg: ModelConfig,
    arena: SharedKvArena,
}

impl ArenaMock {
    fn new(cfg: ModelConfig, slots: usize) -> Self {
        let arena = KvArena::shared(1, 1, cfg.seq, 1, slots);
        ArenaMock { cfg, arena }
    }
}

impl LanguageModel for ArenaMock {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        mix_logits(&self.cfg, tokens)
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn kv_arena(&self) -> Option<SharedKvArena> {
        Some(self.arena.clone())
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        let v = self.cfg.vocab;
        let seq = self.cfg.seq;
        let b = prompts.len();
        let mut sums = Vec::with_capacity(b);
        for p in prompts {
            if p.is_empty() {
                return Err(Error::Config("empty prompt".into()));
            }
            sums.push(p.iter().map(|&t| t as i64).sum::<i64>());
        }
        // batched admission: all-or-nothing; a full arena falls back to
        // recompute sessions rather than failing the request
        let Some(ids) = lock_arena(&self.arena).try_reserve(b) else {
            return decode::recompute_prefill(self, prompts);
        };
        // one batched "prefill output": row r carries row r's running sum
        let mut kd = vec![0.0f32; b * seq];
        for (r, &sum) in sums.iter().enumerate() {
            kd[r * seq] = sum as f32;
        }
        let k = Tensor::f32(&[b, 1, seq, 1], kd.clone());
        let vv = Tensor::f32(&[b, 1, seq, 1], kd);
        {
            let mut g = lock_arena(&self.arena);
            for (r, &slot) in ids.iter().enumerate() {
                g.write_row(0, slot, &k, &vv, r)?;
                g.note(slot, *prompts[r].last().unwrap(), (prompts[r].len() - 1) as i32);
            }
        }
        Ok(prompts
            .iter()
            .zip(sums)
            .zip(ids)
            .map(|((p, sum), slot)| DecodeSession {
                tokens: p.clone(),
                logits: one_hot(pref(sum, p.len(), v), v),
                kv: KvCache::Slot(ArenaSlot::new(self.arena.clone(), slot)),
            })
            .collect())
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        let v = self.cfg.vocab;
        let seq = self.cfg.seq;
        let mut slotted: Vec<(usize, &mut DecodeSession)> = Vec::new();
        let mut rest: Vec<&mut DecodeSession> = Vec::new();
        for s in sessions.iter_mut() {
            let slot = match &s.kv {
                KvCache::Slot(a) => Some(a.index()),
                _ => None,
            };
            match slot {
                Some(i) => slotted.push((i, &mut **s)),
                None => rest.push(&mut **s),
            }
        }
        if !slotted.is_empty() {
            let mut g = lock_arena(&self.arena);
            let (mut k, kv) = g.take_layer(0)?;
            {
                let kd = k.as_f32_mut()?;
                for (slot, s) in slotted.iter_mut() {
                    let last = *s.tokens.last().unwrap() as i64;
                    let sum = kd[*slot * seq] as i64 + last;
                    kd[*slot * seq] = sum as f32;
                    s.logits = one_hot(pref(sum, s.tokens.len(), v), v);
                    g.note(*slot, last as i32, (s.tokens.len() - 1) as i32);
                }
            }
            g.put_layer(0, k, kv)?;
        }
        if !rest.is_empty() {
            decode::recompute_decode_step(self, &mut rest)?;
        }
        Ok(())
    }
}

fn greedy() -> SampleConfig {
    SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 1 }
}

#[test]
fn session_loop_matches_generate_on_recompute_mock() {
    let m = Plain(ModelConfig::builtin("nt-tiny").unwrap());
    let prompts = vec![vec![5, 9], vec![1000, 3, 77, 4]];
    let target = 12;
    let expected = generate(&m, &prompts, target, &greedy()).unwrap();

    // drive the session API by hand, the way the serving engine does
    let mut sessions = m.prefill(&prompts).unwrap();
    loop {
        let mut stepping = Vec::new();
        for (i, s) in sessions.iter_mut().enumerate() {
            if s.tokens.len() >= target {
                continue;
            }
            let tok = s.greedy_next();
            s.tokens.push(tok);
            if s.tokens.len() < target {
                stepping.push(i);
            }
        }
        if stepping.is_empty() {
            break;
        }
        let mut rest = &mut sessions[..];
        let mut refs = Vec::new();
        let mut consumed = 0;
        for &i in &stepping {
            let (head, tail) = rest.split_at_mut(i - consumed + 1);
            refs.push(&mut head[i - consumed]);
            rest = tail;
            consumed = i + 1;
        }
        m.decode_step(&mut refs).unwrap();
    }
    let got: Vec<Vec<i32>> = sessions.into_iter().map(|s| s.tokens).collect();
    assert_eq!(got, expected, "DecodeSession greedy loop must match generate()");
}

#[test]
fn cached_sessions_match_recompute_path_token_for_token() {
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let plain = Plain(cfg.clone());
    let cached = Cached(cfg);
    let prompts = vec![vec![2, 4, 6], vec![11], vec![300, 301]];
    let a = generate(&plain, &prompts, 10, &greedy()).unwrap();
    let b = generate(&cached, &prompts, 10, &greedy()).unwrap();
    assert_eq!(a, b, "incremental cache must be token-identical to recompute");
}

#[test]
fn stochastic_generation_is_path_independent() {
    // same seed, same logits → same sampled stream on either path
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let plain = Plain(cfg.clone());
    let cached = Cached(cfg);
    let sc = SampleConfig { temperature: 0.8, stochastic_prefix: 6, seed: 0xFEED };
    let prompts = vec![vec![42], vec![7, 8]];
    let a = generate(&plain, &prompts, 9, &sc).unwrap();
    let b = generate(&cached, &prompts, 9, &sc).unwrap();
    assert_eq!(a, b);
}

#[test]
fn continuous_batching_interleave_matches_solo_generation() {
    // sessions created at different times, stepped in shifting subsets —
    // exactly the engine's continuous batching — must finish with the same
    // tokens as one-at-a-time generation
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let m = Cached(cfg);
    let target = 8;

    let solo_a = generate(&m, &[vec![10, 20]], target, &greedy()).unwrap();
    let solo_b = generate(&m, &[vec![500]], target, &greedy()).unwrap();

    // A starts alone
    let mut sessions = m.prefill(&[vec![10, 20]]).unwrap();
    let tok = sessions[0].greedy_next();
    sessions[0].tokens.push(tok);
    let (first, _) = sessions.split_at_mut(1);
    let mut refs = vec![&mut first[0]];
    m.decode_step(&mut refs).unwrap();

    // B joins mid-stream; both step together from here
    sessions.extend(m.prefill(&[vec![500]]).unwrap());
    loop {
        for s in sessions.iter_mut() {
            if s.tokens.len() < target {
                let tok = s.greedy_next();
                s.tokens.push(tok);
            }
        }
        let mut refs: Vec<&mut DecodeSession> = sessions
            .iter_mut()
            .filter(|s| s.tokens.len() < target)
            .collect();
        if refs.is_empty() {
            break;
        }
        m.decode_step(&mut refs).unwrap();
    }
    assert_eq!(sessions[0].tokens, solo_a[0]);
    assert_eq!(sessions[1].tokens, solo_b[0]);
}

#[test]
fn arena_sessions_match_recompute_path_token_for_token() {
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let plain = Plain(cfg.clone());
    let arena = ArenaMock::new(cfg, 4);
    let prompts = vec![vec![2, 4, 6], vec![11], vec![300, 301]];
    let a = generate(&plain, &prompts, 10, &greedy()).unwrap();
    let b = generate(&arena, &prompts, 10, &greedy()).unwrap();
    assert_eq!(a, b, "slot-arena decode must be token-identical to recompute");
    // generate() retired every session; the arena must be fully drained
    assert_eq!(lock_arena(&arena.arena).occupancy(), 0);
}

#[test]
fn arena_matches_stacked_cached_path() {
    // the arena is a drop-in replacement for the legacy stacked per-session
    // caches: same tokens, greedy and stochastic
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let cached = Cached(cfg.clone());
    let arena = ArenaMock::new(cfg, 4);
    let prompts = vec![vec![2, 4, 6], vec![11], vec![300, 301]];
    let a = generate(&cached, &prompts, 10, &greedy()).unwrap();
    let b = generate(&arena, &prompts, 10, &greedy()).unwrap();
    assert_eq!(a, b, "arena and stacked caches must agree");
    let sc = SampleConfig { temperature: 0.8, stochastic_prefix: 6, seed: 0xFEED };
    let a = generate(&cached, &prompts, 9, &sc).unwrap();
    let b = generate(&arena, &prompts, 9, &sc).unwrap();
    assert_eq!(a, b, "same seed, same logits -> same sampled stream");
}

#[test]
fn arena_slots_are_reused_after_retirement() {
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let solo = generate(&Plain(cfg.clone()), &[vec![10, 20]], 8, &greedy()).unwrap();
    let m = ArenaMock::new(cfg, 1);

    let first = generate(&m, &[vec![10, 20]], 8, &greedy()).unwrap();
    assert_eq!(first, solo);
    assert_eq!(lock_arena(&m.arena).occupancy(), 0, "retirement must free the slot");

    // the freed slot serves a second generation with no cross-talk from
    // the first occupant's rows
    let second = generate(&m, &[vec![10, 20]], 8, &greedy()).unwrap();
    assert_eq!(second, solo);
    assert_eq!(lock_arena(&m.arena).occupancy(), 0);
}

#[test]
fn arena_exhaustion_falls_back_to_recompute_sessions() {
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let m = ArenaMock::new(cfg.clone(), 1);
    // batched admission is all-or-nothing: two prompts cannot both fit a
    // one-slot arena, so both ride the recompute fallback
    let sessions = m.prefill(&[vec![5], vec![6]]).unwrap();
    assert!(sessions.iter().all(|s| matches!(s.kv, KvCache::Recompute)));
    assert_eq!(lock_arena(&m.arena).occupancy(), 0);
    drop(sessions);
    // and generation through the fallback still matches recompute
    let prompts = vec![vec![2, 4, 6], vec![11]];
    let a = generate(&Plain(cfg), &prompts, 10, &greedy()).unwrap();
    let b = generate(&m, &prompts, 10, &greedy()).unwrap();
    assert_eq!(a, b);
}

#[test]
fn arena_chunked_admission_interleaves_with_decode() {
    // admission chunks land at different times while earlier residents keep
    // stepping — the engine's chunked-prefill interleaving — and every
    // session still matches its solo generation
    let cfg = ModelConfig::builtin("nt-tiny").unwrap();
    let m = ArenaMock::new(cfg, 4);
    let target = 8;
    let solo_a = generate(&m, &[vec![10, 20]], target, &greedy()).unwrap();
    let solo_b = generate(&m, &[vec![500]], target, &greedy()).unwrap();
    let solo_c = generate(&m, &[vec![7, 8, 9]], target, &greedy()).unwrap();

    // chunk 1: A admitted alone, takes a decode turn
    let mut sessions = m.prefill(&[vec![10, 20]]).unwrap();
    assert!(matches!(sessions[0].kv, KvCache::Slot(_)));
    let tok = sessions[0].greedy_next();
    sessions[0].tokens.push(tok);
    {
        let (first, _) = sessions.split_at_mut(1);
        let mut refs = vec![&mut first[0]];
        m.decode_step(&mut refs).unwrap();
    }

    // chunk 2: B and C admitted together mid-stream; everyone steps from here
    sessions.extend(m.prefill(&[vec![500], vec![7, 8, 9]]).unwrap());
    assert_eq!(lock_arena(&m.arena).occupancy(), 3);
    loop {
        for s in sessions.iter_mut() {
            if s.tokens.len() < target {
                let tok = s.greedy_next();
                s.tokens.push(tok);
            }
        }
        let mut refs: Vec<&mut DecodeSession> = sessions
            .iter_mut()
            .filter(|s| s.tokens.len() < target)
            .collect();
        if refs.is_empty() {
            break;
        }
        m.decode_step(&mut refs).unwrap();
    }
    assert_eq!(sessions[0].tokens, solo_a[0]);
    assert_eq!(sessions[1].tokens, solo_b[0]);
    assert_eq!(sessions[2].tokens, solo_c[0]);
    drop(sessions);
    assert_eq!(lock_arena(&m.arena).occupancy(), 0);
}

#[test]
fn recompute_fallback_is_always_available() {
    // a model that never opted into decode still serves the session API
    let m = Plain(ModelConfig::builtin("nt-tiny").unwrap());
    assert!(!m.supports_decode());
    let mut sessions = m.prefill(&[vec![1, 2, 3]]).unwrap();
    assert!(matches!(sessions[0].kv, KvCache::Recompute));
    let tok = sessions[0].greedy_next();
    sessions[0].tokens.push(tok);
    let (head, _) = sessions.split_at_mut(1);
    let mut refs = vec![&mut head[0]];
    m.decode_step(&mut refs).unwrap();
    assert_eq!(sessions[0].logits.len(), m.config().vocab);
}

//! Golden-fixture corpus for the `normtweak check` lint rules.
//!
//! Fully offline: corrupted manifests live under
//! `tests/fixtures/analysis/`, corrupted checkpoints and profiles are
//! synthesized into temp dirs.  The suite pins three contracts:
//!
//! 1. every committed fixture produces exactly its golden diagnostic-code
//!    set (and the clean fixture produces none),
//! 2. every stable `NTxxxx` code in [`normtweak::analysis::codes::ALL`]
//!    fires on at least one corpus scenario and appears in the module's
//!    rustdoc table,
//! 3. `check --format json` output round-trips through `util::json`.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use normtweak::analysis::{codes, run_lints, CheckContext, PlanSpec, ServeCheck};
use normtweak::model::{ModelConfig, ModelWeights, QuantLinear, QuantizedBlock, QuantizedModel};
use normtweak::quant::QuantScheme;
use normtweak::runtime::ArtifactManifest;
use normtweak::tensor::{load_ntz, pack_codes, save_ntz, Tensor};
use normtweak::tweak::LossKind;
use normtweak::util::hash::file_hex;
use normtweak::util::json::Json;

fn fixture_dir(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/analysis").join(name)
}

fn search_fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/search").join(name)
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nt_analysis_lint_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny() -> ModelConfig {
    ModelConfig::builtin("nt-tiny").unwrap()
}

fn w4g64() -> QuantScheme {
    QuantScheme { bits: 4, group_size: Some(64) }
}

fn good_manifest() -> ArtifactManifest {
    ArtifactManifest::load(fixture_dir("good")).unwrap()
}

fn mk_linear(k: usize, n: usize, scheme: QuantScheme) -> QuantLinear {
    let packed = pack_codes(&vec![0i8; k * n], scheme.pack_bits().unwrap()).unwrap();
    let groups = scheme.group_size.map_or(1, |g| k / g);
    QuantLinear::new(k, n, packed, Tensor::ones(&[groups, n]), Tensor::zeros(&[n]))
}

/// A well-formed nt-tiny checkpoint at `scheme`, saved into a temp dir.
fn save_checkpoint(name: &str, scheme: QuantScheme) -> PathBuf {
    let cfg = tiny();
    let w = ModelWeights::random(cfg.clone(), 7);
    let mut qm = QuantizedModel::scaffold(&w, scheme).unwrap();
    for i in 0..cfg.n_layer {
        let b = w.block(i).unwrap();
        qm.blocks.push(QuantizedBlock {
            ln1_g: b.ln1_g.clone(),
            ln1_b: b.ln1_b.cloned(),
            qkv: mk_linear(cfg.d_model, 3 * cfg.d_model, scheme),
            proj: mk_linear(cfg.d_model, cfg.d_model, scheme),
            ln2_g: b.ln2_g.clone(),
            ln2_b: b.ln2_b.cloned(),
            fc1: mk_linear(cfg.d_model, cfg.d_ff, scheme),
            fc2: mk_linear(cfg.d_ff, cfg.d_model, scheme),
        });
    }
    let path = temp_dir(name).join("q.ntz");
    qm.save(&path).unwrap();
    path
}

/// Save a clean checkpoint, then mutate its raw tensor map in place.
fn corrupt_checkpoint(
    name: &str,
    scheme: QuantScheme,
    f: impl FnOnce(&mut BTreeMap<String, Tensor>),
) -> PathBuf {
    let path = save_checkpoint(name, scheme);
    let mut tensors = load_ntz(&path).unwrap();
    f(&mut tensors);
    save_ntz(&path, &tensors).unwrap();
    path
}

fn write_file(dir: &str, file: &str, body: &str) -> PathBuf {
    let path = temp_dir(dir).join(file);
    std::fs::write(&path, body).unwrap();
    path
}

const GOOD_PROFILE: &str = r#"{"model":"nt-tiny","method":"gptq","group_tag":"g64",
    "calib_source":"gen-v2","loss":"dist","candidate_bits":[2,4],
    "layers":[{"layer":0,"scores":{"2":1.0,"4":0.5}},
              {"layer":1,"scores":{"2":1.0,"4":0.5}}]}"#;

fn plan(method: &str, scheme: QuantScheme) -> PlanSpec {
    PlanSpec {
        method: method.to_string(),
        scheme,
        layer_schemes: Vec::new(),
        tweak_loss: None,
    }
}

/// Unique sorted code set of a full lint run over `ctx`.
fn code_set(ctx: &CheckContext) -> BTreeSet<&'static str> {
    run_lints(ctx).codes().into_iter().collect()
}

// ---------------------------------------------------------------- golden --

#[test]
fn good_fixture_is_clean() {
    // the everything-populated context `normtweak check --graphs` builds,
    // against entirely well-formed inputs: zero findings (deep mode on, so
    // this also pins that the good fixture's recorded signatures + HLO
    // stubs satisfy the full reconstructed dataflow contract)
    let weights = write_file("clean_weights", "weights_nt-tiny.ntz", "frozen float checkpoint");
    let hashed_profile = GOOD_PROFILE.replace(
        "\"candidate_bits\"",
        &format!("\"ckpt_hash\":\"{}\",\"candidate_bits\"", file_hex(&weights).unwrap()),
    );
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("good")),
        manifest: Some(good_manifest()),
        graphs: true,
        ckpt_path: Some(save_checkpoint("clean", w4g64())),
        model: Some(tiny()),
        model_name: Some("nt-tiny".to_string()),
        plan: Some(PlanSpec {
            method: "gptq".to_string(),
            scheme: w4g64(),
            layer_schemes: vec![(1, QuantScheme { bits: 2, group_size: Some(64) })],
            tweak_loss: Some(LossKind::Dist),
        }),
        profile_path: Some(write_file("clean_profile", "sensitivity.json", &hashed_profile)),
        target_bits: Some(2.5),
        recipe_path: Some(search_fixture("recipe_clean.json")),
        weights_path: Some(weights),
        serve: Some(ServeCheck {
            spec: Some("max_batch=8,batch_window_ms=2,deadline_ms=500".to_string()),
            models_spec: Some("w4=quantized.ntz".to_string()),
        }),
    };
    let report = run_lints(&ctx);
    assert!(report.is_empty(), "clean fixture raised: {:?}", report.codes());
    assert!(!report.should_fail(true));
}

#[test]
fn bad_manifest_fixture_matches_golden_code_set() {
    // tests/fixtures/analysis/bad/manifest.json packs seven violation
    // classes; the walk must surface all of them in one run
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad")),
        ..CheckContext::default()
    };
    let want: BTreeSet<&str> = [
        codes::MANIFEST_KEY,       // no calib_batch
        codes::MANIFEST_GROUPS,    // {"g32": 64} tag/size drift
        codes::DECODE_RECORD,      // rank-2 decode cache shape
        codes::ARENA_SLOTS,        // slots 4 < largest decode bucket 8
        codes::DECODE_BUCKET_GAP,  // decode max bucket 8 < main max 32
        codes::GRAPH_FILE_MISSING, // HLO file absent from the fixture dir
        codes::GRAPH_DUPLICATE,    // (nt-tiny, embed.b8) listed twice
    ]
    .iter()
    .copied()
    .collect();
    assert_eq!(code_set(&ctx), want);
}

#[test]
fn bad_graphs_fixture_matches_golden_code_set() {
    // tests/fixtures/analysis/bad_graphs/ seeds one violation per NT05xx
    // diagnostic (see gen_fixtures.py); the deep pass must surface every
    // one of them in a single run, plus the shallow NT0108 presence
    // warnings for the HLO files the fixture deliberately omits
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad_graphs")),
        manifest: Some(ArtifactManifest::load(fixture_dir("bad_graphs")).unwrap()),
        graphs: true,
        ..CheckContext::default()
    };
    let want: BTreeSet<&str> = [
        codes::GRAPH_FILE_MISSING, // shallow: files absent from the fixture
        codes::GRAPH_HLO_INVALID,  // garbage + empty HLO text
        codes::GRAPH_SIG_DRIFT,    // embed.b8 lowered tokens as s32[8,64]
        codes::GRAPH_QARGS,        // truncated q-args, pc scales at g64
        codes::GRAPH_DATAFLOW,     // head.b16: bucket 16 never exported
        codes::GRAPH_KV_SPEC,      // prefill caches drifted to seq 64
        codes::GRAPH_DECODE_STEP,  // block_dec pos recorded as f32
        codes::GRAPH_TWEAK_LOSS,   // tweak_step loss result f32[32]
        codes::GRAPH_SKIPPED,      // unknown family `mystery`
        codes::GRAPH_NO_OUTPUTS,   // mystery.b8 records no outputs
    ]
    .iter()
    .copied()
    .collect();
    let report = run_lints(&ctx);
    assert_eq!(report.codes().into_iter().collect::<BTreeSet<_>>(), want);
    // NT05xx contract violations are errors; the run must gate a pipeline
    assert!(report.should_fail(false));
    // every deep finding carries provenance back to a file and a field
    for d in &report.diagnostics {
        assert!(d.origin.is_some(), "finding {} has no origin", d.code);
        assert!(d.field.is_some(), "finding {} has no field", d.code);
    }
}

#[test]
fn deep_flag_off_leaves_bad_graphs_fixture_shallow() {
    // without --graphs the same fixture only raises the shallow
    // missing/empty HLO file warnings — the deep pass is strictly opt-in
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad_graphs")),
        manifest: Some(ArtifactManifest::load(fixture_dir("bad_graphs")).unwrap()),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    let seen: BTreeSet<&str> = report.codes().into_iter().collect();
    let want: BTreeSet<&str> = [codes::GRAPH_FILE_MISSING].iter().copied().collect();
    assert_eq!(seen, want, "{:?}", report.codes());
    assert_eq!(report.errors(), 0);
}

#[test]
fn bad_manifest_findings_name_field_and_fix() {
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad")),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    for d in &report.diagnostics {
        assert!(d.field.is_some(), "finding {} has no field", d.code);
        assert!(d.fix.is_some(), "finding {} has no fix", d.code);
        assert!(d.origin.is_some(), "finding {} has no origin", d.code);
    }
}

// ------------------------------------------------------------- NT01xx ----

#[test]
fn missing_manifest_dir_is_nt0101_only() {
    let ctx = CheckContext {
        manifest_dir: Some(temp_dir("no_manifest")),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::MANIFEST_UNREADABLE]);
}

#[test]
fn garbage_manifest_is_nt0102_only() {
    write_file("garbage_manifest", "manifest.json", "not json {");
    let ctx = CheckContext {
        manifest_dir: Some(temp_dir("garbage_manifest")),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::MANIFEST_PARSE]);
}

#[test]
fn empty_buckets_is_nt0104() {
    write_file(
        "empty_buckets",
        "manifest.json",
        r#"{"format":1,"calib_batch":32,"buckets":[],
            "groups":{"pc":0},"models":{},"graphs":[]}"#,
    );
    let ctx = CheckContext {
        manifest_dir: Some(temp_dir("empty_buckets")),
        ..CheckContext::default()
    };
    assert!(code_set(&ctx).contains(codes::MANIFEST_BUCKETS));
}

// ------------------------------------------------------------- NT02xx ----

#[test]
fn unreadable_checkpoint_is_nt0201() {
    let ctx = CheckContext {
        ckpt_path: Some(temp_dir("no_ckpt").join("missing.ntz")),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::CKPT_UNREADABLE]);
}

#[test]
fn corrupted_checkpoint_collects_tensor_pack_and_geometry() {
    let ckpt = corrupt_checkpoint("corrupt_tensors", w4g64(), |t| {
        t.remove("block0.ln1.g"); // NT0202 missing tensor
        t.remove("meta.bits"); // NT0202 missing meta
        // NT0203: pack width 5 has no packed storage
        t.insert("block0.attn.wqkv.pbits".to_string(), Tensor::i32(&[1], vec![5]));
        // NT0204: logical shape disagrees with the nt-tiny architecture
        t.insert("block0.attn.wproj.shape".to_string(), Tensor::i32(&[2], vec![64, 64]));
    });
    let ctx = CheckContext {
        ckpt_path: Some(ckpt),
        model: Some(tiny()),
        ..CheckContext::default()
    };
    let seen = code_set(&ctx);
    for want in [codes::CKPT_TENSOR, codes::CKPT_PACK, codes::CKPT_GEOMETRY] {
        assert!(seen.contains(want), "missing {want} in {seen:?}");
    }
}

#[test]
fn unexported_grain_checkpoint_is_nt0205() {
    // a w2/g32 checkpoint against a manifest exporting only pc + g64
    let ckpt = save_checkpoint("grain_g32", QuantScheme::w2_g32());
    let ctx = CheckContext {
        ckpt_path: Some(ckpt),
        manifest: Some(good_manifest()),
        ..CheckContext::default()
    };
    assert!(code_set(&ctx).contains(codes::CKPT_GRAIN));
}

#[test]
fn model_absent_from_manifest_is_nt0206() {
    let ckpt = save_checkpoint("model_unknown", w4g64());
    let ctx = CheckContext {
        ckpt_path: Some(ckpt),
        manifest: Some(good_manifest()),
        model: Some(ModelConfig::builtin("nt-small").unwrap()),
        ..CheckContext::default()
    };
    assert!(code_set(&ctx).contains(codes::MODEL_UNKNOWN));
}

#[test]
fn registry_vs_manifest_drift_is_nt0207() {
    let ckpt = save_checkpoint("model_drift", w4g64());
    let mut cfg = tiny();
    cfg.d_model = 96; // drift from the manifest's recorded 128
    let ctx = CheckContext {
        ckpt_path: Some(ckpt),
        manifest: Some(good_manifest()),
        model: Some(cfg),
        ..CheckContext::default()
    };
    assert!(code_set(&ctx).contains(codes::MODEL_DRIFT));
}

#[test]
fn decode_cache_drift_is_nt0208() {
    // manifest records an 8-head cache; nt-tiny has 4 heads
    write_file(
        "decode_drift",
        "manifest.json",
        r#"{"format":1,"calib_batch":32,"buckets":[8],
            "groups":{"pc":0,"g64":64},
            "decode":{"buckets":[8],
                      "caches":{"nt-tiny":{"n_layer":2,"shape":[8,128,32]}}},
            "models":{"nt-tiny":{"n_layer":2,"d_model":128,"n_head":4,
                                 "d_ff":512,"vocab":2048,"seq":128,
                                 "norm":"layernorm"}},
            "graphs":[]}"#,
    );
    let manifest = ArtifactManifest::load(temp_dir("decode_drift")).unwrap();
    let ctx = CheckContext {
        ckpt_path: Some(save_checkpoint("decode_drift_ckpt", w4g64())),
        manifest: Some(manifest),
        model: Some(tiny()),
        ..CheckContext::default()
    };
    assert!(code_set(&ctx).contains(codes::DECODE_CACHE_DRIFT));
}

// ------------------------------------------------------------- NT03xx ----

#[test]
fn plan_violations_are_all_collected() {
    let mut p = plan("nope", QuantScheme::w2_g64());
    p.layer_schemes = vec![
        (0, QuantScheme { bits: 8, group_size: Some(64) }),
        (0, QuantScheme { bits: 5, group_size: Some(64) }), // dup + bad width
        (1, QuantScheme { bits: 4, group_size: None }),     // grain drift
        (9, QuantScheme { bits: 4, group_size: Some(64) }), // out of range
    ];
    let ctx = CheckContext {
        plan: Some(p),
        model: Some(tiny()),
        ..CheckContext::default()
    };
    let want: BTreeSet<&str> = [
        codes::BAD_METHOD,
        codes::DUP_LAYER_BITS,
        codes::BAD_PACK_WIDTH,
        codes::GRAIN_OVERRIDE,
        codes::LAYER_RANGE,
    ]
    .iter()
    .copied()
    .collect();
    assert_eq!(code_set(&ctx), want);
}

#[test]
fn unexported_plan_grain_is_nt0308_and_suppresses_nt0309() {
    let mut p = plan("gptq", QuantScheme::w2_g32());
    p.tweak_loss = Some(LossKind::Dist);
    let ctx = CheckContext {
        plan: Some(p),
        manifest: Some(good_manifest()),
        model_name: Some("nt-tiny".to_string()),
        ..CheckContext::default()
    };
    // one finding, not two: the tweak graph can't exist at an unexported
    // grain, so only the grain itself is reported
    assert_eq!(run_lints(&ctx).codes(), vec![codes::GRAIN_UNEXPORTED]);
}

#[test]
fn missing_tweak_graph_is_nt0309() {
    // grain g64 is exported, but only the Dist tweak_step graph is — an
    // Mse-loss run has no nt-tiny.tweak_step_mse.g64
    let mut p = plan("gptq", w4g64());
    p.tweak_loss = Some(LossKind::Mse);
    let ctx = CheckContext {
        plan: Some(p),
        manifest: Some(good_manifest()),
        model_name: Some("nt-tiny".to_string()),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::TWEAK_GRAPH]);
}

#[test]
fn profile_provenance_mismatch_is_nt0307() {
    let body = GOOD_PROFILE.replace("\"model\":\"nt-tiny\"", "\"model\":\"nt-small\"");
    let ctx = CheckContext {
        profile_path: Some(write_file("profile_wrong_model", "sensitivity.json", &body)),
        model: Some(tiny()),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::PROFILE_MISMATCH]);
}

#[test]
fn infeasible_target_bits_is_nt0306() {
    let ctx = CheckContext {
        profile_path: Some(write_file("profile_budget", "sensitivity.json", GOOD_PROFILE)),
        target_bits: Some(1.5), // below the smallest candidate (2)
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::INFEASIBLE_BUDGET]);
}

#[test]
fn inconsistent_profile_is_nt0310() {
    // duplicate layer 0 and a missing 4-bit score on layer 1
    let body = r#"{"model":"nt-tiny","method":"gptq","group_tag":"g64",
        "calib_source":"gen-v2","loss":"dist","candidate_bits":[2,4],
        "layers":[{"layer":0,"scores":{"2":1.0,"4":0.5}},
                  {"layer":0,"scores":{"2":1.0,"4":0.5}},
                  {"layer":1,"scores":{"2":1.0}}]}"#;
    let ctx = CheckContext {
        profile_path: Some(write_file("profile_inconsistent", "sensitivity.json", body)),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    let want: BTreeSet<&str> = [codes::PROFILE_INVALID].iter().copied().collect();
    assert_eq!(code_set(&ctx), want);
    assert_eq!(report.errors(), 2, "{:?}", report.diagnostics);
}

#[test]
fn stale_profile_checkpoint_hash_is_nt0311() {
    // profile recorded one checkpoint hash; the weights file now holds
    // different bytes — every score in the profile is stale
    let weights = write_file("stale_weights", "weights_nt-tiny.ntz", "re-exported bytes");
    let body = GOOD_PROFILE.replace(
        "\"candidate_bits\"",
        "\"ckpt_hash\":\"0000000000000000\",\"candidate_bits\"",
    );
    let profile = write_file("stale_profile", "sensitivity.json", &body);
    let ctx = CheckContext {
        profile_path: Some(profile.clone()),
        weights_path: Some(weights),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::PROFILE_STALE]);
    // without a weights path there is nothing to compare against: silent
    let ctx = CheckContext { profile_path: Some(profile), ..CheckContext::default() };
    assert!(run_lints(&ctx).is_empty());
}

// ------------------------------------------------------------- NT04xx ----

#[test]
fn serve_tuning_violations_are_all_collected() {
    let ctx = CheckContext {
        manifest: Some(good_manifest()),
        serve: Some(ServeCheck {
            // zero batch + zero window + unknown key in one spec; the
            // models entry is missing its `=`
            spec: Some("max_batch=0,batch_window_ms=0,bogus=1".to_string()),
            models_spec: Some("missing-equals.ntz".to_string()),
        }),
        ..CheckContext::default()
    };
    let want: BTreeSet<&str> = [
        codes::ZERO_MAX_BATCH,
        codes::ZERO_BATCH_WINDOW,
        codes::BAD_SERVE_SPEC, // both the bogus key and the bad models entry
    ]
    .iter()
    .copied()
    .collect();
    assert_eq!(code_set(&ctx), want);
}

#[test]
fn serve_warnings_are_nt0403_and_nt0404() {
    let ctx = CheckContext {
        manifest: Some(good_manifest()),
        serve: Some(ServeCheck {
            // 64 > largest exported bucket (32); deadline 1ms < window 2ms
            spec: Some("max_batch=64,batch_window_ms=2,deadline_ms=1".to_string()),
            models_spec: None,
        }),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    let want: BTreeSet<&str> =
        [codes::BATCH_OVER_BUCKET, codes::DEADLINE_WINDOW].iter().copied().collect();
    assert_eq!(report.codes().into_iter().collect::<BTreeSet<_>>(), want);
    // both are warnings: fail only under --deny-warnings
    assert_eq!(report.errors(), 0);
    assert_eq!(report.warnings(), 2);
    assert!(!report.should_fail(false));
    assert!(report.should_fail(true));
}

// ------------------------------------------------------------- NT06xx ----

/// The replay context `quantize --recipe` preflights with.
fn recipe_ctx(fixture: &str) -> CheckContext {
    CheckContext {
        recipe_path: Some(search_fixture(fixture)),
        manifest: Some(good_manifest()),
        model: Some(tiny()),
        model_name: Some("nt-tiny".to_string()),
        ..CheckContext::default()
    }
}

#[test]
fn clean_recipe_fixture_is_clean() {
    let report = run_lints(&recipe_ctx("recipe_clean.json"));
    assert!(report.is_empty(), "clean recipe raised: {:?}", report.codes());
    // and with nothing but the recipe, the relative profile path still
    // resolves next to the recipe file: no spurious NT0605
    let ctx = CheckContext {
        recipe_path: Some(search_fixture("recipe_clean.json")),
        ..CheckContext::default()
    };
    assert!(run_lints(&ctx).is_empty());
}

#[test]
fn bad_recipe_fixture_matches_golden_code_set() {
    // recipe_bad.json: searched for nt-small at grain g32 (never exported)
    // from a profile whose recorded hash no longer matches the file; the
    // tweak-graph check is suppressed — the grain itself is the finding
    let want: BTreeSet<&str> = [
        codes::RECIPE_GRAIN,         // g32 not in the manifest's grain table
        codes::RECIPE_MODEL,         // searched for nt-small, checking nt-tiny
        codes::RECIPE_PROFILE_STALE, // recorded profile hash drifted
    ]
    .iter()
    .copied()
    .collect();
    assert_eq!(code_set(&recipe_ctx("recipe_bad.json")), want);
}

#[test]
fn missing_tweak_graph_recipe_is_nt0604() {
    // g64 is exported, but only the Dist tweak_step graph is — an
    // mse-loss recipe has no nt-tiny.tweak_step_mse.g64 to replay with
    assert_eq!(
        run_lints(&recipe_ctx("recipe_bad_tweak.json")).codes(),
        vec![codes::RECIPE_TWEAK_GRAPH]
    );
}

#[test]
fn garbage_recipe_fixture_is_nt0601() {
    let ctx = CheckContext {
        recipe_path: Some(search_fixture("recipe_garbage.json")),
        ..CheckContext::default()
    };
    assert_eq!(run_lints(&ctx).codes(), vec![codes::RECIPE_INVALID]);
}

// ------------------------------------------------------- meta-contracts --

/// Every stable code fires somewhere in this corpus — running all the
/// scenario contexts above must cover `codes::ALL` exactly.
#[test]
fn corpus_covers_every_stable_code() {
    let mut fired: BTreeSet<&'static str> = BTreeSet::new();

    // NT0101/NT0102/NT0104 + the bad fixture's seven
    fired.extend(code_set(&CheckContext {
        manifest_dir: Some(fixture_dir("bad")),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        manifest_dir: Some(temp_dir("cov_no_manifest")),
        ..CheckContext::default()
    }));
    write_file("cov_garbage", "manifest.json", "{");
    fired.extend(code_set(&CheckContext {
        manifest_dir: Some(temp_dir("cov_garbage")),
        ..CheckContext::default()
    }));
    write_file(
        "cov_buckets",
        "manifest.json",
        r#"{"format":1,"calib_batch":32,"buckets":[],
            "groups":{"pc":0},"models":{},"graphs":[]}"#,
    );
    fired.extend(code_set(&CheckContext {
        manifest_dir: Some(temp_dir("cov_buckets")),
        ..CheckContext::default()
    }));

    // NT02xx
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(temp_dir("cov_no_ckpt").join("missing.ntz")),
        ..CheckContext::default()
    }));
    let corrupted = corrupt_checkpoint("cov_corrupt", w4g64(), |t| {
        t.remove("block0.ln1.g");
        t.insert("block0.attn.wqkv.pbits".to_string(), Tensor::i32(&[1], vec![5]));
        t.insert("block0.attn.wproj.shape".to_string(), Tensor::i32(&[2], vec![64, 64]));
    });
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(corrupted),
        model: Some(tiny()),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(save_checkpoint("cov_grain", QuantScheme::w2_g32())),
        manifest: Some(good_manifest()),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(save_checkpoint("cov_unknown", w4g64())),
        manifest: Some(good_manifest()),
        model: Some(ModelConfig::builtin("nt-small").unwrap()),
        ..CheckContext::default()
    }));
    let mut drifted = tiny();
    drifted.d_model = 96;
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(save_checkpoint("cov_drift", w4g64())),
        manifest: Some(good_manifest()),
        model: Some(drifted),
        ..CheckContext::default()
    }));
    write_file(
        "cov_decode",
        "manifest.json",
        r#"{"format":1,"calib_batch":32,"buckets":[8],
            "groups":{"pc":0,"g64":64},
            "decode":{"buckets":[8],
                      "caches":{"nt-tiny":{"n_layer":2,"shape":[8,128,32]}}},
            "models":{"nt-tiny":{"n_layer":2,"d_model":128,"n_head":4,
                                 "d_ff":512,"vocab":2048,"seq":128,
                                 "norm":"layernorm"}},
            "graphs":[]}"#,
    );
    fired.extend(code_set(&CheckContext {
        ckpt_path: Some(save_checkpoint("cov_decode_ckpt", w4g64())),
        manifest: Some(ArtifactManifest::load(temp_dir("cov_decode")).unwrap()),
        model: Some(tiny()),
        ..CheckContext::default()
    }));

    // NT03xx
    let mut bad_plan = plan("nope", QuantScheme::w2_g64());
    bad_plan.layer_schemes = vec![
        (0, QuantScheme { bits: 8, group_size: Some(64) }),
        (0, QuantScheme { bits: 5, group_size: Some(64) }),
        (1, QuantScheme { bits: 4, group_size: None }),
        (9, QuantScheme { bits: 4, group_size: Some(64) }),
    ];
    fired.extend(code_set(&CheckContext {
        plan: Some(bad_plan),
        model: Some(tiny()),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        plan: Some(plan("gptq", QuantScheme::w2_g32())),
        manifest: Some(good_manifest()),
        ..CheckContext::default()
    }));
    let mut mse_plan = plan("gptq", w4g64());
    mse_plan.tweak_loss = Some(LossKind::Mse);
    fired.extend(code_set(&CheckContext {
        plan: Some(mse_plan),
        manifest: Some(good_manifest()),
        model_name: Some("nt-tiny".to_string()),
        ..CheckContext::default()
    }));
    let wrong_model = GOOD_PROFILE.replace("\"model\":\"nt-tiny\"", "\"model\":\"nt-small\"");
    fired.extend(code_set(&CheckContext {
        profile_path: Some(write_file("cov_profile_model", "sensitivity.json", &wrong_model)),
        model: Some(tiny()),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        profile_path: Some(write_file("cov_budget", "sensitivity.json", GOOD_PROFILE)),
        target_bits: Some(1.5),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        profile_path: Some(temp_dir("cov_no_profile").join("missing.json")),
        ..CheckContext::default()
    }));
    let stale = GOOD_PROFILE.replace(
        "\"candidate_bits\"",
        "\"ckpt_hash\":\"0000000000000000\",\"candidate_bits\"",
    );
    fired.extend(code_set(&CheckContext {
        profile_path: Some(write_file("cov_stale", "sensitivity.json", &stale)),
        weights_path: Some(write_file("cov_stale_w", "weights_nt-tiny.ntz", "drifted")),
        ..CheckContext::default()
    }));

    // NT06xx — the seeded bad-recipe fixtures
    fired.extend(code_set(&CheckContext {
        recipe_path: Some(search_fixture("recipe_garbage.json")),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&recipe_ctx("recipe_bad.json")));
    fired.extend(code_set(&recipe_ctx("recipe_bad_tweak.json")));

    // NT05xx — the deep graph pass over the seeded-violation fixture
    fired.extend(code_set(&CheckContext {
        manifest_dir: Some(fixture_dir("bad_graphs")),
        manifest: Some(ArtifactManifest::load(fixture_dir("bad_graphs")).unwrap()),
        graphs: true,
        ..CheckContext::default()
    }));

    // NT04xx
    fired.extend(code_set(&CheckContext {
        manifest: Some(good_manifest()),
        serve: Some(ServeCheck {
            spec: Some("max_batch=0,batch_window_ms=0,bogus=1".to_string()),
            models_spec: None,
        }),
        ..CheckContext::default()
    }));
    fired.extend(code_set(&CheckContext {
        manifest: Some(good_manifest()),
        serve: Some(ServeCheck {
            spec: Some("max_batch=64,batch_window_ms=2,deadline_ms=1".to_string()),
            models_spec: None,
        }),
        ..CheckContext::default()
    }));

    let all: BTreeSet<&'static str> = codes::ALL.iter().map(|(c, _)| *c).collect();
    let missing: Vec<_> = all.difference(&fired).collect();
    assert!(missing.is_empty(), "codes never fired on the corpus: {missing:?}");
    let unknown: Vec<_> = fired.difference(&all).collect();
    assert!(unknown.is_empty(), "codes fired but not in codes::ALL: {unknown:?}");
}

/// Every stable code is documented in the `analysis` module rustdoc table.
#[test]
fn every_code_is_documented() {
    let docs = include_str!("../src/analysis/mod.rs");
    for (code, summary) in codes::ALL {
        assert!(docs.contains(code), "{code} missing from analysis/mod.rs rustdoc");
        assert!(!summary.is_empty(), "{code} has an empty summary");
    }
}

/// `check --format json` output parses back through `util::json` to an
/// identical tree, and carries the codes machine-readably.
#[test]
fn report_json_round_trips() {
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad")),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    let tree = report.to_json();
    let text = tree.emit();
    let back = Json::parse(&text).unwrap();
    assert_eq!(back, tree, "JSON emit/parse round-trip drifted");

    let diags = back.get("diagnostics").and_then(|d| d.as_arr()).unwrap();
    assert_eq!(diags.len(), report.diagnostics.len());
    for (d, json) in report.diagnostics.iter().zip(diags) {
        let code = json.get("code").and_then(|c| c.as_str()).unwrap();
        assert_eq!(code, d.code);
    }
}

/// The human renderer names every code and ends with a severity summary.
#[test]
fn human_render_names_every_code() {
    let ctx = CheckContext {
        manifest_dir: Some(fixture_dir("bad")),
        ..CheckContext::default()
    };
    let report = run_lints(&ctx);
    let text = report.render_human();
    for code in report.codes() {
        assert!(text.contains(code), "{code} missing from human rendering");
    }
    assert!(text.contains("error"), "no severity summary in:\n{text}");
}

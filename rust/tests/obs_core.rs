//! Core observability invariants: histogram percentiles against a
//! brute-force oracle, deterministic span nesting under [`TestClock`],
//! ring-buffer overflow accounting, snapshot JSON round-trips, and the
//! repo-wide ban on stray `println!` / `eprintln!` diagnostics.

use normtweak::obs::trace::{TestClock, TraceCollector};
use normtweak::obs::{bucket_high, bucket_index, Hist, MetricsRegistry, MetricsSnapshot};
use normtweak::util::json::{self, Json};

/// SplitMix64 — deterministic pseudo-random stream for the oracle test.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[test]
fn percentile_tracks_brute_force_oracle() {
    // mixed magnitudes: exercise exact small buckets and wide log buckets
    let mut state = 0xfeed_f00du64;
    let mut values: Vec<u64> = (0..1000)
        .map(|i| {
            let r = splitmix64(&mut state);
            match i % 3 {
                0 => r % 16,          // small: exact buckets
                1 => r % 10_000,      // mid-range latencies
                _ => r % 50_000_000,  // long tail
            }
        })
        .collect();
    let mut h = Hist::new();
    for &v in &values {
        h.record(v);
    }
    values.sort_unstable();

    for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
        let rank = ((p / 100.0) * values.len() as f64).ceil() as usize;
        let oracle = values[rank.clamp(1, values.len()) - 1];
        let est = h.percentile(p);
        // never overestimates, and the true order statistic sits within
        // the reported value's own bucket (≤ 25% relative error)
        assert!(est <= oracle, "p{p}: est {est} > oracle {oracle}");
        assert!(
            oracle < bucket_high(bucket_index(est)) || est == h.max(),
            "p{p}: oracle {oracle} outside est {est}'s bucket"
        );
    }
    // boundary exactness
    assert_eq!(h.percentile(100.0), *values.last().unwrap());
    assert_eq!(h.min(), values[0]);
}

#[test]
fn spans_nest_deterministically_under_test_clock() {
    let tc = TraceCollector::with_clock(64, Box::new(TestClock::new(1)));
    let tid = tc.track("t");
    {
        let _outer = tc.span(tid, "outer"); // start 0
        {
            let _inner = tc.span(tid, "inner"); // start 1, ends 2
        }
    } // outer ends 3

    let evs = tc.snapshot();
    // collection order: inner dropped first
    assert_eq!(evs[0].name, "inner");
    assert_eq!(evs[1].name, "outer");
    let (inner, outer) = (&evs[0], &evs[1]);
    assert_eq!((outer.ts, outer.dur), (0, 3));
    assert_eq!((inner.ts, inner.dur), (1, 1));
    // strict containment: the property trace_validate checks per track
    assert!(outer.ts <= inner.ts && inner.ts + inner.dur <= outer.ts + outer.dur);

    // export order: sorted by start time, so the parent precedes the child
    let chrome = tc.export_chrome(None);
    let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
    let names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .map(|e| e.get("name").and_then(Json::as_str).unwrap())
        .collect();
    assert_eq!(names, ["outer", "inner"]);
}

#[test]
fn ring_overflow_drops_oldest_and_counts() {
    let tc = TraceCollector::with_clock(8, Box::new(TestClock::new(1)));
    let tid = tc.track("t");
    for i in 0..12 {
        tc.instant(tid, &format!("i{i}"), vec![]);
    }
    assert_eq!(tc.len(), 8);
    assert_eq!(tc.dropped(), 4);
    // survivors are the newest 8, oldest first
    let evs = tc.snapshot();
    assert_eq!(evs[0].name, "i4");
    assert_eq!(evs[7].name, "i11");
    // the export reports the loss so a truncated trace is never mistaken
    // for a complete one
    let chrome = tc.export_chrome(None);
    let dropped = chrome
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(Json::as_f64);
    assert_eq!(dropped, Some(4.0));
}

#[test]
fn chrome_export_covers_every_phase() {
    let tc = TraceCollector::with_clock(64, Box::new(TestClock::new(1)));
    let tid = tc.track("work");
    tc.complete_at(tid, "job", 0, 5, vec![("k", json::s("v"))]);
    tc.instant(tid, "mark", vec![]);
    tc.counter("loss", "loss", 0.25);
    let id = tc.next_async_id();
    tc.async_begin(tid, "req", id, vec![]);
    tc.async_end(tid, "req", id);

    let chrome = tc.export_chrome(None);
    let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
    // thread_name metadata first, then the five events
    assert_eq!(events.len(), 6);
    let meta = &events[0];
    assert_eq!(meta.get("ph").and_then(Json::as_str), Some("M"));
    assert_eq!(
        meta.get("args").and_then(|a| a.get("name")).and_then(Json::as_str),
        Some("work")
    );
    let phase_of = |i: usize| events[i].get("ph").and_then(Json::as_str).unwrap();
    let phases: Vec<&str> = (1..6).map(phase_of).collect();
    assert_eq!(phases, ["X", "i", "C", "b", "e"]);
    // X carries dur; instants are scoped; async pairs share a hex id
    assert_eq!(events[1].get("dur").and_then(Json::as_f64), Some(5.0));
    assert_eq!(events[2].get("s").and_then(Json::as_str), Some("t"));
    let b_id = events[4].get("id").and_then(Json::as_str).unwrap();
    assert!(b_id.starts_with("0x"), "async id not hex: {b_id}");
    assert_eq!(events[5].get("id").and_then(Json::as_str), Some(b_id));
    // the whole document survives a parse round-trip
    let reparsed = Json::parse(&chrome.emit()).unwrap();
    assert_eq!(
        reparsed.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len),
        Some(6)
    );
}

#[test]
fn metrics_snapshot_round_trips_through_json() {
    let reg = MetricsRegistry::new();
    reg.counter("xla.executions").add(42);
    reg.gauge("engine.bench.queue_depth").set(-7);
    let h = reg.histogram("xla.exec_us.block_fwd_q");
    for v in [3u64, 17, 170, 1_700, 17_000] {
        h.record(v);
    }
    let snap = reg.snapshot();
    let text = snap.to_json().emit();
    let back = MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, snap);
    // percentiles survive the round trip, not just the counts
    let rt = &back.hists["xla.exec_us.block_fwd_q"];
    assert_eq!(rt.percentile(50.0), snap.hists["xla.exec_us.block_fwd_q"].percentile(50.0));
    assert_eq!(rt.max(), 17_000);
}

/// Every diagnostic must route through the leveled logger: `eprintln!` is
/// allowed only inside the logger's own sink, `println!` only in the CLI
/// and checked-in bins (stdout there is intentional machine/product
/// output).  Keeps `--format json` pipelines and bench stdout byte-clean.
#[test]
fn no_stray_print_diagnostics_in_src() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut offenders = Vec::new();
    scan_dir(&src, &mut offenders);
    assert!(
        offenders.is_empty(),
        "stray print diagnostics (route through obs::log macros):\n{}",
        offenders.join("\n")
    );
}

fn scan_dir(dir: &std::path::Path, offenders: &mut Vec<String>) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.is_dir() {
            scan_dir(&path, offenders);
            continue;
        }
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        let rel = path.to_string_lossy().replace('\\', "/");
        let in_logger = rel.ends_with("obs/log.rs");
        let stdout_ok = rel.ends_with("main.rs") || rel.contains("/bin/");
        let text = std::fs::read_to_string(&path).unwrap();
        for (n, line) in text.lines().enumerate() {
            let t = line.trim_start();
            if t.starts_with("//") {
                continue; // comments and docs may mention the macros
            }
            if t.contains("eprintln!") && !in_logger {
                offenders.push(format!("{rel}:{}: eprintln!", n + 1));
            }
            if t.contains("println!") && !t.contains("eprintln!") && !stdout_ok {
                offenders.push(format!("{rel}:{}: println!", n + 1));
            }
        }
    }
}

//! Offline end-to-end pins for the recipe search subsystem: deterministic
//! enumeration, budget monotonicity, kill/resume equivalence, and the
//! recipe artifact's round-trip + replay guarantees — all against the
//! committed fixtures in `tests/fixtures/search/` so the same inputs CI's
//! `search-smoke` job drives through the CLI are exercised through the
//! library API here.

use std::path::PathBuf;

use normtweak::model::{ModelConfig, ModelWeights};
use normtweak::policy::SensitivityProfile;
use normtweak::search::{
    default_tweak_grid, CandidateStatus, Recipe, RecipeProvenance, SearchConfig, SearchOutcome,
    SearchRunner, SpaceConfig,
};
use normtweak::tweak::TweakConfig;
use normtweak::util::hash::file_hex;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/search")
        .join(name)
}

fn profile() -> SensitivityProfile {
    SensitivityProfile::load(fixture("sensitivity.json")).unwrap()
}

fn weights() -> ModelWeights {
    ModelWeights::random(ModelConfig::builtin("nt-tiny").unwrap(), 42)
}

/// The space the CI smoke searches: both methods, one profiled grain plus
/// one that stage 0 must prune, the default tweak grid.
fn space() -> SpaceConfig {
    SpaceConfig {
        methods: vec!["rtn".into(), "gptq".into()],
        grains: vec!["g64".into(), "pc".into()],
        tweak_grid: default_tweak_grid(TweakConfig::default()),
        target_bits: 3.0,
    }
}

fn run(budget: usize) -> SearchOutcome {
    let p = profile();
    let w = weights();
    SearchRunner::new(&p, &w, SearchConfig { space: space(), budget, seed: 7 })
        .run()
        .unwrap()
        .unwrap()
}

/// Build the recipe exactly the way `normtweak search` does: base scheme
/// at the plan's smallest allocated width, provenance pinned to the
/// fixture profile's content hash.
fn recipe_from(out: &SearchOutcome, budget: usize) -> Recipe {
    let min_bits = out.plan.schemes.values().map(|s| s.bits).min().unwrap();
    Recipe {
        model: "nt-tiny".into(),
        method: out.winner.method.clone(),
        scheme: out.winner.scheme(min_bits).unwrap(),
        tweak: out.winner.tweak,
        plan: out.plan.clone(),
        provenance: RecipeProvenance {
            manifest_hash: None,
            profile_path: "sensitivity.json".into(),
            profile_hash: file_hex(fixture("sensitivity.json")).unwrap(),
            space: space(),
            seed: 7,
            budget,
            stats: out.stats,
        },
        frontier: out.frontier.clone(),
    }
}

#[test]
fn enumeration_order_is_deterministic() {
    let a = space().enumerate();
    let b = space().enumerate();
    assert_eq!(a, b);
    assert_eq!(a.len(), 16); // 2 methods × 2 grains × 4 tweak points
    for (i, c) in a.iter().enumerate() {
        assert_eq!(c.id, i, "ids must be dense in declaration order");
    }
    assert_eq!((a[0].method.as_str(), a[0].grain.as_str()), ("rtn", "g64"));
    // and the whole staged run is reproducible, not just the enumeration
    assert_eq!(run(2), run(2));
}

#[test]
fn raising_the_budget_escalates_a_superset() {
    // pruning monotonicity: a candidate surviving to stage 1 at budget N
    // must survive at every budget > N (group ranking ties break on id)
    let mut prev: Vec<usize> = Vec::new();
    for budget in 1..=3 {
        let out = run(budget);
        let ids: Vec<usize> = out
            .frontier
            .iter()
            .filter(|e| {
                matches!(e.status, CandidateStatus::Escalated | CandidateStatus::Scored)
            })
            .map(|e| e.candidate.id)
            .collect();
        for id in &prev {
            assert!(ids.contains(id), "budget {budget} dropped survivor {id}");
        }
        assert!(ids.len() >= prev.len());
        prev = ids;
    }
    // the `pc` grain is never measured by the fixture profile, so it is
    // pruned at every budget — monotonicity never resurrects it
    let out = run(3);
    for e in &out.frontier {
        if e.candidate.grain == "pc" {
            assert_eq!(e.status, CandidateStatus::Pruned);
        }
    }
}

#[test]
fn resume_after_interrupt_reaches_the_same_winner() {
    let p = profile();
    let w = weights();
    let dir = std::env::temp_dir().join("nt_search_recipes_test");
    std::fs::create_dir_all(&dir).unwrap();
    let state = dir.join("resume.state.json");
    let _ = std::fs::remove_file(&state);
    let cfg = SearchConfig { space: space(), budget: 2, seed: 7 };

    // killed after the first fresh escalation: checkpoint holds the trial
    let interrupted = SearchRunner::new(&p, &w, cfg.clone())
        .with_state_path(&state)
        .with_max_escalations(1)
        .run()
        .unwrap();
    assert!(interrupted.is_none(), "cap should abort before finishing");

    let resumed = SearchRunner::new(&p, &w, cfg.clone())
        .with_state_path(&state)
        .run()
        .unwrap()
        .unwrap();
    let straight = SearchRunner::new(&p, &w, cfg).run().unwrap().unwrap();
    assert_eq!(resumed, straight);
    let _ = std::fs::remove_file(&state);
}

#[test]
fn recipe_round_trip_replays_the_same_pipeline_config() {
    let out = run(2);
    let recipe = recipe_from(&out, 2);
    let dir = std::env::temp_dir().join("nt_search_recipes_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip_recipe.json");
    recipe.save(&path).unwrap();
    let back = Recipe::load(&path).unwrap();
    assert_eq!(back, recipe);

    // replay builds the identical PipelineConfig, field for field
    let a = recipe.to_pipeline_config().unwrap();
    let b = back.to_pipeline_config().unwrap();
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
    // and the per-layer scheme map the replay runs is exactly the plan
    // the search chose
    for (&layer, &scheme) in &out.plan.schemes {
        assert_eq!(b.scheme_for(layer), scheme);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn committed_clean_fixture_stays_in_sync() {
    let r = Recipe::load(fixture("recipe_clean.json")).unwrap();
    assert_eq!(r.model, "nt-tiny");
    assert_eq!(r.group_tag(), "g64");
    // the recorded hash matches the sibling profile's on-disk bytes, so
    // the NT0605 staleness lint keeps accepting the fixture pair
    assert_eq!(
        r.provenance.profile_hash,
        file_hex(fixture("sensitivity.json")).unwrap()
    );
    let cfg = r.to_pipeline_config().unwrap();
    cfg.validate(2).unwrap();
    let map = r.layer_map_json();
    assert_eq!(map.get("layers").and_then(|v| v.as_obj()).unwrap().len(), 2);
}

//! Registry-driven parity suite for the open `Quantizer` plugin API.
//!
//! Runs entirely offline: a `LayerContext` with precomputed (static) taps,
//! CPU Gram matrices for Hessians, no PJRT artifacts. Two invariants for
//! every registered plugin (plus composed specs):
//!
//! 1. **Reconstruction parity** — the plugin's dequantized weights are no
//!    worse than plain RTN applied to the same effective (post-preprocess)
//!    weights, in the activation-weighted norm `tr(Eᵀ XᵀX E)` that the
//!    pipeline actually cares about.
//! 2. **Requirements honesty** — `requirements()` matches what the plugin
//!    actually consumed: no silent Hessian collection, no false claims.

use normtweak::model::BlockWeights;
use normtweak::quant::quantizer::{registry, resolve, LayerContext, Linear, QuantizerParams};
use normtweak::quant::{rtn, QuantScheme, QuantizedWeight};
use normtweak::tensor::{matmul, transpose2d, Tensor};

const D: usize = 16;
const FF: usize = 32;
const ROWS: usize = 96;

/// Owned block weights in `BlockWeights` field order.
fn fixture_weights() -> Vec<Tensor> {
    vec![
        Tensor::ones(&[D]),                    // ln1_g
        Tensor::zeros(&[D]),                   // ln1_b
        Tensor::randn(&[D, 3 * D], 21, 0.5),   // wqkv
        Tensor::zeros(&[3 * D]),               // bqkv
        Tensor::randn(&[D, D], 22, 0.5),       // wproj
        Tensor::zeros(&[D]),                   // bproj
        Tensor::ones(&[D]),                    // ln2_g
        Tensor::zeros(&[D]),                   // ln2_b
        Tensor::randn(&[D, FF], 23, 0.5),      // wfc1
        Tensor::zeros(&[FF]),                  // bfc1
        Tensor::randn(&[FF, D], 24, 0.5),      // wfc2
        Tensor::zeros(&[D]),                   // bfc2
    ]
}

fn block_view(w: &[Tensor]) -> BlockWeights<'_> {
    BlockWeights {
        ln1_g: &w[0],
        ln1_b: Some(&w[1]),
        wqkv: &w[2],
        bqkv: &w[3],
        wproj: &w[4],
        bproj: &w[5],
        ln2_g: &w[6],
        ln2_b: Some(&w[7]),
        wfc1: &w[8],
        bfc1: &w[9],
        wfc2: &w[10],
        bfc2: &w[11],
    }
}

/// Correlated activations with two outlier channels — the regime where the
/// non-trivial methods (GPTQ / AWQ / clipping) earn their keep.
fn correlated_tap(seed: u64, k: usize) -> Tensor {
    let base = Tensor::randn(&[ROWS, 1], seed, 1.0);
    let noise = Tensor::randn(&[ROWS, k], seed + 100, 0.4);
    let b = base.as_f32().unwrap();
    let nz = noise.as_f32().unwrap();
    let mut v = vec![0.0f32; ROWS * k];
    for r in 0..ROWS {
        for c in 0..k {
            v[r * k + c] = b[r] + nz[r * k + c];
        }
        v[r * k] *= 6.0;
        v[r * k + 1] *= 4.0;
    }
    Tensor::f32(&[ROWS, k], v)
}

fn fixture_taps() -> Vec<Tensor> {
    vec![
        correlated_tap(31, D),
        correlated_tap(32, D),
        correlated_tap(33, D),
        correlated_tap(34, FF),
    ]
}

/// Activation-weighted reconstruction error `tr(Eᵀ (XᵀX) E)` of a
/// quantized weight against the float weight it was asked to reproduce.
fn recon_err(x: &Tensor, w_eff: &Tensor, q: &QuantizedWeight) -> f64 {
    let k = w_eff.shape[0];
    let n = w_eff.shape[1];
    let gram = matmul(&transpose2d(x).unwrap(), x).unwrap();
    let gv = gram.as_f32().unwrap();
    let wv = w_eff.as_f32().unwrap();
    let deq = q.dequantize();
    let mut total = 0.0f64;
    for col in 0..n {
        for i in 0..k {
            let ei = (wv[i * n + col] - deq[i * n + col]) as f64;
            if ei == 0.0 {
                continue;
            }
            for j in 0..k {
                let ej = (wv[j * n + col] - deq[j * n + col]) as f64;
                total += ei * gv[i * k + j] as f64 * ej;
            }
        }
    }
    total
}

const LINEARS: [Linear; 4] = [Linear::Qkv, Linear::Proj, Linear::Fc1, Linear::Fc2];

/// Run one spec; return (per-linear plugin error, per-linear RTN-on-same-
/// weights baseline error, requirements parity info).
fn run_spec(spec: &str, scheme: QuantScheme) -> (f64, f64, bool, bool, bool, bool) {
    let params = QuantizerParams::default();
    let q = resolve(spec, &params).unwrap_or_else(|e| panic!("{spec}: {e}"));
    let weights = fixture_weights();
    let mut ctx = LayerContext::with_static_taps(block_view(&weights), fixture_taps(), scheme);
    let bq = q
        .quantize_layer(&mut ctx)
        .unwrap_or_else(|e| panic!("{spec}: {e}"));
    // capture consumption flags before the error computation touches taps
    let (taps_used, hessians_used) = (ctx.taps_used(), ctx.hessians_used());
    let req = q.requirements();

    let mut err_q = 0.0f64;
    let mut err_rtn = 0.0f64;
    for lin in LINEARS {
        let x = ctx.tap(lin).unwrap();
        let quantized = match lin {
            Linear::Qkv => &bq.qkv,
            Linear::Proj => &bq.proj,
            Linear::Fc1 => &bq.fc1,
            Linear::Fc2 => &bq.fc2,
        };
        let w_eff = ctx.weight(lin).clone();
        err_q += recon_err(&x, &w_eff, quantized);
        let baseline = rtn::quantize(&w_eff, &scheme).unwrap();
        err_rtn += recon_err(&x, &w_eff, &baseline);
    }
    (err_q, err_rtn, taps_used, hessians_used, req.act_taps, req.hessians)
}

#[test]
fn every_registered_quantizer_meets_rtn_parity() {
    let scheme = QuantScheme { bits: 2, group_size: Some(16) };
    for reg in registry() {
        let (err_q, err_rtn, ..) = run_spec(reg.name, scheme);
        assert!(
            err_q <= err_rtn * 1.10 + 1e-9,
            "{}: reconstruction error {err_q:.4} exceeds RTN baseline {err_rtn:.4}",
            reg.name
        );
    }
}

#[test]
fn requirements_match_actual_consumption() {
    let scheme = QuantScheme { bits: 2, group_size: Some(16) };
    for reg in registry() {
        let (_, _, taps_used, hessians_used, req_taps, req_hessians) =
            run_spec(reg.name, scheme);
        assert_eq!(
            taps_used, req_taps,
            "{}: requirements().act_taps = {req_taps} but consumption = {taps_used}",
            reg.name
        );
        assert_eq!(
            hessians_used, req_hessians,
            "{}: requirements().hessians = {req_hessians} but consumption = {hessians_used}",
            reg.name
        );
    }
}

#[test]
fn composed_specs_meet_parity_too() {
    let scheme = QuantScheme { bits: 2, group_size: Some(16) };
    for spec in ["smoothquant+gptq", "awq+gptq", "smoothquant+omniquant"] {
        let (err_q, err_rtn, ..) = run_spec(spec, scheme);
        assert!(
            err_q <= err_rtn * 1.10 + 1e-9,
            "{spec}: reconstruction error {err_q:.4} exceeds RTN baseline {err_rtn:.4}"
        );
    }
}

#[test]
fn gptq_strictly_improves_on_correlated_inputs() {
    // the correlated fixture is exactly GPTQ's regime: the win must be real,
    // not just parity (guards against the dispatch quietly degrading to RTN)
    let scheme = QuantScheme { bits: 2, group_size: Some(16) };
    let (err_q, err_rtn, ..) = run_spec("gptq", scheme);
    assert!(
        err_q < err_rtn * 0.98,
        "gptq {err_q:.4} should clearly beat rtn {err_rtn:.4} on correlated inputs"
    );
}

#[test]
fn preprocess_folds_norms_and_registers_scales() {
    let scheme = QuantScheme::w4_perchannel();
    let params = QuantizerParams::default();
    let q = resolve("smoothquant+gptq", &params).unwrap();
    let weights = fixture_weights();
    let mut ctx = LayerContext::with_static_taps(block_view(&weights), fixture_taps(), scheme);
    q.quantize_layer(&mut ctx).unwrap();
    // smoothing must fold 1/s into both norm-fed affines...
    assert!(ctx.input_scales(Linear::Qkv).is_some());
    assert!(ctx.input_scales(Linear::Fc1).is_some());
    assert!(ctx.input_scales(Linear::Proj).is_none());
    assert!(ctx.input_scales(Linear::Fc2).is_none());
    let s0 = ctx.input_scales(Linear::Qkv).unwrap()[0];
    let norms = ctx.into_norms();
    // ...and the outlier channel's gamma shrinks by exactly 1/s
    let g0 = norms.ln1_g.as_f32().unwrap()[0];
    assert!((g0 - 1.0 / s0).abs() < 1e-5, "gamma {g0} vs 1/s {}", 1.0 / s0);
    assert!(s0 > 1.0, "outlier channel should get s > 1, got {s0}");
}

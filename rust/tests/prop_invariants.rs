//! Property-based tests over the coordinator's invariants (hand-rolled
//! generator loops over SplitMix64 — proptest is unavailable offline; each
//! property sweeps many random cases and shrink-prints the failing seed).

mod common;

use normtweak::calib::rng::SplitMix64;
use normtweak::calib::CalibSet;
use normtweak::coordinator::pad_batch;
use normtweak::quant::gptq::{cholesky_lower, invert_lower, GptqParams, Hessian};
use normtweak::quant::{gptq, rtn, smoothquant, QuantScheme};
use normtweak::tensor::{matmul, pack_codes, transpose2d, unpack_codes, Tensor};
use normtweak::tweak::LayerLrScheduler;

const CASES: usize = 50;

fn rand_tensor(rng: &mut SplitMix64, shape: &[usize], scale: f32) -> Tensor {
    Tensor::randn(shape, rng.next_u64(), scale)
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let mut rng = SplitMix64::new(0xA11CE);
    for case in 0..CASES {
        let bits = [2u8, 4, 8][rng.below(3) as usize];
        let qmax = ((1i32 << (bits - 1)) - 1) as i64;
        let len = 1 + rng.below(300) as usize;
        let codes: Vec<i8> = (0..len)
            .map(|_| ((rng.below((2 * qmax + 1) as u64) as i64) - qmax) as i8)
            .collect();
        let packed = pack_codes(&codes, bits).unwrap();
        assert_eq!(unpack_codes(&packed), codes, "case {case} bits {bits}");
        // packed size is exactly ceil(len * bits / 8)
        assert_eq!(packed.data.len(), (len * bits as usize).div_ceil(8));
    }
}

#[test]
fn prop_rtn_error_bounded_by_half_scale() {
    let mut rng = SplitMix64::new(0xB0B);
    for case in 0..CASES {
        let k = 8 * (1 + rng.below(8)) as usize;
        let n = 4 * (1 + rng.below(8)) as usize;
        let bits = [2u8, 3, 4, 8][rng.below(4) as usize];
        let group = if rng.chance(1, 2) { None } else { Some(k) };
        let scheme = QuantScheme { bits, group_size: group };
        let w = rand_tensor(&mut rng, &[k, n], 2.0);
        let q = rtn::quantize(&w, &scheme).unwrap();
        let deq = q.dequantize();
        let wv = w.as_f32().unwrap();
        let g = scheme.group_for(k);
        for kk in 0..k {
            for col in 0..n {
                let scale = q.scales[(kk / g) * n + col];
                let err = (wv[kk * n + col] - deq[kk * n + col]).abs();
                assert!(
                    err <= scale / 2.0 + 1e-5,
                    "case {case}: err {err} > scale/2 {scale}"
                );
            }
        }
    }
}

#[test]
fn prop_gptq_identity_hessian_equals_rtn() {
    let mut rng = SplitMix64::new(0xCAFE);
    for case in 0..20 {
        let k = 8 * (1 + rng.below(4)) as usize;
        let n = 4 * (1 + rng.below(4)) as usize;
        let w = rand_tensor(&mut rng, &[k, n], 1.0);
        let scheme = QuantScheme::w4_perchannel();
        let qg = gptq::quantize(&w, &Hessian::identity(k), &scheme,
                                &GptqParams::default()).unwrap();
        let qr = rtn::quantize(&w, &scheme).unwrap();
        assert_eq!(qg.codes, qr.codes, "case {case}");
    }
}

#[test]
fn prop_cholesky_reconstructs() {
    let mut rng = SplitMix64::new(0xD1CE);
    for case in 0..20 {
        let k = 2 + rng.below(12) as usize;
        // A = B Bᵀ + k*I is symmetric positive definite
        let b = rand_tensor(&mut rng, &[k, k], 1.0);
        let bt = transpose2d(&b).unwrap();
        let mut a = matmul(&b, &bt).unwrap();
        for i in 0..k {
            a.as_f32_mut().unwrap()[i * k + i] += k as f32;
        }
        let a64: Vec<f64> = a.as_f32().unwrap().iter().map(|&x| x as f64).collect();
        let l = cholesky_lower(&a64, k).expect("PD");
        // L Lᵀ == A
        for i in 0..k {
            for j in 0..k {
                let mut s = 0.0;
                for p in 0..k {
                    s += l[i * k + p] * l[j * k + p];
                }
                assert!((s - a64[i * k + j]).abs() < 1e-3, "case {case}");
            }
        }
        // L · L⁻¹ == I
        let linv = invert_lower(&l, k);
        for i in 0..k {
            for j in 0..=i {
                let mut s = 0.0;
                for p in 0..k {
                    s += l[i * k + p] * linv[p * k + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "case {case}");
            }
        }
    }
}

#[test]
fn prop_smoothquant_transform_exact() {
    let mut rng = SplitMix64::new(0xFACE);
    for case in 0..20 {
        let k = 4 * (1 + rng.below(6)) as usize;
        let n = 4 * (1 + rng.below(6)) as usize;
        let rows = 4 + rng.below(12) as usize;
        let x = rand_tensor(&mut rng, &[rows, k], 2.0);
        let w = rand_tensor(&mut rng, &[k, n], 1.0);
        let mut st = smoothquant::ActStats::new(k);
        st.update(&x).unwrap();
        let alpha = 0.1 + 0.8 * (rng.below(100) as f32 / 100.0);
        let s = smoothquant::smoothing_factors(&w, &st, &smoothquant::SmoothParams { alpha })
            .unwrap();
        let ws = smoothquant::scale_weight(&w, &s).unwrap();
        // (x/s) @ (s*w) == x @ w
        let xv = x.as_f32().unwrap();
        let mut xs = vec![0.0f32; rows * k];
        for r in 0..rows {
            for j in 0..k {
                xs[r * k + j] = xv[r * k + j] / s[j];
            }
        }
        let y0 = matmul(&x, &w).unwrap();
        let y1 = matmul(&Tensor::f32(&[rows, k], xs), &ws).unwrap();
        let d = normtweak::tensor::max_abs_diff(&y0, &y1).unwrap();
        assert!(d < 1e-3, "case {case}: {d}");
    }
}

#[test]
fn prop_scheduler_monotone_and_bounded() {
    let mut rng = SplitMix64::new(0x5EED);
    for _ in 0..CASES {
        let lr0 = 1e-6 + (rng.below(1000) as f32) * 1e-6;
        let scale = (rng.below(300) as f32) / 100.0;
        let layers = 1 + rng.below(32) as usize;
        let s = LayerLrScheduler::new(lr0, scale, layers);
        let mut prev = 0.0;
        for i in 0..layers {
            let lr = s.lr(i);
            assert!(lr >= prev);
            assert!(lr >= lr0 && lr <= lr0 * (1.0 + scale) + 1e-12);
            prev = lr;
        }
    }
}

#[test]
fn prop_calibset_never_drops_or_duplicates() {
    let mut rng = SplitMix64::new(0xFEED);
    for _ in 0..CASES {
        let n = 1 + rng.below(16) as usize;
        let seq = 8 * (1 + rng.below(8)) as usize;
        let stream: Vec<i32> = (0..n * seq + rng.below(64) as usize)
            .map(|_| rng.below(2048) as i32)
            .collect();
        let cs = CalibSet::from_stream(&stream, n, seq, "t").unwrap();
        assert_eq!(cs.tokens.as_i32().unwrap(), &stream[..n * seq]);
        // too-short stream must error, not truncate silently
        assert!(CalibSet::from_stream(&stream[..n * seq - 1], n, seq, "t").is_err());
    }
}

#[test]
fn prop_pad_batch_preserves_rows() {
    let mut rng = SplitMix64::new(0xBEAD);
    for _ in 0..CASES {
        let b = 1 + rng.below(8) as usize;
        let bucket = b + rng.below(8) as usize;
        let cols = 1 + rng.below(16) as usize;
        let t = rand_tensor(&mut rng, &[b, cols], 1.0);
        let p = pad_batch(&t, bucket).unwrap();
        assert_eq!(p.shape, vec![bucket, cols]);
        assert_eq!(&p.as_f32().unwrap()[..b * cols], t.as_f32().unwrap());
        assert!(p.as_f32().unwrap()[b * cols..].iter().all(|&x| x == 0.0));
    }
}

#[test]
fn prop_omniquant_never_worse_than_rtn() {
    let mut rng = SplitMix64::new(0x0111);
    for case in 0..20 {
        let k = 16 * (1 + rng.below(4)) as usize;
        let n = 4 * (1 + rng.below(4)) as usize;
        let bits = [2u8, 3, 4][rng.below(3) as usize];
        let scheme = QuantScheme { bits, group_size: None };
        let w = rand_tensor(&mut rng, &[k, n], 1.5);
        let qo = normtweak::quant::omniquant::quantize(&w, &scheme).unwrap();
        let qr = rtn::quantize(&w, &scheme).unwrap();
        let mse = |q: &normtweak::quant::QuantizedWeight| -> f64 {
            let deq = q.dequantize();
            w.as_f32().unwrap().iter().zip(&deq)
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(mse(&qo) <= mse(&qr) + 1e-9, "case {case}");
    }
}

//! Shared helpers for integration tests: artifact discovery + graceful skip
//! when `make artifacts` has not run yet.

use normtweak::model::ModelWeights;
use normtweak::runtime::Runtime;

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load the runtime, or None (with a notice) when artifacts are absent —
/// integration tests become no-ops instead of failures pre-`make artifacts`.
pub fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] no artifacts at {} — run `make artifacts`", dir.display());
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

/// Load a trained model's weights, or skip if the checkpoint is missing.
pub fn weights_or_skip(name: &str) -> Option<ModelWeights> {
    let dir = artifacts_dir();
    if !dir.join(format!("weights_{name}.ntz")).exists() {
        eprintln!("[skip] no weights for {name} — run `make artifacts`");
        return None;
    }
    Some(ModelWeights::load_from_dir(name, dir).expect("weights"))
}

//! Crate-wide error and result types.

use thiserror::Error;

/// All errors surfaced by the normtweak library.
#[derive(Error, Debug)]
pub enum Error {
    /// Wrapper around errors from the `xla` PJRT crate.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// I/O failure (artifact files, checkpoints, corpora).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON (manifest / report) parse or encode failure.
    #[error("json error: {0}")]
    Json(String),

    /// TOML config parse failure.
    #[error("config error: {0}")]
    Config(String),

    /// Shape mismatch in tensor operations.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Bad or unsupported quantization configuration.
    #[error("quantization error: {0}")]
    Quant(String),

    /// A required AOT artifact is missing or inconsistent with the manifest.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Numerical failure (e.g. Cholesky of a non-PD Hessian).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Evaluation harness failure.
    #[error("eval error: {0}")]
    Eval(String),

    /// Serving-loop failure.
    #[error("serve error: {0}")]
    Serve(String),

    /// Checkpoint format failure.
    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    /// Anything else.
    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Convenience constructor for ad-hoc errors.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

//! Experiment reporting: ASCII tables matching the paper's layout + JSON
//! records appended to `artifacts/experiments/`.

pub mod repro;

use std::path::Path;

use crate::error::Result;
use crate::util::json::Json;

/// A printable table (rows of strings, first row = header).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        self.rows.push(row);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out += &format!("| {} |\n", self.header.join(" | "));
        out += &format!("|{}|\n", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            out += &format!("| {} |\n", r.join(" | "));
        }
        out
    }

    /// Render with aligned columns for terminal output.
    pub fn ascii(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out += &fmt_row(&self.header);
        out += "\n";
        out += &"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1));
        out += "\n";
        for r in &self.rows {
            out += &fmt_row(r);
            out += "\n";
        }
        out
    }
}

/// Persist a JSON experiment record under `artifacts/experiments/`.
pub fn save_record(dir: impl AsRef<Path>, name: &str, record: &Json) -> Result<()> {
    let dir = dir.as_ref().join("experiments");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(path, record.emit())?;
    Ok(())
}

impl Table {
    /// JSON form of the table (for experiment records).
    pub fn to_json(&self) -> Json {
        use crate::util::json::{arr, obj, s};
        obj(vec![
            ("title", s(self.title.clone())),
            ("header", arr(self.header.iter().map(|h| s(h.clone())).collect())),
            ("rows", arr(self
                .rows
                .iter()
                .map(|r| arr(r.iter().map(|c| s(c.clone())).collect()))
                .collect())),
        ])
    }
}

/// Format a float like the paper's tables (4 decimals).
pub fn f4(x: f32) -> String {
    format!("{x:.4}")
}

/// Format a float with 2 decimals.
pub fn f2(x: f32) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_ascii_render() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.push(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2 |"));
        let a = t.ascii();
        assert!(a.contains("Demo"));
    }

    #[test]
    fn record_saves_json() {
        use crate::util::json::{n, obj};
        let dir = std::env::temp_dir().join("nt_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        save_record(&dir, "t", &obj(vec![("x", n(1.0))])).unwrap();
        let back = std::fs::read_to_string(dir.join("experiments/t.json")).unwrap();
        assert!(back.contains("\"x\""));
    }

    #[test]
    fn table_to_json() {
        let mut t = Table::new("T", &["c"]);
        t.push(vec!["v".into()]);
        let j = t.to_json().emit();
        assert!(j.contains("\"title\":\"T\""));
    }
}

//! The experiment harness: one function per paper table/figure (DESIGN.md §5
//! maps each to its source).  `examples/repro_tables.rs` is the CLI.

use std::time::Instant;

use crate::calib::vocab::{LANGS, VOCAB_SIZE};
use crate::calib::CalibSet;
use crate::coordinator::{build_calib, quantize_model, FloatModel, PipelineConfig,
                         PipelineMetrics, QuantModel};
use crate::error::Result;
use crate::eval::{lambada, ppl, subjective, tasks, LanguageModel};
use crate::model::{ModelWeights, QuantizedModel};
use crate::policy::{BitBudgetPlanner, BitPlan, SensitivityConfig, SensitivityProfile,
                    SensitivityProfiler};
use crate::quant::QuantScheme;
use crate::runtime::Runtime;
use crate::tweak::tweaker::LossKind;
use crate::tweak::TweakConfig;

use super::{f2, f4, Table};

/// Everything a table run needs.
pub struct ReproCtx {
    pub runtime: Runtime,
    /// number of lambada-syn items per accuracy point
    pub n_eval: usize,
    /// tokens per PPL point
    pub ppl_tokens: usize,
}

impl ReproCtx {
    pub fn new(artifacts: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(ReproCtx {
            runtime: Runtime::new(artifacts)?,
            n_eval: 256,
            ppl_tokens: 4096,
        })
    }

    pub fn weights(&self, model: &str) -> Result<ModelWeights> {
        ModelWeights::load_from_dir(model, &self.runtime.manifest.dir)
    }

    pub fn calib(&self, w: &ModelWeights, source: &str) -> Result<CalibSet> {
        build_calib(&self.runtime, w, source, self.runtime.manifest.calib_batch, 0xCA11B)
    }

    pub fn quantize(
        &self,
        w: &ModelWeights,
        method: &str,
        scheme: QuantScheme,
        tweak: Option<TweakConfig>,
        calib: &CalibSet,
    ) -> Result<(QuantizedModel, PipelineMetrics)> {
        let mut cfg = PipelineConfig::new(method, scheme);
        if let Some(t) = tweak {
            cfg = cfg.with_tweak(t);
        }
        quantize_model(&self.runtime, w, calib, &cfg)
    }

    pub fn lambada_acc(&self, m: &dyn LanguageModel) -> Result<f32> {
        let set = lambada::LambadaSet::generate(0x1A3B, self.n_eval, m.config().seq);
        lambada::accuracy(m, &set, 8)
    }

    pub fn ppl(&self, m: &dyn LanguageModel, corpus: &str) -> Result<f32> {
        ppl::perplexity(m, corpus, self.ppl_tokens, 8)
    }

    fn nt(&self) -> TweakConfig {
        TweakConfig::default()
    }
}

/// Table 1 — corpus-share vs vocab-share mismatch of the top languages.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — corpus vs vocabulary share (the GenData-V2 motivation)",
        &["language", "corpus share", "vocab tokens", "vocab share"],
    );
    for l in &LANGS[..5] {
        t.push(vec![
            l.name.to_string(),
            f2(l.corpus_share as f32 * 100.0) + "%",
            (l.hi - l.lo).to_string(),
            f2((l.hi - l.lo) as f32 / VOCAB_SIZE as f32 * 100.0) + "%",
        ]);
    }
    let top_c: f64 = LANGS[..5].iter().map(|l| l.corpus_share).sum();
    let top_v: u32 = LANGS[..5].iter().map(|l| l.hi - l.lo).sum();
    t.push(vec![
        "top-5 total".into(),
        f2(top_c as f32 * 100.0) + "%",
        top_v.to_string(),
        f2(top_v as f32 / VOCAB_SIZE as f32 * 100.0) + "%",
    ]);
    t
}

/// Table 2 — LAMBADA-syn accuracy: FP32 / W4 / W2, GPTQ vs GPTQ+NT.
pub fn table2(ctx: &ReproCtx, models: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — LAMBADA-syn accuracy (%), GPTQ vs Norm-Tweaking",
        &["model", "FP32", "W4 GPTQ", "W4 +NT", "W2g64 GPTQ", "W2g64 +NT"],
    );
    for model in models {
        let w = ctx.weights(model)?;
        let calib = ctx.calib(&w, "gen-v2")?;
        let fm = FloatModel::new(&ctx.runtime, &w)?;
        let fp = ctx.lambada_acc(&fm)?;
        let mut row = vec![model.to_string(), f4(fp)];
        for scheme in [QuantScheme::w4_perchannel(), QuantScheme::w2_g64()] {
            for tweak in [None, Some(ctx.nt())] {
                let (qm, _) = ctx.quantize(&w, "gptq", scheme, tweak, &calib)?;
                let qr = QuantModel::new(&ctx.runtime, &qm)?;
                row.push(f4(ctx.lambada_acc(&qr)?));
            }
        }
        t.push(row);
    }
    Ok(t)
}

/// Table 3 — quantization runtime, GPTQ vs GPTQ+NT (seconds).
pub fn table3(ctx: &ReproCtx, models: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — quantization runtime (s)",
        &["model", "GPTQ", "GPTQ+NT", "overhead"],
    );
    for model in models {
        let w = ctx.weights(model)?;
        let calib = ctx.calib(&w, "gen-v2")?;
        let t0 = Instant::now();
        ctx.quantize(&w, "gptq", QuantScheme::w4_perchannel(), None, &calib)?;
        let plain = t0.elapsed().as_secs_f32();
        let t1 = Instant::now();
        ctx.quantize(&w, "gptq", QuantScheme::w4_perchannel(),
                     Some(ctx.nt()), &calib)?;
        let tweaked = t1.elapsed().as_secs_f32();
        t.push(vec![
            model.to_string(),
            f2(plain),
            f2(tweaked),
            format!("{}%", f2((tweaked / plain - 1.0) * 100.0)),
        ]);
    }
    Ok(t)
}

/// Table 4 — NT on RTN (W4) and SmoothQuant (W4A8).
pub fn table4(ctx: &ReproCtx, models: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — Norm-Tweaking on other PTQ methods (LAMBADA-syn acc %)",
        &["model", "FP32", "RTN W4", "RTN+NT W4", "SQ W4A8", "SQ+NT W4A8"],
    );
    for model in models {
        let w = ctx.weights(model)?;
        let calib = ctx.calib(&w, "gen-v2")?;
        let fm = FloatModel::new(&ctx.runtime, &w)?;
        let mut row = vec![model.to_string(), f4(ctx.lambada_acc(&fm)?)];
        let scheme = QuantScheme::w4_perchannel();
        for tweak in [None, Some(ctx.nt())] {
            let (qm, _) = ctx.quantize(&w, "rtn", scheme, tweak, &calib)?;
            let qr = QuantModel::new(&ctx.runtime, &qm)?;
            row.push(f4(ctx.lambada_acc(&qr)?));
        }
        for tweak in [None, Some(ctx.nt())] {
            let (qm, _) =
                ctx.quantize(&w, "smoothquant", scheme, tweak, &calib)?;
            let qr = QuantModel::new(&ctx.runtime, &qm)?.with_act_bits(Some(8));
            row.push(f4(ctx.lambada_acc(&qr)?));
        }
        t.push(row);
    }
    Ok(t)
}

/// Table 5 — subjective generation quality (mechanically scored).
pub fn table5(ctx: &ReproCtx, model: &str) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let prompt = vec![1, 42]; // BOS + an "en" token: "Beijing is..." analog
    let mut t = Table::new(
        "Table 5 — generation quality from a fixed prompt",
        &["model", "succ-rate %", "bucket violations", "3-gram loops", "sample"],
    );
    let clip = |s: &str| {
        let short: String = s.chars().take(48).collect();
        format!("{short}…")
    };

    let fm = FloatModel::new(&ctx.runtime, &w)?;
    let evals = subjective::subjective_eval(&fm, &prompt, 2, 48)?;
    let (text, rep) = &evals[0];
    t.push(vec!["FP32".into(), f2(rep.successor_rate * 100.0),
                rep.bucket_violations.to_string(),
                rep.repetition_loops.to_string(), clip(text)]);

    for (label, tweak) in [("GPTQ (2-bit)", None), ("Norm-Tweaking (2-bit)", Some(ctx.nt()))] {
        let (qm, _) = ctx.quantize(&w, "gptq", QuantScheme::w2_g64(),
                                   tweak, &calib)?;
        let qr = QuantModel::new(&ctx.runtime, &qm)?;
        let evals = subjective::subjective_eval(&qr, &prompt, 2, 48)?;
        let (text, rep) = &evals[0];
        t.push(vec![label.into(), f2(rep.successor_rate * 100.0),
                    rep.bucket_violations.to_string(),
                    rep.repetition_loops.to_string(), clip(text)]);
    }
    Ok(t)
}

/// Table 6 — tweaking-iterations ablation.
pub fn table6(ctx: &ReproCtx, model: &str, iters: &[usize]) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let mut t = Table::new(
        "Table 6 — effect of tweaking iterations (LAMBADA-syn acc %)",
        &["iters", "acc"],
    );
    for &it in iters {
        let tweak = TweakConfig { iters: it, ..ctx.nt() };
        let (qm, _) = ctx.quantize(&w, "gptq", QuantScheme::w4_perchannel(),
                                   Some(tweak), &calib)?;
        let qr = QuantModel::new(&ctx.runtime, &qm)?;
        t.push(vec![it.to_string(), f4(ctx.lambada_acc(&qr)?)]);
    }
    Ok(t)
}

/// Table 7 — the multi-task suite at 2 bits (and FP32/4-bit for Table 11).
pub fn table7(ctx: &ReproCtx, model: &str, include_w4: bool) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let mut header = vec!["precision".to_string()];
    header.extend(tasks::TASK_NAMES.iter().map(|s| s.to_string()));
    let mut t = Table::new(
        "Table 7/11 — LM-harness-syn task accuracy (%)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let score_all = |m: &dyn LanguageModel, label: &str,
                     t: &mut Table| -> Result<()> {
        let mut row = vec![label.to_string()];
        for name in tasks::TASK_NAMES {
            let task = tasks::build_task(name, 64, 0xE7A1);
            row.push(f2(tasks::score_task(m, &task, 8)?));
        }
        t.push(row);
        Ok(())
    };
    let fm = FloatModel::new(&ctx.runtime, &w)?;
    score_all(&fm, &format!("{model} (FP32)"), &mut t)?;
    let mut schemes = vec![(QuantScheme::w2_g64(), "2-bit")];
    if include_w4 {
        schemes.push((QuantScheme::w4_perchannel(), "4-bit"));
    }
    for (scheme, tag) in schemes {
        for (label, tweak) in [("GPTQ", None), ("Norm-Tweak", Some(ctx.nt()))] {
            let (qm, _) = ctx.quantize(&w, "gptq", scheme, tweak, &calib)?;
            let qr = QuantModel::new(&ctx.runtime, &qm)?;
            score_all(&qr, &format!("w/ {label} ({tag})"), &mut t)?;
        }
    }
    Ok(t)
}

/// Table 8 — calibration-data ablation (PPL matrix).
pub fn table8(ctx: &ReproCtx, model: &str) -> Result<Table> {
    let w = ctx.weights(model)?;
    let mut t = Table::new(
        "Table 8 — calibration data vs held-out PPL (GPTQ+NT)",
        &["calibration", "wiki-syn", "ptb-syn", "c4-syn"],
    );
    for source in ["wiki-syn", "ptb-syn", "c4-syn", "random", "gen-v1", "gen-v2"] {
        let calib = ctx.calib(&w, source)?;
        let (qm, _) = ctx.quantize(&w, "gptq", QuantScheme::w2_g64(),
                                   Some(ctx.nt()), &calib)?;
        let qr = QuantModel::new(&ctx.runtime, &qm)?;
        let mut row = vec![source.to_string()];
        for eval_set in ["wiki-syn", "ptb-syn", "c4-syn"] {
            row.push(f4(ctx.ppl(&qr, eval_set)?));
        }
        t.push(row);
    }
    Ok(t)
}

/// Table 9 — tweak-loss ablation (L_MSE vs L_KL vs L_dist).
pub fn table9(ctx: &ReproCtx, models: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 9 — loss-function ablation (LAMBADA-syn acc %)",
        &["model", "L_MSE", "L_KL", "L_dist"],
    );
    for model in models {
        let w = ctx.weights(model)?;
        let calib = ctx.calib(&w, "gen-v2")?;
        let mut row = vec![model.to_string()];
        for loss in [LossKind::Mse, LossKind::Kl, LossKind::Dist] {
            let tweak = TweakConfig { loss, ..ctx.nt() };
            let (qm, _) = ctx.quantize(&w, "gptq",
                                       QuantScheme::w4_perchannel(), Some(tweak), &calib)?;
            let qr = QuantModel::new(&ctx.runtime, &qm)?;
            row.push(f4(ctx.lambada_acc(&qr)?));
        }
        t.push(row);
    }
    Ok(t)
}

/// Table 10 — NT on OmniQuant (+AWQ row): PPL wiki-syn / c4-syn.
pub fn table10(ctx: &ReproCtx, model: &str) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let mut t = Table::new(
        "Table 10 — OmniQuant ± NT (PPL wiki-syn / c4-syn, lower is better)",
        &["method", "W2A16g64", "W3A16g64", "W4A4"],
    );
    let modes: [(QuantScheme, Option<u8>); 3] = [
        (QuantScheme::w2_g64(), None),
        (QuantScheme::w3_g64(), None),
        (QuantScheme::w4_perchannel(), Some(4)),
    ];
    let run = |method: &str, tweak: Option<TweakConfig>| -> Result<Vec<String>> {
        let mut cells = Vec::new();
        for (scheme, act) in &modes {
            let (qm, _) = ctx.quantize(&w, method, *scheme, tweak, &calib)?;
            let qr = QuantModel::new(&ctx.runtime, &qm)?.with_act_bits(*act);
            cells.push(format!(
                "{} / {}",
                f2(ctx.ppl(&qr, "wiki-syn")?),
                f2(ctx.ppl(&qr, "c4-syn")?)
            ));
        }
        Ok(cells)
    };
    let mut awq = vec!["AWQ".to_string()];
    awq.extend(run("awq", None)?);
    t.push(awq);
    let mut oq = vec!["OmniQuant".to_string()];
    oq.extend(run("omniquant", None)?);
    t.push(oq);
    let mut oqnt = vec!["w/ NT".to_string()];
    oqnt.extend(run("omniquant", Some(ctx.nt()))?);
    t.push(oqnt);
    Ok(t)
}

/// Render a (profile, plan) pair as the per-layer score × allocation table
/// shared by `normtweak plan` and the repro harness. The profile's full
/// provenance (model, method, grain, calibration source, loss) rides in the
/// title, so a persisted record is reproducible.
pub fn plan_table(profile: &SensitivityProfile, plan: &BitPlan, target_bits: f32) -> Table {
    let mut header = vec!["layer".to_string()];
    header.extend(profile.candidate_bits.iter().map(|b| format!("L@{b}b")));
    header.push("alloc bits".into());
    let mut t = Table::new(
        &format!(
            "mixed-precision plan @ {target_bits} avg bits ({})",
            profile.provenance()
        ),
        &header.iter().map(|h| h.as_str()).collect::<Vec<_>>(),
    );
    for l in &profile.layers {
        let mut row = vec![l.layer.to_string()];
        for &b in &profile.candidate_bits {
            row.push(l.score(b).map(f4).unwrap_or_default());
        }
        row.push(
            plan.schemes
                .get(&l.layer)
                .map(|s| s.bits.to_string())
                .unwrap_or_default(),
        );
        t.push(row);
    }
    let mut summary = vec!["mean".to_string()];
    summary.extend(profile.candidate_bits.iter().map(|_| String::new()));
    summary.push(f2(plan.mean_bits));
    t.push(summary);
    t
}

/// Sensitivity profile → mixed-precision plan for one model, end to end
/// (profile with GPTQ at the paper's W2g64 grain, allocate `target_bits`).
pub fn table_plan(ctx: &ReproCtx, model: &str, target_bits: f32) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let base = QuantScheme::w2_g64();
    let scfg = SensitivityConfig::new("gptq", base);
    let profile = SensitivityProfiler::new(&ctx.runtime, &w, scfg).profile(&calib)?;
    let plan = BitBudgetPlanner::new(base, target_bits).plan(&profile)?;
    Ok(plan_table(&profile, &plan, target_bits))
}

/// Figure 1 — per-layer activation drift Δμ, GPTQ vs GPTQ+NT.
pub fn figure1(ctx: &ReproCtx, model: &str) -> Result<Table> {
    let w = ctx.weights(model)?;
    let calib = ctx.calib(&w, "gen-v2")?;
    let scheme = QuantScheme::w2_g64();
    let (_, m_plain) = ctx.quantize(&w, "gptq", scheme, None, &calib)?;
    let (_, m_nt) = ctx.quantize(&w, "gptq", scheme, Some(ctx.nt()), &calib)?;
    let mut t = Table::new(
        "Figure 1 — per-layer activation drift Δμ (GPTQ vs Norm-Tweaking, W2)",
        &["layer", "GPTQ Δμ", "NT Δμ", "bar (GPTQ=#, NT=*)"],
    );
    let peak = m_plain
        .layers
        .iter()
        .map(|l| l.delta_mu)
        .fold(1e-9f32, f32::max);
    for (a, b) in m_plain.layers.iter().zip(&m_nt.layers) {
        let bars = |v: f32, ch: char| {
            let n = ((v / peak) * 30.0).round() as usize;
            std::iter::repeat(ch).take(n.max(1)).collect::<String>()
        };
        t.push(vec![
            a.layer.to_string(),
            format!("{:.5}", a.delta_mu),
            format!("{:.5}", b.delta_mu),
            format!("{} | {}", bars(a.delta_mu, '#'), bars(b.delta_mu, '*')),
        ]);
    }
    Ok(t)
}

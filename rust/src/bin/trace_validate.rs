//! `trace_validate <trace.json> [BENCH_serve.json]` — CI checker for the
//! observability exports.
//!
//! Validates the Chrome trace-event JSON produced by `--trace` (parses,
//! non-empty, ≥3 named tracks, per-track monotonic timestamps in file
//! order, complete spans nest without partial overlap, and — guarding the
//! decode fast path — no stacked-cache era span (`stack_layer` /
//! `scatter_layer` / `cache_row`) ever appears on a `lane:*/decode`
//! track) and, when given, the enriched `BENCH_serve.json` schema
//! (per-config `latency_us` percentile blocks for queue / prefill /
//! decode_step / e2e, the `fast_path` arena-occupancy / admission-batch
//! block, plus the `failed` counter).  Exits non-zero with an `error:`
//! line naming the first violation, so a refactor that silently breaks
//! the export fails at PR time instead of at the next debugging session.

use std::collections::HashMap;

use normtweak::util::json::Json;
use normtweak::{Error, Result};

fn fail(msg: impl Into<String>) -> Error {
    Error::Config(msg.into())
}

/// Validate one exported Chrome trace.
fn check_trace(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| fail(format!("{path}: {e}")))?;
    let j = Json::parse(&text).map_err(|e| fail(format!("{path}: bad JSON: {e}")))?;
    let events = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| fail(format!("{path}: no traceEvents array")))?;
    if events.is_empty() {
        return Err(fail(format!("{path}: traceEvents is empty")));
    }

    let mut tracks = 0usize;
    // tid → declared track name (from "M" metadata events), so span rules
    // can key on *which* track a span landed on
    let mut track_names: HashMap<u64, String> = HashMap::new();
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    // per-track stack of open complete-span end times (file order = sorted
    // by start, parents before children)
    let mut open: HashMap<u64, Vec<f64>> = HashMap::new();
    let mut spans = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| fail(format!("{path}: event {i} has no ph")))?;
        let tid = ev.get("tid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if ph == "M" {
            let Some(name) = ev
                .get("args")
                .and_then(|a| a.get("name"))
                .and_then(|n| n.as_str())
            else {
                return Err(fail(format!("{path}: metadata event {i} has no track name")));
            };
            track_names.insert(tid, name.to_string());
            tracks += 1;
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| fail(format!("{path}: event {i} has no ts")))?;
        if let Some(prev) = last_ts.get(&tid) {
            if ts < *prev {
                return Err(fail(format!(
                    "{path}: event {i} on tid {tid} goes back in time ({ts} < {prev})"
                )));
            }
        }
        last_ts.insert(tid, ts);
        if ph == "X" {
            spans += 1;
            // decode-track hygiene: the slot-arena fast path indexes KV
            // caches in place, so a stacked-cache era span on a lane's
            // decode track means per-step stack/scatter/row-copy crept
            // back into the hot loop
            let span = ev.get("name").and_then(|v| v.as_str()).unwrap_or("");
            if let Some(track) = track_names.get(&tid) {
                if track.starts_with("lane:")
                    && track.ends_with("/decode")
                    && matches!(span, "stack_layer" | "scatter_layer" | "cache_row")
                {
                    return Err(fail(format!(
                        "{path}: span `{span}` (event {i}) on decode track `{track}`: \
                         the decode fast path must not stack, scatter, or copy KV \
                         rows per step"
                    )));
                }
            }
            let dur = ev.get("dur").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let stack = open.entry(tid).or_default();
            while stack.last().is_some_and(|end| *end <= ts) {
                stack.pop();
            }
            if let Some(end) = stack.last() {
                if ts + dur > *end {
                    return Err(fail(format!(
                        "{path}: span at event {i} on tid {tid} partially overlaps its \
                         parent (ends {} > {end})",
                        ts + dur
                    )));
                }
            }
            stack.push(ts + dur);
        }
    }
    if tracks < 3 {
        return Err(fail(format!(
            "{path}: only {tracks} named track(s); a lifecycle trace needs >= 3 \
             (scheduler + per-lane prefill/decode, or pipeline + xla)"
        )));
    }
    println!(
        "{path}: ok ({} events, {tracks} tracks, {spans} complete spans)",
        events.len()
    );
    Ok(())
}

/// Validate the enriched `BENCH_serve.json` schema.
fn check_bench(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| fail(format!("{path}: {e}")))?;
    let j = Json::parse(&text).map_err(|e| fail(format!("{path}: bad JSON: {e}")))?;
    let configs = j
        .get("configs")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| fail(format!("{path}: no configs array")))?;
    if configs.is_empty() {
        return Err(fail(format!("{path}: configs is empty")));
    }
    for (i, c) in configs.iter().enumerate() {
        let lat = c
            .get("latency_us")
            .ok_or_else(|| fail(format!("{path}: config {i} has no latency_us")))?;
        for phase in ["queue", "prefill", "decode_step", "e2e"] {
            let h = lat.get(phase).ok_or_else(|| {
                fail(format!("{path}: config {i} latency_us has no `{phase}`"))
            })?;
            for field in ["count", "p50", "p90", "p99", "max"] {
                if h.get(field).and_then(|v| v.as_f64()).is_none() {
                    return Err(fail(format!(
                        "{path}: config {i} latency_us.{phase}.{field} missing or \
                         not a number"
                    )));
                }
            }
        }
        let fp = c
            .get("fast_path")
            .ok_or_else(|| fail(format!("{path}: config {i} has no fast_path")))?;
        for key in ["arena_occupancy", "admission_batch_size"] {
            let h = fp.get(key).ok_or_else(|| {
                fail(format!("{path}: config {i} fast_path has no `{key}`"))
            })?;
            for field in ["count", "p50", "p90", "p99", "max"] {
                if h.get(field).and_then(|v| v.as_f64()).is_none() {
                    return Err(fail(format!(
                        "{path}: config {i} fast_path.{key}.{field} missing or \
                         not a number"
                    )));
                }
            }
        }
        if c.get("failed").and_then(|v| v.as_f64()).is_none() {
            return Err(fail(format!("{path}: config {i} has no numeric `failed`")));
        }
    }
    println!("{path}: ok ({} configs with engine latency percentiles)", configs.len());
    Ok(())
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (trace, bench) = match args.as_slice() {
        [t] => (t, None),
        [t, b] => (t, Some(b)),
        _ => {
            return Err(fail(
                "usage: trace_validate <trace.json> [BENCH_serve.json]",
            ))
        }
    };
    check_trace(trace)?;
    if let Some(b) = bench {
        check_bench(b)?;
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        normtweak::log_error!("trace_validate", "{e}");
        std::process::exit(1);
    }
}

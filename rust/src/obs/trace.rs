//! Structured spans/events with a pluggable clock, collected into a ring
//! buffer and exported as Chrome trace-event JSON.
//!
//! Producers hold an `Option<Arc<TraceCollector>>` and skip all work when
//! it is `None`, so tracing costs nothing unless a `--trace out.json`
//! flag (or a test) attaches a collector.  The export is the classic
//! `{"traceEvents": [...]}` object format: load it in `chrome://tracing`
//! or <https://ui.perfetto.dev>.  Events are sorted by start time at
//! export — within one track, timestamps are non-decreasing in file order
//! and complete spans nest without partial overlap (the property
//! `trace_validate` checks in CI).

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::error::Result;
use crate::util::json::{self, Json};

use super::metrics::MetricsSnapshot;

/// Monotonic time source, microseconds since a per-collector origin.
/// Pluggable so tests get deterministic, strictly ordered stamps.
pub trait Clock: Send + Sync {
    fn now_micros(&self) -> u64;
}

/// Production clock: monotonic wall time anchored at construction.
#[derive(Debug)]
pub struct WallClock {
    origin: Instant,
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock { origin: Instant::now() }
    }
}

impl WallClock {
    pub fn new() -> Self {
        WallClock::default()
    }
}

impl Clock for WallClock {
    fn now_micros(&self) -> u64 {
        self.origin.elapsed().as_micros().min(u128::from(u64::MAX)) as u64
    }
}

/// Deterministic test clock: every reading returns the previous value and
/// advances it by `tick`, so consecutive events get strictly increasing
/// timestamps without any real time passing.
#[derive(Debug)]
pub struct TestClock {
    t: AtomicU64,
    tick: u64,
}

impl TestClock {
    pub fn new(tick: u64) -> Self {
        TestClock { t: AtomicU64::new(0), tick }
    }

    /// Jump forward without producing a reading.
    pub fn advance(&self, dt: u64) {
        self.t.fetch_add(dt, Ordering::Relaxed);
    }
}

impl Clock for TestClock {
    fn now_micros(&self) -> u64 {
        self.t.fetch_add(self.tick, Ordering::Relaxed)
    }
}

/// Chrome trace-event phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// `"X"` — complete span (`ts` + `dur`)
    Complete,
    /// `"i"` — instant event
    Instant,
    /// `"C"` — counter sample
    Counter,
    /// `"b"` — async begin, paired with the matching end by `id`
    AsyncBegin,
    /// `"e"` — async end
    AsyncEnd,
}

impl Phase {
    pub fn code(self) -> &'static str {
        match self {
            Phase::Complete => "X",
            Phase::Instant => "i",
            Phase::Counter => "C",
            Phase::AsyncBegin => "b",
            Phase::AsyncEnd => "e",
        }
    }
}

/// One collected event — the pre-serialization form of a Chrome trace
/// event (`ts`/`dur` in microseconds of the collector's clock).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub ph: Phase,
    pub ts: u64,
    pub dur: u64,
    pub tid: u64,
    /// async begin/end pairing id (0 for other phases)
    pub id: u64,
    pub args: Vec<(String, Json)>,
}

#[derive(Debug, Default)]
struct Ring {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Default ring capacity: enough for a full quantize run or a bench
/// sweep without unbounded memory.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Ring-buffered trace collector.  `Send + Sync`: producers on any thread
/// push events under one short mutex hold; on overflow the **oldest**
/// event is dropped and counted ([`TraceCollector::dropped`]).
pub struct TraceCollector {
    clock: Box<dyn Clock>,
    cap: usize,
    ring: Mutex<Ring>,
    tracks: Mutex<BTreeMap<String, u64>>,
    next_tid: AtomicU64,
    next_id: AtomicU64,
}

impl std::fmt::Debug for TraceCollector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCollector")
            .field("cap", &self.cap)
            .field("len", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a poisoned trace is still a trace: recover the data, don't panic
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn own_args(args: Vec<(&str, Json)>) -> Vec<(String, Json)> {
    args.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

impl TraceCollector {
    /// Wall-clock collector holding at most `cap` events.
    pub fn new(cap: usize) -> Self {
        TraceCollector::with_clock(cap, Box::new(WallClock::new()))
    }

    /// Collector with an explicit clock (tests: [`TestClock`]).
    pub fn with_clock(cap: usize, clock: Box<dyn Clock>) -> Self {
        TraceCollector {
            clock,
            cap: cap.max(1),
            ring: Mutex::new(Ring::default()),
            tracks: Mutex::new(BTreeMap::new()),
            next_tid: AtomicU64::new(1),
            next_id: AtomicU64::new(1),
        }
    }

    /// Current timestamp (µs since the collector's origin).
    pub fn now(&self) -> u64 {
        self.clock.now_micros()
    }

    /// Get-or-create the track (Chrome `tid`) named `name`.
    pub fn track(&self, name: &str) -> u64 {
        let mut t = lock(&self.tracks);
        if let Some(id) = t.get(name) {
            return *id;
        }
        let id = self.next_tid.fetch_add(1, Ordering::Relaxed);
        t.insert(name.to_string(), id);
        id
    }

    /// Registered track names with their `tid`s.
    pub fn track_names(&self) -> BTreeMap<String, u64> {
        lock(&self.tracks).clone()
    }

    /// Fresh id for an async begin/end pair.
    pub fn next_async_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn push(&self, ev: TraceEvent) {
        let mut r = lock(&self.ring);
        if r.events.len() >= self.cap {
            r.events.pop_front();
            r.dropped += 1;
        }
        r.events.push_back(ev);
    }

    /// Complete span that started at `start` (a [`TraceCollector::now`]
    /// reading) and ends now.
    pub fn complete(&self, tid: u64, name: &str, start: u64, args: Vec<(&str, Json)>) {
        let end = self.now();
        self.complete_at(tid, name, start, end.saturating_sub(start), args);
    }

    /// Complete span with explicit start and duration (µs) — for work
    /// timed outside the collector's clock.
    pub fn complete_at(&self, tid: u64, name: &str, ts: u64, dur: u64, args: Vec<(&str, Json)>) {
        self.push(TraceEvent {
            name: name.to_string(),
            ph: Phase::Complete,
            ts,
            dur,
            tid,
            id: 0,
            args: own_args(args),
        });
    }

    /// RAII span: records a complete event on `tid` when the guard drops.
    pub fn span(&self, tid: u64, name: &str) -> SpanGuard<'_> {
        SpanGuard { tc: self, tid, name: name.to_string(), start: self.now(), args: Vec::new() }
    }

    /// Zero-duration marker event.
    pub fn instant(&self, tid: u64, name: &str, args: Vec<(&str, Json)>) {
        let ts = self.now();
        self.push(TraceEvent {
            name: name.to_string(),
            ph: Phase::Instant,
            ts,
            dur: 0,
            tid,
            id: 0,
            args: own_args(args),
        });
    }

    /// One sample of the counter track `name` (series → value).
    pub fn counter(&self, name: &str, series: &str, value: f64) {
        let ts = self.now();
        self.push(TraceEvent {
            name: name.to_string(),
            ph: Phase::Counter,
            ts,
            dur: 0,
            tid: 0,
            id: 0,
            args: vec![(series.to_string(), json::n(value))],
        });
    }

    /// Async begin: pairs with the [`TraceCollector::async_end`] carrying
    /// the same `name` and `id`.
    pub fn async_begin(&self, tid: u64, name: &str, id: u64, args: Vec<(&str, Json)>) {
        let ts = self.now();
        self.push(TraceEvent {
            name: name.to_string(),
            ph: Phase::AsyncBegin,
            ts,
            dur: 0,
            tid,
            id,
            args: own_args(args),
        });
    }

    /// Async end (see [`TraceCollector::async_begin`]).
    pub fn async_end(&self, tid: u64, name: &str, id: u64) {
        let ts = self.now();
        self.push(TraceEvent {
            name: name.to_string(),
            ph: Phase::AsyncEnd,
            ts,
            dur: 0,
            tid,
            id,
            args: Vec::new(),
        });
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        lock(&self.ring).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by ring overflow since construction.
    pub fn dropped(&self) -> u64 {
        lock(&self.ring).dropped
    }

    /// Copy of the buffered events in collection order.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        lock(&self.ring).events.iter().cloned().collect()
    }

    /// Chrome trace-event JSON: `thread_name` metadata for every
    /// registered track, then the buffered events sorted by start time
    /// (ties: longer span first, so parents precede their children).
    /// `metrics`, when given, is embedded under the extra top-level
    /// `"metrics"` key, which trace viewers ignore.
    pub fn export_chrome(&self, metrics: Option<&MetricsSnapshot>) -> Json {
        let mut events = self.snapshot();
        events.sort_by(|a, b| a.ts.cmp(&b.ts).then(b.dur.cmp(&a.dur)));
        let mut out = Vec::new();
        for (name, tid) in self.track_names() {
            out.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::n(1.0)),
                ("tid", json::n(tid as f64)),
                ("args", json::obj(vec![("name", json::s(name))])),
            ]));
        }
        for ev in &events {
            let mut pairs = vec![
                ("name", json::s(ev.name.clone())),
                ("cat", json::s("normtweak")),
                ("ph", json::s(ev.ph.code())),
                ("ts", json::n(ev.ts as f64)),
                ("pid", json::n(1.0)),
                ("tid", json::n(ev.tid as f64)),
            ];
            match ev.ph {
                Phase::Complete => pairs.push(("dur", json::n(ev.dur as f64))),
                Phase::Instant => pairs.push(("s", json::s("t"))),
                Phase::AsyncBegin | Phase::AsyncEnd => {
                    pairs.push(("id", json::s(format!("{:#x}", ev.id))));
                }
                Phase::Counter => {}
            }
            if !ev.args.is_empty() {
                pairs.push(("args", Json::Obj(ev.args.iter().cloned().collect())));
            }
            out.push(json::obj(pairs));
        }
        let mut top = vec![
            ("traceEvents", json::arr(out)),
            ("displayTimeUnit", json::s("ms")),
            (
                "otherData",
                json::obj(vec![("dropped_events", json::n(self.dropped() as f64))]),
            ),
        ];
        if let Some(m) = metrics {
            top.push(("metrics", m.to_json()));
        }
        json::obj(top)
    }

    /// Write [`TraceCollector::export_chrome`] to `path`.
    pub fn write_chrome(&self, path: &Path, metrics: Option<&MetricsSnapshot>) -> Result<()> {
        std::fs::write(path, self.export_chrome(metrics).emit())?;
        Ok(())
    }
}

/// RAII guard from [`TraceCollector::span`]: emits a complete event over
/// its lifetime when dropped (including on early `?` exits).
pub struct SpanGuard<'a> {
    tc: &'a TraceCollector,
    tid: u64,
    name: String,
    start: u64,
    args: Vec<(String, Json)>,
}

impl SpanGuard<'_> {
    /// Attach an argument shown in the trace viewer's span details.
    pub fn arg(&mut self, key: &str, value: Json) {
        self.args.push((key.to_string(), value));
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let end = self.tc.now();
        self.tc.push(TraceEvent {
            name: std::mem::take(&mut self.name),
            ph: Phase::Complete,
            ts: self.start,
            dur: end.saturating_sub(self.start),
            tid: self.tid,
            id: 0,
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Executable name up to the first `.` — the graph *family* shared by
/// every batch/grain specialization (`"block_fwd_q.g64.b8"` →
/// `"block_fwd_q"`).  Metric and span names key on the family so timing
/// aggregates across specializations.
pub fn graph_family(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_strips_specialization() {
        assert_eq!(graph_family("block_fwd_q.g64.b8"), "block_fwd_q");
        assert_eq!(graph_family("embed"), "embed");
        assert_eq!(graph_family(""), "");
    }

    #[test]
    fn test_clock_is_strictly_ordered() {
        let c = TestClock::new(1);
        assert_eq!(c.now_micros(), 0);
        assert_eq!(c.now_micros(), 1);
        c.advance(10);
        assert_eq!(c.now_micros(), 12);
    }

    #[test]
    fn tracks_are_stable_get_or_create() {
        let tc = TraceCollector::with_clock(16, Box::new(TestClock::new(1)));
        let a = tc.track("alpha");
        let b = tc.track("beta");
        assert_ne!(a, b);
        assert_eq!(tc.track("alpha"), a);
        assert_eq!(tc.track_names().len(), 2);
    }

    #[test]
    fn span_guard_emits_on_drop() {
        let tc = TraceCollector::with_clock(16, Box::new(TestClock::new(1)));
        let tid = tc.track("t");
        {
            let mut s = tc.span(tid, "work");
            s.arg("k", json::s("v"));
        }
        let evs = tc.snapshot();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "work");
        assert_eq!(evs[0].ph, Phase::Complete);
        assert_eq!(evs[0].args.len(), 1);
    }
}

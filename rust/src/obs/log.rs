//! Leveled stderr logger behind the crate-root `log_*!` macros.
//!
//! The ceiling comes from `NORMTWEAK_LOG` (`error` | `warn` | `info` |
//! `debug`), read once on first use.  When it is unset, `NT_QUIET` maps
//! to `warn` so existing CI environments stay silent; otherwise the
//! default is `info`.  All output goes to **stderr** — stdout belongs to
//! machine-readable products (tables, report JSON, generated samples)
//! and must never interleave with logs.
//!
//! ```text
//! log_info!("pipeline", "layer {l}: loss {loss:.3}");
//! //  -> stderr: [pipeline] layer 7: loss 0.041
//! log_warn!("check", "{code}: {msg}");
//! //  -> stderr: warning: [check] NT0403: ...
//! ```

use std::fmt;
use std::sync::OnceLock;

/// Log severity, ordered `Error < Warn < Info < Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `NORMTWEAK_LOG` value (case-insensitive; common synonyms
    /// accepted).  `None` for anything unrecognized.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "err" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }
}

static MAX: OnceLock<Level> = OnceLock::new();

fn level_from_env() -> Level {
    match std::env::var("NORMTWEAK_LOG") {
        Ok(v) => Level::parse(&v).unwrap_or(Level::Info),
        Err(_) => {
            if std::env::var_os("NT_QUIET").is_some() {
                Level::Warn
            } else {
                Level::Info
            }
        }
    }
}

/// The active ceiling: messages above it are discarded.  The first call
/// locks the level in from the environment.
pub fn max_level() -> Level {
    *MAX.get_or_init(level_from_env)
}

/// Force the ceiling before any message is logged (CLI overrides, tests).
/// Returns `false` if the level was already locked in.
pub fn set_max_level(level: Level) -> bool {
    MAX.set(level).is_ok()
}

/// Would a message at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Macro backend — prefer the `log_*!` macros over calling this directly.
pub fn write(level: Level, target: &str, msg: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    match level {
        Level::Error => eprintln!("error: [{target}] {msg}"),
        Level::Warn => eprintln!("warning: [{target}] {msg}"),
        Level::Info | Level::Debug => eprintln!("[{target}] {msg}"),
    }
}

/// Log an unrecoverable condition (always emitted).
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Error, $target,
                                format_args!($($arg)*))
    };
}

/// Log a suspicious-but-survivable condition.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Warn, $target,
                                format_args!($($arg)*))
    };
}

/// Log progress narration (the old `NT_QUIET`-gated prints).
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Info, $target,
                                format_args!($($arg)*))
    };
}

/// Log detail useful only when chasing a specific problem.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Debug, $target,
                                format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_correctly() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_accepts_synonyms_case_insensitively() {
        assert_eq!(Level::parse("ERROR"), Some(Level::Error));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("Trace"), Some(Level::Debug));
        assert_eq!(Level::parse("loud"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn as_str_round_trips() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
    }
}

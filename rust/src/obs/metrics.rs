//! Metrics: counters, gauges, and log-bucketed latency histograms with
//! percentile extraction, plus the process-wide [`MetricsRegistry`].
//!
//! Histograms bucket values geometrically — four sub-buckets per power of
//! two, so every bucket spans at most 25% of its lower bound (values below
//! 4 get exact buckets).  A reported percentile is therefore within 25%
//! of the true order statistic, and exact at the recorded min/max.
//! Recording is one short mutex hold; counters and gauges are single
//! relaxed atomics.  Snapshots serialize through `util::json`
//! ([`MetricsSnapshot::to_json`] / [`MetricsSnapshot::from_json`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

const SUB_BITS: u32 = 2;
const SUB: usize = 1 << SUB_BITS; // sub-buckets per power of two

/// Bucket index for a value: exact below `SUB` (4), then `SUB` geometric
/// sub-buckets per octave (relative bucket width ≤ 1/SUB of the bound).
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // floor(log2 v), >= SUB_BITS here
    let sub = ((v >> (e - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    (e - SUB_BITS) as usize * SUB + SUB + sub
}

/// Inclusive lower bound of bucket `i` (inverse of [`bucket_index`]).
pub fn bucket_low(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let e = (i - SUB) / SUB + SUB_BITS as usize;
    if e >= 64 {
        return u64::MAX;
    }
    let sub = ((i - SUB) % SUB) as u128;
    ((1u128 << e) + (sub << (e - SUB_BITS as usize))).min(u128::from(u64::MAX)) as u64
}

/// Exclusive upper bound of bucket `i` (saturating at `u64::MAX`).
pub fn bucket_high(i: usize) -> u64 {
    bucket_low(i + 1)
}

/// Single-writer log-bucketed histogram.  Plain data (`Clone + Eq`), so
/// it can live inside snapshot structs like `engine::ModelStats`; shared
/// concurrent recording goes through [`HistHandle`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hist {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: Vec::new(), count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist::default()
    }

    pub fn record(&mut self, v: u64) {
        let i = bucket_index(v);
        if self.counts.len() <= i {
            self.counts.resize(i + 1, 0);
        }
        self.counts[i] += 1;
        self.count += 1;
        self.sum += u128::from(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at percentile `p` in `0..=100`: the lower bound of the bucket
    /// holding the `ceil(p/100 · count)`-th smallest sample, clamped to
    /// the observed `[min, max]`.  Never overestimates the true order
    /// statistic; underestimates by at most one bucket width (≤ 25%).
    /// Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        if rank == self.count {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_low(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &Hist) {
        if other.count == 0 {
            return;
        }
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Summary object: `count` / `mean` / `min` / `max` / `p50` / `p90` /
    /// `p99` plus the sparse `buckets` list `[[index, count], ...]` that
    /// [`Hist::from_json`] rebuilds from.  `sum` is emitted as an f64 and
    /// loses precision past 2^53 — the percentile fields do not.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| json::arr(vec![json::n(i as f64), json::n(*c as f64)]))
            .collect();
        json::obj(vec![
            ("count", json::n(self.count as f64)),
            ("sum", json::n(self.sum as f64)),
            ("mean", json::n(self.mean())),
            ("min", json::n(self.min() as f64)),
            ("max", json::n(self.max as f64)),
            ("p50", json::n(self.percentile(50.0) as f64)),
            ("p90", json::n(self.percentile(90.0) as f64)),
            ("p99", json::n(self.percentile(99.0) as f64)),
            ("buckets", json::arr(buckets)),
        ])
    }

    /// Rebuild from [`Hist::to_json`] output.
    pub fn from_json(v: &Json) -> Result<Hist> {
        let count = field_u64(v, "count")?;
        if count == 0 {
            return Ok(Hist::default());
        }
        let mut h = Hist {
            counts: Vec::new(),
            count,
            sum: field_u64(v, "sum")?.into(),
            min: field_u64(v, "min")?,
            max: field_u64(v, "max")?,
        };
        let buckets = v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::msg("histogram JSON missing 'buckets'"))?;
        for b in buckets {
            let pair = b.as_arr().ok_or_else(|| Error::msg("histogram bucket not a pair"))?;
            let (i, c) = match pair {
                [i, c] => (
                    i.as_usize().ok_or_else(|| Error::msg("bad bucket index"))?,
                    c.as_f64().ok_or_else(|| Error::msg("bad bucket count"))? as u64,
                ),
                _ => return Err(Error::msg("histogram bucket not a pair")),
            };
            if h.counts.len() <= i {
                h.counts.resize(i + 1, 0);
            }
            h.counts[i] += c;
        }
        Ok(h)
    }
}

fn field_u64(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Json::as_f64)
        .map(|n| n as u64)
        .ok_or_else(|| Error::msg(format!("histogram JSON missing numeric '{key}'")))
}

/// Monotonic counter handle (clones share the underlying cell).
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge handle (clones share the underlying cell).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

type SharedHist = Arc<Mutex<Hist>>;

/// Concurrent histogram handle: `record` is one short mutex hold.
#[derive(Debug, Clone, Default)]
pub struct HistHandle(SharedHist);

impl HistHandle {
    pub fn record(&self, v: u64) {
        lock(&self.0).record(v);
    }

    pub fn snapshot(&self) -> Hist {
        lock(&self.0).clone()
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a poisoned metric is still a metric: take the data, don't panic
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicI64>>,
    hists: BTreeMap<String, SharedHist>,
}

/// Get-or-create registry of named metrics (see the module docs of
/// [`crate::obs`] for the naming convention).  Handles stay valid after
/// [`MetricsRegistry::reset`], but detach from future snapshots.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut g = lock(&self.inner);
        Counter(g.counters.entry(name.to_string()).or_default().clone())
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut g = lock(&self.inner);
        Gauge(g.gauges.entry(name.to_string()).or_default().clone())
    }

    pub fn histogram(&self, name: &str) -> HistHandle {
        let mut g = lock(&self.inner);
        HistHandle(g.hists.entry(name.to_string()).or_default().clone())
    }

    /// Consistent point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = lock(&self.inner);
        MetricsSnapshot {
            counters: g
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: g
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            hists: g.hists.iter().map(|(k, h)| (k.clone(), lock(h).clone())).collect(),
        }
    }

    /// Drop every registered metric (tests).
    pub fn reset(&self) {
        *lock(&self.inner) = Inner::default();
    }
}

/// The process-wide registry used by runtime / pipeline instrumentation.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Point-in-time copy of a [`MetricsRegistry`], serializable via
/// `util::json`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, Hist>,
}

impl MetricsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters.iter().map(|(k, v)| (k.clone(), json::n(*v as f64))).collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges.iter().map(|(k, v)| (k.clone(), json::n(*v as f64))).collect(),
                ),
            ),
            (
                "hists",
                Json::Obj(self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<MetricsSnapshot> {
        let section =
            |key: &str| v.get(key).and_then(Json::as_obj).cloned().unwrap_or_default();
        let mut snap = MetricsSnapshot::default();
        for (k, n) in &section("counters") {
            let n = n.as_f64().ok_or_else(|| Error::msg("non-numeric counter"))?;
            snap.counters.insert(k.clone(), n as u64);
        }
        for (k, n) in &section("gauges") {
            let n = n.as_f64().ok_or_else(|| Error::msg("non-numeric gauge"))?;
            snap.gauges.insert(k.clone(), n as i64);
        }
        for (k, h) in &section("hists") {
            snap.hists.insert(k.clone(), Hist::from_json(h)?);
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_get_exact_buckets() {
        for v in 0..8u64 {
            assert_eq!(bucket_low(bucket_index(v)), v, "v={v}");
            assert_eq!(bucket_high(bucket_index(v)), v + 1, "v={v}");
        }
    }

    #[test]
    fn bucket_bounds_bracket_every_value() {
        let probes = [8u64, 9, 15, 16, 100, 1_000, 65_535, 1 << 40, u64::MAX];
        for v in probes {
            let i = bucket_index(v);
            assert!(bucket_low(i) <= v, "low({i}) > {v}");
            assert!(v < bucket_high(i) || bucket_high(i) == u64::MAX, "{v} >= high({i})");
        }
    }

    #[test]
    fn relative_bucket_width_is_bounded() {
        for i in SUB..bucket_index(1 << 30) {
            let low = bucket_low(i);
            let high = bucket_high(i);
            assert!(high - low <= low / SUB as u64 + 1, "bucket {i}: [{low}, {high})");
        }
    }

    #[test]
    fn percentiles_of_known_distribution() {
        let mut h = Hist::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 100);
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(100.0), 100);
        // p50 -> 50th smallest = 50, bucket [48, 56) -> reported 48
        let p50 = h.percentile(50.0);
        assert!(p50 <= 50 && 50 < bucket_high(bucket_index(p50)), "p50={p50}");
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = Hist::default();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut both = Hist::new();
        for v in [3u64, 9, 81, 6561] {
            a.record(v);
            both.record(v);
        }
        for v in [1u64, 100, 10_000] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
    }

    #[test]
    fn registry_handles_share_cells() {
        let reg = MetricsRegistry::new();
        reg.counter("x.calls").add(2);
        reg.counter("x.calls").inc();
        reg.gauge("x.depth").set(-3);
        reg.histogram("x.us").record(7);
        let snap = reg.snapshot();
        assert_eq!(snap.counters.get("x.calls"), Some(&3));
        assert_eq!(snap.gauges.get("x.depth"), Some(&-3));
        assert_eq!(snap.hists.get("x.us").map(Hist::count), Some(1));
        reg.reset();
        assert!(reg.snapshot().is_empty());
    }
}

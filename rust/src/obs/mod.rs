//! Observability: metrics registry, structured trace spans with Chrome
//! trace-event export, and the leveled stderr logger.
//!
//! Three dependency-free pillars, mirroring the registry idiom of
//! `quant::quantizer` and `analysis`:
//!
//! * [`metrics`] — counters, gauges, and log-bucketed latency histograms
//!   with p50/p90/p99 extraction.  Lock-cheap (atomics for scalars, one
//!   short mutex hold per histogram sample), snapshot-on-demand, and
//!   serialized through `util::json`.  A process-wide registry lives
//!   behind [`metrics::global`].
//! * [`trace`] — spans, instants, counter samples, and async begin/end
//!   pairs collected into a fixed-capacity ring buffer (oldest event
//!   dropped on overflow, drop count reported) and exported as Chrome
//!   trace-event JSON (`chrome://tracing`, <https://ui.perfetto.dev>).
//!   The clock is pluggable: production uses a monotonic wall clock,
//!   tests use [`trace::TestClock`] for deterministic ordering.
//! * [`log`] — the leveled stderr logger behind the crate-root
//!   `log_error!` / `log_warn!` / `log_info!` / `log_debug!` macros.
//!   Every progress print in the crate routes through it; stdout is
//!   reserved for machine-readable products (tables, report JSON,
//!   generated samples).
//!
//! # Metric naming convention
//!
//! Dotted lowercase paths, coarse-to-fine, with the unit as a suffix:
//! `<subsystem>.<what>[_<unit>][.<instance>]`.
//!
//! ```text
//! xla.executions              counter   graph dispatches through Runtime::run
//! xla.exec_us.<family>        histogram per-call wall time by graph family
//! pipeline.quant_us           histogram per-layer quantize phase
//! pipeline.tweak_us           histogram per-layer norm-tweak phase
//! tweak.iters                 counter   total tweak iterations run
//! engine.<lane>.queue_depth   gauge     live scheduler queue length
//! ```
//!
//! # Trace schema
//!
//! One Chrome process (`pid` 1); each named track is a `tid` with a
//! `thread_name` metadata record.  Producers emit:
//!
//! ```text
//! scheduler               instants: submit / admit / cache_hit / retire,
//!                         async b/e pair per request (id = submit seq)
//! lane:<name>/prefill     X spans: one per prefill dispatch
//! lane:<name>/decode      X spans: one per decode step dispatch
//! xla                     X spans: one per executable call, named by family
//! pipeline                X spans: per-layer phases (float_ref / quantize /
//!                         pack / tweak / advance) nested in a layer span
//! policy                  X spans: per-layer sensitivity scoring
//! tweak.loss              C samples: per-iteration norm-tweak loss
//! ```
//!
//! # `NORMTWEAK_LOG` levels
//!
//! `error` | `warn` | `info` (default) | `debug`.  When `NORMTWEAK_LOG`
//! is unset and `NT_QUIET` is set, the ceiling is `warn` — preserving the
//! historical meaning of `NT_QUIET` (silence per-layer progress) for CI
//! and test environments.

pub mod log;
pub mod metrics;
pub mod trace;

pub use log::Level;
pub use metrics::{
    bucket_high, bucket_index, bucket_low, global, Counter, Gauge, Hist, HistHandle,
    MetricsRegistry, MetricsSnapshot,
};
pub use trace::{
    graph_family, Clock, Phase, SpanGuard, TestClock, TraceCollector, TraceEvent, WallClock,
};

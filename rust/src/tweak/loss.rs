//! Tweaking losses — CPU references mirroring the L2 graphs.
//!
//! The deployed loss is Eq. 2 of the paper:
//! `L_dist = 1/C Σ_c ( |μ_f^c − μ_q^c| + |σ²_f^c − σ²_q^c| )`
//! (channel-wise mean/variance alignment — relaxed on purpose: point-wise
//! alignment overfits the calibration set, see Table 9).

// Justified unwraps: loss inputs are rank-checked before the channel split
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::tensor::{mean_var_channels, Tensor};

/// Eq. 2 on precomputed channel stats.
pub fn dist_loss_stats(mu_f: &[f32], var_f: &[f32], mu_q: &[f32], var_q: &[f32]) -> f32 {
    let c = mu_f.len();
    let mut total = 0.0f64;
    for i in 0..c {
        total += (mu_f[i] - mu_q[i]).abs() as f64;
        total += (var_f[i] - var_q[i]).abs() as f64;
    }
    (total / c as f64) as f32
}

/// Eq. 2 on raw activations (reduces to channel stats first).
pub fn dist_loss(y_f: &Tensor, y_q: &Tensor) -> Result<f32> {
    if y_f.shape != y_q.shape {
        return Err(Error::Shape(format!("{:?} vs {:?}", y_f.shape, y_q.shape)));
    }
    let (mu_f, var_f) = mean_var_channels(y_f)?;
    let (mu_q, var_q) = mean_var_channels(y_q)?;
    Ok(dist_loss_stats(&mu_f, &var_f, &mu_q, &var_q))
}

/// Point-wise MSE (Table 9 ablation).
pub fn mse_loss(y_f: &Tensor, y_q: &Tensor) -> Result<f32> {
    if y_f.shape != y_q.shape {
        return Err(Error::Shape(format!("{:?} vs {:?}", y_f.shape, y_q.shape)));
    }
    let (a, b) = (y_f.as_f32()?, y_q.as_f32()?);
    let s: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    Ok((s / a.len() as f64) as f32)
}

/// Channel-softmax KL divergence (Table 9 ablation).
pub fn kl_loss(y_f: &Tensor, y_q: &Tensor) -> Result<f32> {
    if y_f.shape != y_q.shape {
        return Err(Error::Shape(format!("{:?} vs {:?}", y_f.shape, y_q.shape)));
    }
    let c = *y_f.shape.last().unwrap();
    let (a, b) = (y_f.as_f32()?, y_q.as_f32()?);
    let rows = a.len() / c;
    let mut total = 0.0f64;
    for r in 0..rows {
        let fa = &a[r * c..(r + 1) * c];
        let fb = &b[r * c..(r + 1) * c];
        let lsa = log_softmax(fa);
        let lsb = log_softmax(fb);
        for i in 0..c {
            total += (lsa[i].exp() * (lsa[i] - lsb[i])) as f64;
        }
    }
    Ok((total / rows as f64) as f32)
}

fn log_softmax(x: &[f32]) -> Vec<f32> {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = m + x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
    x.iter().map(|&v| v - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_loss_zero_for_identical() {
        let x = Tensor::randn(&[4, 8], 1, 1.0);
        assert_eq!(dist_loss(&x, &x).unwrap(), 0.0);
        assert_eq!(mse_loss(&x, &x).unwrap(), 0.0);
        assert!(kl_loss(&x, &x).unwrap().abs() < 1e-6);
    }

    #[test]
    fn dist_loss_detects_mean_shift() {
        let x = Tensor::randn(&[64, 8], 1, 1.0);
        let mut shifted = x.clone();
        for v in shifted.as_f32_mut().unwrap() {
            *v += 0.5;
        }
        let l = dist_loss(&x, &shifted).unwrap();
        assert!((l - 0.5).abs() < 0.05, "loss {l}");
    }

    #[test]
    fn dist_loss_invariant_to_permutation_within_channel() {
        // Eq. 2 only sees per-channel stats: permuting rows changes nothing
        let x = Tensor::f32(&[3, 2], vec![1., 10., 2., 20., 3., 30.]);
        let y = Tensor::f32(&[3, 2], vec![3., 30., 1., 10., 2., 20.]);
        assert!(dist_loss(&x, &y).unwrap().abs() < 1e-6);
        // ... while MSE (point-wise) does change
        assert!(mse_loss(&x, &y).unwrap() > 0.5);
    }

    #[test]
    fn stats_form_matches_raw_form() {
        let a = Tensor::randn(&[32, 16], 2, 1.0);
        let b = Tensor::randn(&[32, 16], 3, 1.0);
        let (mu_f, var_f) = mean_var_channels(&a).unwrap();
        let (mu_q, var_q) = mean_var_channels(&b).unwrap();
        let l1 = dist_loss(&a, &b).unwrap();
        let l2 = dist_loss_stats(&mu_f, &var_f, &mu_q, &var_q);
        assert!((l1 - l2).abs() < 1e-6);
    }

    #[test]
    fn kl_positive_for_different() {
        let a = Tensor::randn(&[8, 16], 4, 1.0);
        let b = Tensor::randn(&[8, 16], 5, 1.0);
        assert!(kl_loss(&a, &b).unwrap() > 0.0);
    }
}

//! Adam state for the tweaked norm parameters.
//!
//! The actual update is fused inside the `tweak_step` XLA graph; this module
//! owns the m/v tensors between iterations and provides a CPU mirror of the
//! update rule so tests can verify the graph's arithmetic.

// Justified unwraps: optimizer state tensors are created f32 by `new` and stay
// f32; `as_f32` on them cannot fail
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::tensor::Tensor;

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Adam moments for one layer's tweakable parameters.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// 1-based timestep (as the graph expects in its `t` input)
    pub t: f32,
}

impl AdamState {
    /// Zero-initialized state for parameter vectors of length `d`.
    pub fn new(n_params: usize, d: usize) -> Self {
        AdamState {
            m: (0..n_params).map(|_| Tensor::zeros(&[d])).collect(),
            v: (0..n_params).map(|_| Tensor::zeros(&[d])).collect(),
            t: 1.0,
        }
    }

    pub fn advance(&mut self) {
        self.t += 1.0;
    }

    /// CPU mirror of one Adam update (test oracle for the XLA graph).
    pub fn apply_cpu(&mut self, theta: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let bc1 = 1.0 - B1.powf(self.t);
        let bc2 = 1.0 - B2.powf(self.t);
        for i in 0..theta.len() {
            let g = grads[i].as_f32().unwrap();
            let m = self.m[i].as_f32_mut().unwrap();
            let v = self.v[i].as_f32_mut().unwrap();
            let th = theta[i].as_f32_mut().unwrap();
            for j in 0..th.len() {
                m[j] = B1 * m[j] + (1.0 - B1) * g[j];
                v[j] = B2 * v[j] + (1.0 - B2) * g[j] * g[j];
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                th[j] -= lr * mhat / (vhat.sqrt() + EPS);
            }
        }
        self.advance();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_against_gradient() {
        let mut st = AdamState::new(1, 4);
        let mut theta = vec![Tensor::zeros(&[4])];
        let grads = vec![Tensor::f32(&[4], vec![1.0, -1.0, 2.0, 0.0])];
        st.apply_cpu(&mut theta, &grads, 0.1);
        let th = theta[0].as_f32().unwrap();
        // adam's first step is ~ -lr * sign(g)
        assert!((th[0] + 0.1).abs() < 1e-3);
        assert!((th[1] - 0.1).abs() < 1e-3);
        assert!(th[3] == 0.0);
        assert_eq!(st.t, 2.0);
    }

    #[test]
    fn repeated_steps_converge_quadratic() {
        // minimize (x - 3)^2 with adam; should approach 3
        let mut st = AdamState::new(1, 1);
        let mut theta = vec![Tensor::zeros(&[1])];
        for _ in 0..500 {
            let x = theta[0].as_f32().unwrap()[0];
            let g = vec![Tensor::f32(&[1], vec![2.0 * (x - 3.0)])];
            st.apply_cpu(&mut theta, &g, 0.05);
        }
        let x = theta[0].as_f32().unwrap()[0];
        assert!((x - 3.0).abs() < 0.1, "x = {x}");
    }
}

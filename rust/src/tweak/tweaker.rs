//! The per-layer tweak loop (Algorithm 1, lines 11–15), driving the fused
//! `tweak_step` XLA executable: quant-forward + channel stats + L_dist +
//! backward (norm params only) + Adam — one PJRT call per iteration.

use crate::error::{Error, Result};
use crate::model::{NormKind, QuantizedBlock};
use crate::obs::global;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::adam::AdamState;

/// Which tweak loss to use (Table 9: Dist wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Eq. 2 channel-wise distribution loss (the paper's choice)
    Dist,
    /// point-wise MSE ablation
    Mse,
    /// channel-softmax KL ablation
    Kl,
}

impl LossKind {
    /// Parse a config/CLI loss name (`dist` | `mse` | `kl`).
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "dist" => Ok(LossKind::Dist),
            "mse" => Ok(LossKind::Mse),
            "kl" => Ok(LossKind::Kl),
            other => Err(Error::Config(format!("unknown loss {other} (dist | mse | kl)"))),
        }
    }

    /// The canonical config/CLI name (inverse of [`LossKind::from_str`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            LossKind::Dist => "dist",
            LossKind::Mse => "mse",
            LossKind::Kl => "kl",
        }
    }

    /// The `tweak_step*` graph this loss drives, at the scheme's grain.
    ///
    /// Grain-honest for the ablation losses too: `Mse`/`Kl` used to
    /// hardcode `.pc`, which fed per-channel graphs grouped scale tensors
    /// and died at PJRT argument mismatch. Whether the named graph was
    /// actually exported is checked up front by the pipeline
    /// (`validate_scheme_artifacts`), not discovered mid-tweak here.
    pub fn graph_name(&self, group_tag: &str) -> String {
        match self {
            LossKind::Dist => format!("tweak_step.{group_tag}"),
            LossKind::Mse => format!("tweak_step_mse.{group_tag}"),
            LossKind::Kl => format!("tweak_step_kl.{group_tag}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_names_roundtrip() {
        for k in [LossKind::Dist, LossKind::Mse, LossKind::Kl] {
            assert_eq!(LossKind::from_str(k.as_str()).unwrap(), k);
        }
        assert!(LossKind::from_str("zap").is_err());
    }

    #[test]
    fn graph_name_tracks_grain_for_all_losses() {
        assert_eq!(LossKind::Dist.graph_name("g32"), "tweak_step.g32");
        // the ablation losses used to hardcode `.pc` at every grain
        assert_eq!(LossKind::Mse.graph_name("g64"), "tweak_step_mse.g64");
        assert_eq!(LossKind::Kl.graph_name("pc"), "tweak_step_kl.pc");
    }
}

/// Tweaking hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TweakConfig {
    /// Adam steps on the calibration batch per layer (the paper's "Iters";
    /// small on purpose — this is tweaking, not finetuning)
    pub iters: usize,
    /// base learning rate (Eq. 3's lr_0)
    pub lr0: f32,
    /// layer scheduler slope (Eq. 3's `scale`)
    pub lr_scale: f32,
    pub loss: LossKind,
}

impl Default for TweakConfig {
    fn default() -> Self {
        // lr0/iters grid-searched on nt-small at W2g64 (EXPERIMENTS.md §W2):
        // {8,1e-3}→9.8%, {16,3e-3}→14.8%, {32,1e-2}→16.4% lambada-syn vs
        // 7.8% plain GPTQ.  The paper likewise grid-searches lr from 1e-5;
        // our models are ~1000x smaller and tolerate larger steps.
        TweakConfig { iters: 16, lr0: 3e-3, lr_scale: 1.0, loss: LossKind::Dist }
    }
}

/// Targets the loss aligns to (float-stream statistics or raw output).
#[derive(Debug, Clone)]
pub enum TweakTarget {
    /// per-channel mean/variance of the float block output (Dist loss)
    Stats { mu: Tensor, var: Tensor },
    /// the full float output tensor (MSE / KL ablations)
    Full { y_f: Tensor },
}

/// Result of tweaking one layer.
#[derive(Debug, Clone)]
pub struct TweakOutcome {
    /// loss value after each iteration
    pub losses: Vec<f32>,
    pub lr_used: f32,
}

/// Drives `tweak_step` for a (model, quant-grain) pair.
pub struct Tweaker<'rt> {
    pub runtime: &'rt Runtime,
    pub model: String,
    pub group_tag: String,
    pub config: TweakConfig,
}

impl<'rt> Tweaker<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        model: &str,
        group_tag: &str,
        config: TweakConfig,
    ) -> Self {
        Tweaker {
            runtime,
            model: model.to_string(),
            group_tag: group_tag.to_string(),
            config,
        }
    }

    /// Tweak one layer's norm parameters in place.
    ///
    /// `x` is the quantized stream input `qOut_{l-1}` (f32 [CB, S, d]);
    /// `lr` the layer-scheduled learning rate.
    pub fn tweak_layer(
        &self,
        blk: &mut QuantizedBlock,
        norm: NormKind,
        x: &Tensor,
        target: &TweakTarget,
        lr: f32,
    ) -> Result<TweakOutcome> {
        let graph = self.config.loss.graph_name(&self.group_tag);
        let n_np = norm.n_tweak_params();
        let d = blk.ln1_g.shape[0];
        let mut adam = AdamState::new(n_np, d);
        let lr_t = Tensor::f32(&[1], vec![lr]);
        let mut losses = Vec::with_capacity(self.config.iters);

        // codes/scales/biases are frozen across iterations: build once
        let frozen = FrozenQArgs::new(blk);

        for _ in 0..self.config.iters {
            let t_t = Tensor::f32(&[1], vec![adam.t]);
            let norm_params: Vec<Tensor> =
                blk.norm_params().into_iter().cloned().collect();
            let mut args: Vec<&Tensor> = Vec::with_capacity(8 + 16 + 2 * n_np);
            args.push(x);
            frozen.push_args(&norm_params, norm, &mut args);
            for m in &adam.m {
                args.push(m);
            }
            for v in &adam.v {
                args.push(v);
            }
            match target {
                TweakTarget::Stats { mu, var } => {
                    if self.config.loss != LossKind::Dist {
                        return Err(Error::Quant(
                            "stats target requires Dist loss".into(),
                        ));
                    }
                    args.push(mu);
                    args.push(var);
                }
                TweakTarget::Full { y_f } => {
                    if self.config.loss == LossKind::Dist {
                        return Err(Error::Quant(
                            "full target requires Mse/Kl loss".into(),
                        ));
                    }
                    args.push(y_f);
                }
            }
            args.push(&lr_t);
            args.push(&t_t);

            let mut outs = self.runtime.run(&self.model, &graph, &args)?;
            // outputs: theta[n_np], m[n_np], v[n_np], loss[1]
            if outs.len() != 3 * n_np + 1 {
                return Err(Error::Artifact(format!(
                    "{graph}: {} outputs, expected {}",
                    outs.len(),
                    3 * n_np + 1
                )));
            }
            let loss = outs.pop().unwrap().as_f32()?[0];
            let vs: Vec<Tensor> = outs.split_off(2 * n_np);
            let ms: Vec<Tensor> = outs.split_off(n_np);
            let thetas = outs;
            adam.m = ms;
            adam.v = vs;
            adam.advance();
            blk.set_norm_params(thetas)?;
            losses.push(loss);
            global().counter("tweak.iters").inc();
            if let Some(tr) = self.runtime.trace() {
                // one sample per Adam step — renders as the convergence
                // curve under the pipeline's tweak span
                tr.counter("tweak.loss", "loss", f64::from(loss));
            }
        }
        Ok(TweakOutcome { losses, lr_used: lr })
    }
}

/// The frozen (non-tweaked) quantized-weight argument tensors of one block,
/// unpacked once per layer.
struct FrozenQArgs {
    cqkv: Tensor,
    sqkv: Tensor,
    bqkv: Tensor,
    cproj: Tensor,
    sproj: Tensor,
    bproj: Tensor,
    cfc1: Tensor,
    sfc1: Tensor,
    bfc1: Tensor,
    cfc2: Tensor,
    sfc2: Tensor,
    bfc2: Tensor,
}

impl FrozenQArgs {
    fn new(blk: &QuantizedBlock) -> Self {
        FrozenQArgs {
            // owned one-shot unpacks: the tweaker must not populate the
            // model-lifetime serving cache (codes_tensor) just to tweak
            cqkv: blk.qkv.codes_tensor_owned(),
            sqkv: blk.qkv.scales.clone(),
            bqkv: blk.qkv.bias.clone(),
            cproj: blk.proj.codes_tensor_owned(),
            sproj: blk.proj.scales.clone(),
            bproj: blk.proj.bias.clone(),
            cfc1: blk.fc1.codes_tensor_owned(),
            sfc1: blk.fc1.scales.clone(),
            bfc1: blk.fc1.bias.clone(),
            cfc2: blk.fc2.codes_tensor_owned(),
            sfc2: blk.fc2.scales.clone(),
            bfc2: blk.fc2.bias.clone(),
        }
    }

    /// Push the full qweight argument list in AOT order, splicing in the
    /// current norm params.
    fn push_args<'a>(
        &'a self,
        norm_params: &'a [Tensor],
        norm: NormKind,
        args: &mut Vec<&'a Tensor>,
    ) {
        match norm {
            NormKind::LayerNorm => {
                args.push(&norm_params[0]); // ln1.g
                args.push(&norm_params[1]); // ln1.b
                args.extend([&self.cqkv, &self.sqkv, &self.bqkv,
                             &self.cproj, &self.sproj, &self.bproj]);
                args.push(&norm_params[2]); // ln2.g
                args.push(&norm_params[3]); // ln2.b
                args.extend([&self.cfc1, &self.sfc1, &self.bfc1,
                             &self.cfc2, &self.sfc2, &self.bfc2]);
            }
            NormKind::RmsNorm => {
                args.push(&norm_params[0]);
                args.extend([&self.cqkv, &self.sqkv, &self.bqkv,
                             &self.cproj, &self.sproj, &self.bproj]);
                args.push(&norm_params[1]);
                args.extend([&self.cfc1, &self.sfc1, &self.bfc1,
                             &self.cfc2, &self.sfc2, &self.bfc2]);
            }
        }
    }
}

//! Layer-level learning-rate scheduler (Eq. 3 of the paper):
//! `lr_i = lr_0 * (1 + scale * i / L)` — deeper layers get larger steps
//! because quantization error accumulates through the layer stack.

/// Step-increase scheduler over layer index.
#[derive(Debug, Clone, Copy)]
pub struct LayerLrScheduler {
    pub lr0: f32,
    pub scale: f32,
    pub n_layers: usize,
}

impl LayerLrScheduler {
    pub fn new(lr0: f32, scale: f32, n_layers: usize) -> Self {
        LayerLrScheduler { lr0, scale, n_layers }
    }

    /// Learning rate for layer `i` (0-based).
    pub fn lr(&self, layer: usize) -> f32 {
        self.lr0 * (1.0 + self.scale * layer as f32 / self.n_layers as f32)
    }
}

impl Default for LayerLrScheduler {
    /// Paper defaults: initial 1e-5 (grid-searched upward per model); we use
    /// a mildly larger default suited to the small models.
    fn default() -> Self {
        LayerLrScheduler { lr0: 1e-5, scale: 1.0, n_layers: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_layer_index() {
        let s = LayerLrScheduler::new(1e-5, 2.0, 8);
        let mut prev = 0.0;
        for i in 0..8 {
            let lr = s.lr(i);
            assert!(lr > prev);
            prev = lr;
        }
    }

    #[test]
    fn endpoints() {
        let s = LayerLrScheduler::new(1e-4, 1.0, 10);
        assert!((s.lr(0) - 1e-4).abs() < 1e-12);
        assert!((s.lr(10) - 2e-4).abs() < 1e-10); // hypothetical layer L
    }

    #[test]
    fn zero_scale_is_constant() {
        let s = LayerLrScheduler::new(3e-5, 0.0, 4);
        for i in 0..4 {
            assert_eq!(s.lr(i), 3e-5);
        }
    }
}

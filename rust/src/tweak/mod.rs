//! Norm Tweaking — the paper's contribution.
//!
//! * [`loss`] — the channel-wise distribution loss (Eq. 2) + the MSE/KL
//!   ablation losses (Table 9), CPU reference implementations.
//! * [`adam`] — Adam state management (the XLA `tweak_step` graph applies
//!   the update; this mirrors it for tests and owns the m/v tensors).
//! * [`scheduler`] — the layer-level learning-rate step scheduler (Eq. 3).
//! * [`tweaker`] — drives the fused `tweak_step` executable per layer
//!   (Algorithm 1 lines 11–15).

pub mod adam;
pub mod loss;
pub mod scheduler;
pub mod tweaker;

pub use scheduler::LayerLrScheduler;
pub use tweaker::{LossKind, TweakConfig, TweakOutcome, Tweaker};

//! `artifacts/manifest.json` — the contract between `aot.py` and the runtime
//! (parsed with the in-tree JSON parser; serde is unavailable offline).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use crate::analysis::hlo::TensorSig;
use crate::error::{Error, Result};
use crate::model::ModelConfig;
use crate::util::json::Json;

/// Shape + dtype of one graph input or output (as exported by aot.py).
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    /// The shared signature type this spec validates against — the same
    /// [`TensorSig`] the `graphs` lint parses out of the HLO text, so the
    /// runtime's per-call argument check and the static analysis can never
    /// disagree.  An unknown dtype string is an `Error::Artifact`.
    pub fn sig(&self) -> Result<TensorSig> {
        TensorSig::from_manifest(&self.shape, &self.dtype)
    }
}

/// One exported graph.
#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub model: String,
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    /// The exporter's *intended* result signature (`outputs` in the
    /// manifest).  Optional for back-compat: manifests written before the
    /// signature-recording exporter simply have none (empty), and the
    /// `graphs` lint downgrades to the HLO text alone.
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub norm: String,
}

/// Per-model KV-cache layout of the incremental-decode graphs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvSpec {
    /// number of (k, v) cache pairs — one per transformer block
    pub n_layer: usize,
    /// per-row per-layer cache shape `[n_head, seq, d_head]`
    pub shape: Vec<usize>,
}

/// The manifest's `decode` record: which batch buckets have one-token step
/// graphs (`embed_dec` / `block_dec[_q]` / `head_dec` plus the
/// `block_fwd_kv[_q]` prefill variants) and the cache layout per model.
///
/// The record is *optional*: a manifest exported with `--no-decode` simply
/// has none, and the runtime serves through the full-context recompute
/// fallback instead of failing.
#[derive(Debug, Clone)]
pub struct DecodeRecord {
    pub buckets: Vec<usize>,
    /// Slot-arena capacity: the fixed batch bucket every arena decode step
    /// runs at, and the leading dim of each layer's arena tensors.  Must be
    /// one of `buckets` (the step graphs only exist at exported buckets)
    /// and at least the largest of them (so any admitted batch fits).
    /// Defaults to the largest decode bucket when the manifest predates
    /// the field.
    pub slots: usize,
    /// model name -> cache layout
    pub caches: HashMap<String, KvSpec>,
}

impl DecodeRecord {
    /// Smallest decode bucket that fits `n` rows; the error lists what was
    /// exported so an over-provisioned scheduler is self-diagnosing.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets.iter().copied().filter(|&b| b >= n).min().ok_or_else(|| {
            Error::Artifact(format!(
                "decode batch {n} exceeds the largest exported decode bucket \
                 (exported: {}) — re-export with a larger bucket or lower the \
                 engine's max_batch",
                join_buckets(&self.buckets)
            ))
        })
    }
}

fn join_buckets(buckets: &[usize]) -> String {
    buckets.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ")
}

/// The parsed manifest plus the artifacts directory it came from.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub calib_batch: usize,
    pub buckets: Vec<usize>,
    /// Exported quantization grains: tag (`"pc"`, `"g32"`, ...) -> group
    /// size (0 = per-channel). Every tag has `block_fwd_q.{tag}.b*` and
    /// `tweak_step.{tag}` graph variants on disk; schemes with any other
    /// grain are rejected at pipeline startup via [`Self::validate_grain`].
    pub groups: BTreeMap<String, usize>,
    /// Incremental-decode contract; `None` when the export skipped the
    /// decode graphs (`--no-decode`) — generation then falls back to
    /// full-context recompute.
    pub decode: Option<DecodeRecord>,
    pub models: HashMap<String, ManifestModel>,
    pub graphs: Vec<GraphEntry>,
    index: HashMap<(String, String), usize>,
}

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| Error::Artifact(format!("manifest: missing key `{key}`")))
}

fn need_usize(j: &Json, key: &str) -> Result<usize> {
    need(j, key)?
        .as_usize()
        .ok_or_else(|| Error::Artifact(format!("manifest: `{key}` not a number")))
}

fn need_str(j: &Json, key: &str) -> Result<String> {
    Ok(need(j, key)?
        .as_str()
        .ok_or_else(|| Error::Artifact(format!("manifest: `{key}` not a string")))?
        .to_string())
}

/// Strict parse of a graph entry's `inputs`/`outputs` IoSpec list.
fn parse_io_list(v: &Json, what: &str) -> Result<Vec<IoSpec>> {
    let mut out = Vec::new();
    for i in v
        .as_arr()
        .ok_or_else(|| Error::Artifact(format!("{what} not an array")))?
    {
        let name = need_str(i, "name")?;
        let mut shape = Vec::new();
        for d in need(i, "shape")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("shape not an array".into()))?
        {
            shape.push(d.as_usize().ok_or_else(|| {
                Error::Artifact(format!("manifest: non-numeric dim in shape of `{name}`"))
            })?);
        }
        out.push(IoSpec { name, shape, dtype: need_str(i, "dtype")? });
    }
    Ok(out)
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "missing manifest.json in {} — run `make artifacts` ({e})",
                dir.display()
            ))
        })?;
        let root = Json::parse(&text).map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        if need_usize(&root, "format")? != 1 {
            return Err(Error::Artifact("manifest format != 1".into()));
        }
        let calib_batch = need_usize(&root, "calib_batch")?;
        let mut buckets = Vec::new();
        for b in need(&root, "buckets")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("buckets not an array".into()))?
        {
            // strict: a silently dropped bucket would shift every
            // bucket_for() decision instead of failing the load
            buckets.push(b.as_usize().ok_or_else(|| {
                Error::Artifact("manifest: non-numeric entry in `buckets`".into())
            })?);
        }
        if buckets.is_empty() {
            return Err(Error::Artifact("manifest: empty `buckets`".into()));
        }

        let mut groups = BTreeMap::new();
        for (tag, size) in need(&root, "groups")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("groups not an object".into()))?
        {
            let size = size.as_usize().ok_or_else(|| {
                Error::Artifact(format!("manifest: group `{tag}` not a number"))
            })?;
            // the tag is derived from the size at lookup time
            // (QuantScheme::group_tag), so a drifted record like
            // {"g32": 64} would pass validation here and die at PJRT
            // shape mismatch mid-run — reject it at load instead
            let expected = if size == 0 { "pc".to_string() } else { format!("g{size}") };
            if *tag != expected {
                return Err(Error::Artifact(format!(
                    "manifest: group tag `{tag}` inconsistent with size {size} \
                     (expected `{expected}`)"
                )));
            }
            groups.insert(tag.clone(), size);
        }
        if groups.is_empty() {
            return Err(Error::Artifact("manifest: empty `groups`".into()));
        }

        // `decode` is feature-gating, not load-gating: absent means the
        // incremental-decode graphs were not exported (recompute fallback),
        // while a *present but malformed* record is rejected strictly — a
        // half-parsed cache shape would surface as a PJRT shape mismatch
        // in the middle of a served request
        let decode = match root.get("decode") {
            None => None,
            Some(d) => {
                let mut dbuckets = Vec::new();
                for b in need(d, "buckets")?
                    .as_arr()
                    .ok_or_else(|| Error::Artifact("decode.buckets not an array".into()))?
                {
                    dbuckets.push(b.as_usize().ok_or_else(|| {
                        Error::Artifact("manifest: non-numeric entry in `decode.buckets`".into())
                    })?);
                }
                if dbuckets.is_empty() {
                    return Err(Error::Artifact("manifest: empty `decode.buckets`".into()));
                }
                let mut caches = HashMap::new();
                for (name, c) in need(d, "caches")?
                    .as_obj()
                    .ok_or_else(|| Error::Artifact("decode.caches not an object".into()))?
                {
                    let mut shape = Vec::new();
                    for dim in need(c, "shape")?.as_arr().ok_or_else(|| {
                        Error::Artifact(format!("decode cache shape of `{name}` not an array"))
                    })? {
                        shape.push(dim.as_usize().ok_or_else(|| {
                            Error::Artifact(format!(
                                "manifest: non-numeric dim in decode cache shape of `{name}`"
                            ))
                        })?);
                    }
                    if shape.len() != 3 {
                        return Err(Error::Artifact(format!(
                            "decode cache shape of `{name}` must be [n_head, seq, d_head], \
                             got {} dims",
                            shape.len()
                        )));
                    }
                    caches.insert(
                        name.clone(),
                        KvSpec { n_layer: need_usize(c, "n_layer")?, shape },
                    );
                }
                let dec_max = dbuckets.iter().copied().max().unwrap_or(0);
                // `slots` sizes the slot arena; older manifests don't carry
                // it, and the only always-valid value is the largest decode
                // bucket, so that's the default
                let slots = match d.get("slots") {
                    None => dec_max,
                    Some(s) => s.as_usize().ok_or_else(|| {
                        Error::Artifact("manifest: `decode.slots` not a number".into())
                    })?,
                };
                if slots < dec_max {
                    return Err(Error::Artifact(format!(
                        "decode.slots = {slots} is smaller than the largest decode \
                         bucket {dec_max} — the arena could not hold a full step \
                         batch; re-run the AOT export"
                    )));
                }
                if !dbuckets.contains(&slots) {
                    return Err(Error::Artifact(format!(
                        "decode.slots = {slots} has no exported step graph \
                         (decode buckets: {}) — arena steps run at the `slots` \
                         bucket; re-run the AOT export",
                        join_buckets(&dbuckets)
                    )));
                }
                let record = DecodeRecord { buckets: dbuckets, slots, caches };
                // the scheduler chunks decode steps by the *main* bucket
                // cap; a decode record that cannot fit the largest main
                // bucket would pass load and then fail mid-request on the
                // first full-size step — reject the contract gap here
                let main_max = buckets.iter().copied().max().unwrap_or(0);
                if record.buckets.iter().copied().max().unwrap_or(0) < main_max {
                    return Err(Error::Artifact(format!(
                        "decode buckets ({}) cannot fit the largest exported \
                         batch bucket {main_max} — re-run the AOT export with \
                         matching bucket sets",
                        join_buckets(&record.buckets)
                    )));
                }
                Some(record)
            }
        };

        let mut models = HashMap::new();
        for (name, m) in need(&root, "models")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("models not an object".into()))?
        {
            models.insert(
                name.clone(),
                ManifestModel {
                    n_layer: need_usize(m, "n_layer")?,
                    d_model: need_usize(m, "d_model")?,
                    n_head: need_usize(m, "n_head")?,
                    d_ff: need_usize(m, "d_ff")?,
                    vocab: need_usize(m, "vocab")?,
                    seq: need_usize(m, "seq")?,
                    norm: need_str(m, "norm")?,
                },
            );
        }

        let mut graphs = Vec::new();
        for g in need(&root, "graphs")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("graphs not an array".into()))?
        {
            let inputs = parse_io_list(need(g, "inputs")?, "inputs")?;
            // `outputs` is the signature-recording exporter's addition;
            // absent means an older manifest (empty list), present means
            // strict parse like `inputs`
            let outputs = match g.get("outputs") {
                None => Vec::new(),
                Some(o) => parse_io_list(o, "outputs")?,
            };
            graphs.push(GraphEntry {
                model: need_str(g, "model")?,
                name: need_str(g, "name")?,
                file: need_str(g, "file")?,
                inputs,
                outputs,
            });
        }

        let mut index = HashMap::new();
        for (i, g) in graphs.iter().enumerate() {
            index.insert((g.model.clone(), g.name.clone()), i);
        }
        Ok(ArtifactManifest { dir, calib_batch, buckets, groups, decode, models, graphs, index })
    }

    /// The decode contract for one model: `Some` iff the export produced
    /// incremental-decode graphs *and* recorded this model's cache layout.
    pub fn decode_for(&self, model: &str) -> Option<&KvSpec> {
        self.decode.as_ref().and_then(|d| d.caches.get(model))
    }

    /// Verify a model's decode cache spec against its architecture —
    /// runners call this at construction, so a drifted record (wrong
    /// `n_layer` or cache shape) fails at startup with a re-export hint,
    /// not as a PJRT shape mismatch mid-request.  No-op without a record.
    pub fn verify_decode(&self, cfg: &ModelConfig) -> Result<()> {
        let Some(spec) = self.decode_for(&cfg.name) else {
            return Ok(());
        };
        let want = vec![cfg.n_head, cfg.seq, cfg.d_head()];
        if spec.n_layer != cfg.n_layer || spec.shape != want {
            return Err(Error::Artifact(format!(
                "decode cache spec of model {} (n_layer {}, shape {:?}) does not \
                 match the architecture (n_layer {}, shape {want:?}) — re-run \
                 the AOT export",
                cfg.name, spec.n_layer, spec.shape, cfg.n_layer
            )));
        }
        Ok(())
    }

    /// The exported grain tags, sorted (`["g32", "g64", "pc"]`).
    pub fn grain_tags(&self) -> Vec<&str> {
        self.groups.keys().map(|s| s.as_str()).collect()
    }

    /// Whether `tag` has exported graph variants.
    pub fn has_grain(&self, tag: &str) -> bool {
        self.groups.contains_key(tag)
    }

    /// Reject a grain tag with no exported graphs — the fail-fast gate the
    /// pipeline runs at startup instead of dying mid-tweak at graph lookup.
    pub fn validate_grain(&self, tag: &str) -> Result<()> {
        if self.has_grain(tag) {
            return Ok(());
        }
        Err(Error::Artifact(format!(
            "quant grain `{tag}` has no exported graphs (manifest exports: {}) — \
             re-run the AOT export with `--groups` including `{tag}`",
            self.grain_tags().join(", ")
        )))
    }

    /// Largest exported batch bucket (manifests always have ≥ 1 bucket).
    pub fn max_bucket(&self) -> Option<usize> {
        self.buckets.iter().copied().max()
    }

    /// Find a graph by (model, graph-name).
    pub fn graph(&self, model: &str, name: &str) -> Result<&GraphEntry> {
        self.index
            .get(&(model.to_string(), name.to_string()))
            .map(|&i| &self.graphs[i])
            .ok_or_else(|| Error::Artifact(format!("no graph {model}.{name} in manifest")))
    }

    /// Absolute path of a graph's HLO text file.
    pub fn path_of(&self, g: &GraphEntry) -> PathBuf {
        self.dir.join(&g.file)
    }

    /// The models the manifest records, sorted (for self-diagnosing
    /// "not in manifest" errors, like [`Self::grain_tags`]).
    pub fn model_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Field-by-field comparison of a Rust-side model config against the
    /// manifest's record: `None` when the model is absent, otherwise every
    /// drifted field as `(field, manifest_value, registry_value)` (empty =
    /// the records agree).  The lint layer reports each drift separately;
    /// [`Self::verify_model`] collapses them into one error.
    pub fn model_field_mismatches(
        &self,
        cfg: &ModelConfig,
    ) -> Option<Vec<(&'static str, String, String)>> {
        let m = self.models.get(&cfg.name)?;
        let norm = match cfg.norm {
            crate::model::NormKind::LayerNorm => "layernorm",
            crate::model::NormKind::RmsNorm => "rmsnorm",
        };
        let pairs = [
            ("n_layer", m.n_layer, cfg.n_layer),
            ("d_model", m.d_model, cfg.d_model),
            ("n_head", m.n_head, cfg.n_head),
            ("d_ff", m.d_ff, cfg.d_ff),
            ("vocab", m.vocab, cfg.vocab),
            ("seq", m.seq, cfg.seq),
        ];
        let mut diffs: Vec<(&'static str, String, String)> = pairs
            .iter()
            .filter(|(_, a, b)| a != b)
            .map(|&(f, a, b)| (f, a.to_string(), b.to_string()))
            .collect();
        if m.norm != norm {
            diffs.push(("norm", m.norm.clone(), norm.to_string()));
        }
        Some(diffs)
    }

    /// Verify a Rust-side model config against the manifest's record.
    /// Self-diagnosing: an absent model lists what *is* recorded, and a
    /// drifted one names every disagreeing field with both values.
    pub fn verify_model(&self, cfg: &ModelConfig) -> Result<()> {
        let diffs = self.model_field_mismatches(cfg).ok_or_else(|| {
            Error::Artifact(format!(
                "model {} not in manifest (manifest records: {})",
                cfg.name,
                self.model_names().join(", ")
            ))
        })?;
        if diffs.is_empty() {
            return Ok(());
        }
        let detail = diffs
            .iter()
            .map(|(f, m, r)| format!("{f}: manifest={m} registry={r}"))
            .collect::<Vec<_>>()
            .join(", ");
        Err(Error::Artifact(format!(
            "model {} config mismatch between Rust registry and manifest \
             ({detail}) — re-run the AOT export or fix the registry",
            cfg.name
        )))
    }

    /// Smallest exported batch bucket that fits `n`.  The error lists the
    /// exported buckets (like [`Self::validate_grain`] lists grains) so an
    /// oversize-batch failure is self-diagnosing.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets.iter().copied().filter(|&b| b >= n).min().ok_or_else(|| {
            Error::Artifact(format!(
                "batch {n} exceeds the largest exported bucket (exported: {}) — \
                 re-run the AOT export with a bucket >= {n} or split the batch",
                join_buckets(&self.buckets)
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, json: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), json).unwrap();
    }

    fn write_fixture(dir: &Path) {
        let json = r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0, "g64": 64},
            "models": {"nt-tiny": {"n_layer": 2, "d_model": 128, "n_head": 4,
                        "d_ff": 512, "vocab": 2048, "seq": 128, "norm": "layernorm"}},
            "graphs": [{"model": "nt-tiny", "name": "embed.b8",
                        "file": "nt-tiny.embed.b8.hlo.txt",
                        "inputs": [{"name": "tokens", "shape": [8, 128], "dtype": "i32"}]}]
        }"#;
        write_manifest(dir, json);
    }

    #[test]
    fn load_and_lookup() {
        let dir = std::env::temp_dir().join("nt_manifest_test");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.calib_batch, 32);
        let g = m.graph("nt-tiny", "embed.b8").unwrap();
        assert_eq!(g.inputs[0].dtype, "i32");
        assert_eq!(g.inputs[0].shape, vec![8, 128]);
        assert!(m.graph("nt-tiny", "nope").is_err());
    }

    #[test]
    fn outputs_parsed_when_present_and_optional_when_absent() {
        // the base fixture has no `outputs`: back-compat means empty, not Err
        let dir = std::env::temp_dir().join("nt_manifest_outputs_absent");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.graph("nt-tiny", "embed.b8").unwrap().outputs.is_empty());

        let dir = std::env::temp_dir().join("nt_manifest_outputs");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0}, "models": {},
            "graphs": [{"model": "m", "name": "embed.b8", "file": "f",
                        "inputs": [{"name": "tokens", "shape": [8, 128],
                                    "dtype": "i32"}],
                        "outputs": [{"name": "out0", "shape": [8, 128, 64],
                                     "dtype": "f32"}]}]
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        let g = m.graph("m", "embed.b8").unwrap();
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.outputs[0].shape, vec![8, 128, 64]);
        // the shared-signature bridge the runtime validates through
        let sig = g.outputs[0].sig().unwrap();
        assert_eq!(sig.render(), "f32[8,128,64]");
        assert!(IoSpec { name: "x".into(), shape: vec![1], dtype: "f16".into() }
            .sig()
            .is_err());

        // present-but-malformed outputs fail the load like inputs do
        let dir = std::env::temp_dir().join("nt_manifest_outputs_bad");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0}, "models": {},
            "graphs": [{"model": "m", "name": "g", "file": "f",
                        "inputs": [],
                        "outputs": [{"name": "out0", "shape": [8, null],
                                     "dtype": "f32"}]}]
        }"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn verify_model_checks_fields() {
        let dir = std::env::temp_dir().join("nt_manifest_test2");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        m.verify_model(&cfg).unwrap();
        assert_eq!(m.model_field_mismatches(&cfg), Some(vec![]));
        let mut bad = cfg;
        bad.d_model = 96;
        // self-diagnosing: the error names the drifted field and both values
        let err = m.verify_model(&bad).unwrap_err().to_string();
        assert!(err.contains("d_model") && err.contains("128") && err.contains("96"), "{err}");
        // absent model lists what the manifest does record
        let other = ModelConfig::builtin("nt-small").unwrap();
        assert!(m.model_field_mismatches(&other).is_none());
        let err = m.verify_model(&other).unwrap_err().to_string();
        assert!(err.contains("not in manifest") && err.contains("nt-tiny"), "{err}");
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("nt_manifest_test3");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 8);
        assert_eq!(m.bucket_for(8).unwrap(), 8);
        assert_eq!(m.bucket_for(9).unwrap(), 32);
        let err = m.bucket_for(33).unwrap_err().to_string();
        // self-diagnosing: the error names the buckets that *are* exported
        assert!(err.contains("33") && err.contains("8, 32"), "{err}");
    }

    #[test]
    fn decode_record_absent_is_feature_unavailable_not_error() {
        // the base fixture has no `decode` key: load must succeed and the
        // accessors report the feature as unavailable (recompute fallback)
        let dir = std::env::temp_dir().join("nt_manifest_nodecode");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.decode.is_none());
        assert!(m.decode_for("nt-tiny").is_none());
    }

    #[test]
    fn decode_record_parsed_strictly() {
        let dir = std::env::temp_dir().join("nt_manifest_decode");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8, 32],
                       "caches": {"nt-tiny": {"n_layer": 2,
                                              "shape": [4, 128, 32]}}}
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        let spec = m.decode_for("nt-tiny").unwrap();
        assert_eq!(spec.n_layer, 2);
        assert_eq!(spec.shape, vec![4, 128, 32]);
        assert!(m.decode_for("nt-medium").is_none());
        let dec = m.decode.as_ref().unwrap();
        assert_eq!(dec.bucket_for(3).unwrap(), 8);
        assert_eq!(dec.bucket_for(9).unwrap(), 32);
        let err = dec.bucket_for(40).unwrap_err().to_string();
        assert!(err.contains("8, 32"), "{err}");
        // a record without `slots` defaults to the largest decode bucket
        assert_eq!(dec.slots, 32);
    }

    #[test]
    fn decode_slots_parsed_and_validated() {
        // explicit slots equal to the largest decode bucket loads
        let dir = std::env::temp_dir().join("nt_manifest_slots_ok");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8, 32], "slots": 32, "caches": {}}
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.decode.as_ref().unwrap().slots, 32);

        // slots smaller than the largest decode bucket cannot hold a full
        // step batch
        let dir = std::env::temp_dir().join("nt_manifest_slots_small");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8, 32], "slots": 8, "caches": {}}
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("decode.slots") && err.contains("32"), "{err}");

        // slots outside the decode bucket set has no step graph to run at
        let dir = std::env::temp_dir().join("nt_manifest_slots_nograph");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8, 32], "slots": 64, "caches": {}}
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("no exported step graph"), "{err}");

        // non-numeric slots is a strict parse error
        let dir = std::env::temp_dir().join("nt_manifest_slots_nan");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8, 32], "slots": "many", "caches": {}}
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("decode.slots"), "{err}");
    }

    #[test]
    fn decode_spec_verified_against_architecture() {
        let dir = std::env::temp_dir().join("nt_manifest_decodespec");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0},
            "models": {"nt-tiny": {"n_layer": 2, "d_model": 128, "n_head": 4,
                        "d_ff": 512, "vocab": 2048, "seq": 128,
                        "norm": "layernorm"}},
            "graphs": [],
            "decode": {"buckets": [8, 32],
                       "caches": {"nt-tiny": {"n_layer": 2,
                                              "shape": [4, 128, 32]}}}
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        m.verify_decode(&cfg).unwrap();
        // a model without a record verifies trivially (recompute fallback)
        let other = ModelConfig::builtin("nt-small").unwrap();
        m.verify_decode(&other).unwrap();
        // drifted spec (wrong n_layer / wrong shape) fails at startup
        let dir = std::env::temp_dir().join("nt_manifest_decodespec_bad");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8],
                       "caches": {"nt-tiny": {"n_layer": 3,
                                              "shape": [4, 128, 32]}}}
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        let err = m.verify_decode(&cfg).unwrap_err().to_string();
        assert!(err.contains("nt-tiny") && err.contains("re-run"), "{err}");
    }

    #[test]
    fn decode_buckets_must_fit_largest_main_bucket() {
        // the scheduler chunks steps by the main bucket cap: a smaller
        // decode bucket set would fail mid-request, so it fails the load
        let dir = std::env::temp_dir().join("nt_manifest_decodebuckets");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0}, "models": {}, "graphs": [],
            "decode": {"buckets": [8], "caches": {}}
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("decode buckets") && err.contains("32"), "{err}");
    }

    #[test]
    fn malformed_decode_record_rejected() {
        // present-but-broken must fail the load, not limp into a PJRT
        // shape mismatch mid-request
        let cases = [
            // non-numeric bucket
            r#""decode": {"buckets": [8, "32"], "caches": {}}"#,
            // empty buckets
            r#""decode": {"buckets": [], "caches": {}}"#,
            // missing caches key
            r#""decode": {"buckets": [8]}"#,
            // wrong cache rank
            r#""decode": {"buckets": [8],
                "caches": {"m": {"n_layer": 2, "shape": [4, 128]}}}"#,
            // non-numeric shape dim
            r#""decode": {"buckets": [8],
                "caches": {"m": {"n_layer": 2, "shape": [4, null, 32]}}}"#,
            // missing n_layer
            r#""decode": {"buckets": [8],
                "caches": {"m": {"shape": [4, 128, 32]}}}"#,
        ];
        for (i, frag) in cases.iter().enumerate() {
            let dir = std::env::temp_dir().join(format!("nt_manifest_baddec{i}"));
            write_manifest(
                &dir,
                &format!(
                    r#"{{"format": 1, "calib_batch": 32, "buckets": [8],
                        "groups": {{"pc": 0}}, "models": {{}}, "graphs": [],
                        {frag}}}"#
                ),
            );
            assert!(ArtifactManifest::load(&dir).is_err(), "case {i} must be rejected");
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactManifest::load("/definitely/missing").is_err());
    }

    #[test]
    fn groups_parsed_and_grain_validated() {
        let dir = std::env::temp_dir().join("nt_manifest_groups");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.groups.get("pc"), Some(&0));
        assert_eq!(m.groups.get("g64"), Some(&64));
        assert_eq!(m.grain_tags(), vec!["g64", "pc"]);
        assert!(m.has_grain("g64") && !m.has_grain("g128"));
        m.validate_grain("pc").unwrap();
        let err = m.validate_grain("g128").unwrap_err().to_string();
        assert!(err.contains("g128") && err.contains("g64, pc"), "{err}");
        assert_eq!(m.max_bucket(), Some(32));
    }

    #[test]
    fn multi_grain_manifest_loads() {
        let dir = std::env::temp_dir().join("nt_manifest_multigrain");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0, "g32": 32, "g64": 64, "g128": 128},
            "models": {}, "graphs": []
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.grain_tags(), vec!["g128", "g32", "g64", "pc"]);
        m.validate_grain("g32").unwrap();
        m.validate_grain("g128").unwrap();
    }

    #[test]
    fn malformed_buckets_rejected() {
        // a dropped bucket used to silently shift every bucket_for() answer
        let dir = std::env::temp_dir().join("nt_manifest_badbucket");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, "32"],
            "groups": {"pc": 0}, "models": {}, "graphs": []
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("buckets"), "{err}");

        // empty buckets would make every batch oversized at serve time
        let dir = std::env::temp_dir().join("nt_manifest_emptybuckets");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [],
                "groups": {"pc": 0}, "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("buckets"), "{err}");
    }

    #[test]
    fn malformed_or_missing_groups_rejected() {
        let dir = std::env::temp_dir().join("nt_manifest_badgroup");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0, "g64": "sixty-four"}, "models": {}, "graphs": []
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("g64"), "{err}");

        let dir = std::env::temp_dir().join("nt_manifest_nogroups");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("groups"), "{err}");

        let dir = std::env::temp_dir().join("nt_manifest_emptygroups");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {}, "models": {}, "graphs": []}"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());

        // a drifted tag↔size pair would pass grain validation and then die
        // at PJRT shape mismatch mid-run
        let dir = std::env::temp_dir().join("nt_manifest_drifted");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"g32": 64}, "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("`g32`") && err.contains("64"), "{err}");
    }

    #[test]
    fn malformed_shape_rejected() {
        let dir = std::env::temp_dir().join("nt_manifest_badshape");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0}, "models": {},
            "graphs": [{"model": "m", "name": "g", "file": "f",
                        "inputs": [{"name": "x", "shape": [8, null],
                                    "dtype": "f32"}]}]
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("shape") && err.contains("`x`"), "{err}");
    }
}

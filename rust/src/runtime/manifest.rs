//! `artifacts/manifest.json` — the contract between `aot.py` and the runtime
//! (parsed with the in-tree JSON parser; serde is unavailable offline).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::model::ModelConfig;
use crate::util::json::Json;

/// Shape + dtype of one graph input (as exported by aot.py).
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One exported graph.
#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub model: String,
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub norm: String,
}

/// The parsed manifest plus the artifacts directory it came from.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub calib_batch: usize,
    pub buckets: Vec<usize>,
    /// Exported quantization grains: tag (`"pc"`, `"g32"`, ...) -> group
    /// size (0 = per-channel). Every tag has `block_fwd_q.{tag}.b*` and
    /// `tweak_step.{tag}` graph variants on disk; schemes with any other
    /// grain are rejected at pipeline startup via [`Self::validate_grain`].
    pub groups: BTreeMap<String, usize>,
    pub models: HashMap<String, ManifestModel>,
    pub graphs: Vec<GraphEntry>,
    index: HashMap<(String, String), usize>,
}

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| Error::Artifact(format!("manifest: missing key `{key}`")))
}

fn need_usize(j: &Json, key: &str) -> Result<usize> {
    need(j, key)?
        .as_usize()
        .ok_or_else(|| Error::Artifact(format!("manifest: `{key}` not a number")))
}

fn need_str(j: &Json, key: &str) -> Result<String> {
    Ok(need(j, key)?
        .as_str()
        .ok_or_else(|| Error::Artifact(format!("manifest: `{key}` not a string")))?
        .to_string())
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "missing manifest.json in {} — run `make artifacts` ({e})",
                dir.display()
            ))
        })?;
        let root = Json::parse(&text).map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        if need_usize(&root, "format")? != 1 {
            return Err(Error::Artifact("manifest format != 1".into()));
        }
        let calib_batch = need_usize(&root, "calib_batch")?;
        let mut buckets = Vec::new();
        for b in need(&root, "buckets")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("buckets not an array".into()))?
        {
            // strict: a silently dropped bucket would shift every
            // bucket_for() decision instead of failing the load
            buckets.push(b.as_usize().ok_or_else(|| {
                Error::Artifact("manifest: non-numeric entry in `buckets`".into())
            })?);
        }
        if buckets.is_empty() {
            return Err(Error::Artifact("manifest: empty `buckets`".into()));
        }

        let mut groups = BTreeMap::new();
        for (tag, size) in need(&root, "groups")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("groups not an object".into()))?
        {
            let size = size.as_usize().ok_or_else(|| {
                Error::Artifact(format!("manifest: group `{tag}` not a number"))
            })?;
            // the tag is derived from the size at lookup time
            // (QuantScheme::group_tag), so a drifted record like
            // {"g32": 64} would pass validation here and die at PJRT
            // shape mismatch mid-run — reject it at load instead
            let expected = if size == 0 { "pc".to_string() } else { format!("g{size}") };
            if *tag != expected {
                return Err(Error::Artifact(format!(
                    "manifest: group tag `{tag}` inconsistent with size {size} \
                     (expected `{expected}`)"
                )));
            }
            groups.insert(tag.clone(), size);
        }
        if groups.is_empty() {
            return Err(Error::Artifact("manifest: empty `groups`".into()));
        }

        let mut models = HashMap::new();
        for (name, m) in need(&root, "models")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("models not an object".into()))?
        {
            models.insert(
                name.clone(),
                ManifestModel {
                    n_layer: need_usize(m, "n_layer")?,
                    d_model: need_usize(m, "d_model")?,
                    n_head: need_usize(m, "n_head")?,
                    d_ff: need_usize(m, "d_ff")?,
                    vocab: need_usize(m, "vocab")?,
                    seq: need_usize(m, "seq")?,
                    norm: need_str(m, "norm")?,
                },
            );
        }

        let mut graphs = Vec::new();
        for g in need(&root, "graphs")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("graphs not an array".into()))?
        {
            let mut inputs = Vec::new();
            for i in need(g, "inputs")?
                .as_arr()
                .ok_or_else(|| Error::Artifact("inputs not an array".into()))?
            {
                let name = need_str(i, "name")?;
                let mut shape = Vec::new();
                for d in need(i, "shape")?
                    .as_arr()
                    .ok_or_else(|| Error::Artifact("shape not an array".into()))?
                {
                    shape.push(d.as_usize().ok_or_else(|| {
                        Error::Artifact(format!(
                            "manifest: non-numeric dim in shape of `{name}`"
                        ))
                    })?);
                }
                inputs.push(IoSpec { name, shape, dtype: need_str(i, "dtype")? });
            }
            graphs.push(GraphEntry {
                model: need_str(g, "model")?,
                name: need_str(g, "name")?,
                file: need_str(g, "file")?,
                inputs,
            });
        }

        let mut index = HashMap::new();
        for (i, g) in graphs.iter().enumerate() {
            index.insert((g.model.clone(), g.name.clone()), i);
        }
        Ok(ArtifactManifest { dir, calib_batch, buckets, groups, models, graphs, index })
    }

    /// The exported grain tags, sorted (`["g32", "g64", "pc"]`).
    pub fn grain_tags(&self) -> Vec<&str> {
        self.groups.keys().map(|s| s.as_str()).collect()
    }

    /// Whether `tag` has exported graph variants.
    pub fn has_grain(&self, tag: &str) -> bool {
        self.groups.contains_key(tag)
    }

    /// Reject a grain tag with no exported graphs — the fail-fast gate the
    /// pipeline runs at startup instead of dying mid-tweak at graph lookup.
    pub fn validate_grain(&self, tag: &str) -> Result<()> {
        if self.has_grain(tag) {
            return Ok(());
        }
        Err(Error::Artifact(format!(
            "quant grain `{tag}` has no exported graphs (manifest exports: {}) — \
             re-run the AOT export with `--groups` including `{tag}`",
            self.grain_tags().join(", ")
        )))
    }

    /// Largest exported batch bucket (manifests always have ≥ 1 bucket).
    pub fn max_bucket(&self) -> Option<usize> {
        self.buckets.iter().copied().max()
    }

    /// Find a graph by (model, graph-name).
    pub fn graph(&self, model: &str, name: &str) -> Result<&GraphEntry> {
        self.index
            .get(&(model.to_string(), name.to_string()))
            .map(|&i| &self.graphs[i])
            .ok_or_else(|| Error::Artifact(format!("no graph {model}.{name} in manifest")))
    }

    /// Absolute path of a graph's HLO text file.
    pub fn path_of(&self, g: &GraphEntry) -> PathBuf {
        self.dir.join(&g.file)
    }

    /// Verify a Rust-side model config against the manifest's record.
    pub fn verify_model(&self, cfg: &ModelConfig) -> Result<()> {
        let m = self
            .models
            .get(&cfg.name)
            .ok_or_else(|| Error::Artifact(format!("model {} not in manifest", cfg.name)))?;
        let norm = match cfg.norm {
            crate::model::NormKind::LayerNorm => "layernorm",
            crate::model::NormKind::RmsNorm => "rmsnorm",
        };
        if m.n_layer != cfg.n_layer
            || m.d_model != cfg.d_model
            || m.n_head != cfg.n_head
            || m.d_ff != cfg.d_ff
            || m.vocab != cfg.vocab
            || m.seq != cfg.seq
            || m.norm != norm
        {
            return Err(Error::Artifact(format!(
                "model {} config mismatch between Rust registry and manifest",
                cfg.name
            )));
        }
        Ok(())
    }

    /// Smallest exported batch bucket that fits `n` (error if none).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| Error::Artifact(format!("batch {n} exceeds largest bucket")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, json: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), json).unwrap();
    }

    fn write_fixture(dir: &Path) {
        let json = r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0, "g64": 64},
            "models": {"nt-tiny": {"n_layer": 2, "d_model": 128, "n_head": 4,
                        "d_ff": 512, "vocab": 2048, "seq": 128, "norm": "layernorm"}},
            "graphs": [{"model": "nt-tiny", "name": "embed.b8",
                        "file": "nt-tiny.embed.b8.hlo.txt",
                        "inputs": [{"name": "tokens", "shape": [8, 128], "dtype": "i32"}]}]
        }"#;
        write_manifest(dir, json);
    }

    #[test]
    fn load_and_lookup() {
        let dir = std::env::temp_dir().join("nt_manifest_test");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.calib_batch, 32);
        let g = m.graph("nt-tiny", "embed.b8").unwrap();
        assert_eq!(g.inputs[0].dtype, "i32");
        assert_eq!(g.inputs[0].shape, vec![8, 128]);
        assert!(m.graph("nt-tiny", "nope").is_err());
    }

    #[test]
    fn verify_model_checks_fields() {
        let dir = std::env::temp_dir().join("nt_manifest_test2");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        m.verify_model(&cfg).unwrap();
        let mut bad = cfg;
        bad.d_model = 96;
        assert!(m.verify_model(&bad).is_err());
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("nt_manifest_test3");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 8);
        assert_eq!(m.bucket_for(8).unwrap(), 8);
        assert_eq!(m.bucket_for(9).unwrap(), 32);
        assert!(m.bucket_for(33).is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactManifest::load("/definitely/missing").is_err());
    }

    #[test]
    fn groups_parsed_and_grain_validated() {
        let dir = std::env::temp_dir().join("nt_manifest_groups");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.groups.get("pc"), Some(&0));
        assert_eq!(m.groups.get("g64"), Some(&64));
        assert_eq!(m.grain_tags(), vec!["g64", "pc"]);
        assert!(m.has_grain("g64") && !m.has_grain("g128"));
        m.validate_grain("pc").unwrap();
        let err = m.validate_grain("g128").unwrap_err().to_string();
        assert!(err.contains("g128") && err.contains("g64, pc"), "{err}");
        assert_eq!(m.max_bucket(), Some(32));
    }

    #[test]
    fn multi_grain_manifest_loads() {
        let dir = std::env::temp_dir().join("nt_manifest_multigrain");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, 32],
            "groups": {"pc": 0, "g32": 32, "g64": 64, "g128": 128},
            "models": {}, "graphs": []
        }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.grain_tags(), vec!["g128", "g32", "g64", "pc"]);
        m.validate_grain("g32").unwrap();
        m.validate_grain("g128").unwrap();
    }

    #[test]
    fn malformed_buckets_rejected() {
        // a dropped bucket used to silently shift every bucket_for() answer
        let dir = std::env::temp_dir().join("nt_manifest_badbucket");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8, "32"],
            "groups": {"pc": 0}, "models": {}, "graphs": []
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("buckets"), "{err}");

        // empty buckets would make every batch oversized at serve time
        let dir = std::env::temp_dir().join("nt_manifest_emptybuckets");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [],
                "groups": {"pc": 0}, "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("buckets"), "{err}");
    }

    #[test]
    fn malformed_or_missing_groups_rejected() {
        let dir = std::env::temp_dir().join("nt_manifest_badgroup");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0, "g64": "sixty-four"}, "models": {}, "graphs": []
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("g64"), "{err}");

        let dir = std::env::temp_dir().join("nt_manifest_nogroups");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("groups"), "{err}");

        let dir = std::env::temp_dir().join("nt_manifest_emptygroups");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {}, "models": {}, "graphs": []}"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());

        // a drifted tag↔size pair would pass grain validation and then die
        // at PJRT shape mismatch mid-run
        let dir = std::env::temp_dir().join("nt_manifest_drifted");
        write_manifest(
            &dir,
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"g32": 64}, "models": {}, "graphs": []}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("`g32`") && err.contains("64"), "{err}");
    }

    #[test]
    fn malformed_shape_rejected() {
        let dir = std::env::temp_dir().join("nt_manifest_badshape");
        write_manifest(
            &dir,
            r#"{
            "format": 1, "calib_batch": 32, "buckets": [8],
            "groups": {"pc": 0}, "models": {},
            "graphs": [{"model": "m", "name": "g", "file": "f",
                        "inputs": [{"name": "x", "shape": [8, null],
                                    "dtype": "f32"}]}]
        }"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("shape") && err.contains("`x`"), "{err}");
    }
}

//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! coordinator's hot path.
//!
//! Python never runs here — `make artifacts` produced `artifacts/*.hlo.txt`
//! plus `manifest.json`; this module turns them into cached
//! `PjRtLoadedExecutable`s and shuttles [`Tensor`]s in/out as literals.

mod client;
mod literal;
mod manifest;

pub use client::{GraphKey, Runtime};
pub use literal::{literal_to_tensor, tensor_to_literal};
pub use manifest::{ArtifactManifest, DecodeRecord, GraphEntry, IoSpec, KvSpec};

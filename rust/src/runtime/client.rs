//! The PJRT runtime: one CPU client, an executable cache keyed by
//! (model, graph), argument validation against the manifest, and a uniform
//! multi-output execute.

// Justified unwraps: the compile-cache/stats mutexes hold plain maps; lock
// poisoning means a compile thread already panicked
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::obs::trace::{graph_family, TraceCollector};
use crate::obs::{global, MetricsRegistry};
use crate::tensor::Tensor;
use crate::util::json;

use super::literal::{literal_to_tensor, tensor_to_literal};
use super::manifest::ArtifactManifest;

/// Cache key: (model name, graph name).
pub type GraphKey = (String, String);

/// Runtime statistics (observability for the §Perf pass).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub exec_nanos: u128,
}

/// PJRT CPU runtime with compiled-executable caching.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: ArtifactManifest,
    cache: Mutex<HashMap<GraphKey, std::sync::Arc<PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
    /// trace collector + its pre-registered `xla` track tid
    trace: Option<(Arc<TraceCollector>, u64)>,
    /// skip per-call shape/dtype validation (hot-path opt; validated once)
    pub validate_args: bool,
}

impl Runtime {
    /// Create the CPU client and load the manifest from `artifacts/`.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
            trace: None,
            validate_args: true,
        })
    }

    /// Record every graph compile and execution into `trace` on an `xla`
    /// track, spans named by [`graph_family`] so all batch/grain
    /// specializations of a graph aggregate under one label.
    pub fn set_trace(&mut self, trace: Arc<TraceCollector>) {
        let tid = trace.track("xla");
        self.trace = Some((trace, tid));
    }

    /// The attached trace collector, if any — producers above the runtime
    /// (pipeline phases, tweak-loss counters) reuse it so everything lands
    /// on one timeline.
    pub fn trace(&self) -> Option<&Arc<TraceCollector>> {
        self.trace.as_ref().map(|(t, _)| t)
    }

    /// Load + compile a graph (cached).
    pub fn executable(
        &self,
        model: &str,
        graph: &str,
    ) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        let key = (model.to_string(), graph.to_string());
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let t_start = self.trace.as_ref().map(|(t, _)| t.now());
        let entry = self.manifest.graph(model, graph)?;
        let path = self.manifest.path_of(entry);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("bad path".into()))?,
        )
        .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compile {model}.{graph}: {e}")))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        self.stats.lock().unwrap().compiles += 1;
        global().counter("xla.compiles").inc();
        if let Some((tr, tid)) = &self.trace {
            tr.complete(
                *tid,
                "compile",
                t_start.unwrap_or(0),
                vec![("graph", json::s(format!("{model}.{graph}")))],
            );
        }
        Ok(exe)
    }

    /// Execute a graph with tensor args; returns all outputs (the AOT side
    /// always lowers with `return_tuple=True`).
    pub fn run(&self, model: &str, graph: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        if self.validate_args {
            let entry = self.manifest.graph(model, graph)?;
            if entry.inputs.len() != args.len() {
                return Err(Error::Shape(format!(
                    "{model}.{graph}: {} args given, {} expected",
                    args.len(),
                    entry.inputs.len()
                )));
            }
            // the same TensorSig the `graphs` lint checks statically — one
            // signature vocabulary for static analysis and runtime guards
            for (spec, t) in entry.inputs.iter().zip(args) {
                spec.sig().and_then(|sig| sig.check_tensor(t)).map_err(|e| {
                    Error::Shape(format!("{model}.{graph} arg `{}`: {e}", spec.name))
                })?;
            }
        }
        let exe = self.executable(model, graph)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;

        let trace_start = self.trace.as_ref().map(|(t, _)| t.now());
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Xla(format!("execute {model}.{graph}: {e}")))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(e.to_string()))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| Error::Xla(e.to_string()))?;
        let tensors: Vec<Tensor> =
            outs.iter().map(literal_to_tensor).collect::<Result<_>>()?;
        let dt = t0.elapsed();

        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.exec_nanos += dt.as_nanos();
        drop(s);

        let family = graph_family(graph);
        let us = dt.as_micros().min(u128::from(u64::MAX)) as u64;
        let m: &MetricsRegistry = global();
        m.counter("xla.executions").inc();
        m.histogram(&format!("xla.exec_us.{family}")).record(us);
        if let Some((tr, tid)) = &self.trace {
            tr.complete_at(
                *tid,
                family,
                trace_start.unwrap_or(0),
                us,
                vec![("graph", json::s(graph)), ("model", json::s(model))],
            );
        }
        Ok(tensors)
    }

    /// Cache-carrying execution for the incremental-decode step graphs.
    ///
    /// Runs `graph` with `args` followed by the `carry` tensors (the KV
    /// caches — by AOT convention they are the *trailing* inputs and the
    /// *trailing* outputs of every `block_dec[_q]` graph), and splits the
    /// outputs into `(fresh, carried)`: the carried tail has exactly
    /// `carry.len()` entries and is the next step's carry.  Taking the
    /// carry by value makes the state-threading explicit at the call site —
    /// a decode step consumes the old cache and hands back the new one.
    pub fn run_carry(
        &self,
        model: &str,
        graph: &str,
        args: &[&Tensor],
        carry: Vec<Tensor>,
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mut all: Vec<&Tensor> = args.to_vec();
        all.extend(carry.iter());
        let mut outs = self.run(model, graph, &all)?;
        if outs.len() < carry.len() {
            return Err(Error::Xla(format!(
                "{model}.{graph}: {} outputs but {} carried inputs — the graph \
                 does not follow the carry-last decode convention",
                outs.len(),
                carry.len()
            )));
        }
        let carried = outs.split_off(outs.len() - carry.len());
        Ok((outs, carried))
    }

    /// Snapshot of runtime counters.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Pre-compile a set of graphs (warm-up before timed sections).
    pub fn warmup(&self, model: &str, graphs: &[&str]) -> Result<()> {
        for g in graphs {
            self.executable(model, g)?;
        }
        Ok(())
    }

    /// Fail unless `tag` has exported graph variants (the error lists what
    /// the manifest does export). Runners call this at construction so an
    /// unexported grain dies before any graph is compiled.
    pub fn validate_grain(&self, tag: &str) -> Result<()> {
        self.manifest.validate_grain(tag)
    }
}

//! Tensor ⇄ PJRT literal conversion (single contiguous copies, no per-element
//! marshalling — this is on the per-layer hot path).

use xla::{ElementType, Literal};

use crate::analysis::hlo::TensorSig;
use crate::error::{Error, Result};
use crate::tensor::{Storage, Tensor};

fn as_bytes<T: Copy>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
    }
}

/// Build a PJRT literal from a tensor (one memcpy).
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let (ty, bytes): (ElementType, &[u8]) = match &t.data {
        Storage::F32(v) => (ElementType::F32, as_bytes(v)),
        Storage::I8(v) => (ElementType::S8, as_bytes(v)),
        Storage::U8(v) => (ElementType::U8, as_bytes(v)),
        Storage::I32(v) => (ElementType::S32, as_bytes(v)),
        Storage::I64(v) => (ElementType::S64, as_bytes(v)),
    };
    Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        .map_err(|e| Error::Xla(e.to_string()))
}

/// Read a PJRT literal back into a tensor (one copy out).
pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l
        .array_shape()
        .map_err(|e| Error::Xla(e.to_string()))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().map_err(|e| Error::Xla(e.to_string()))?;
    let data = match ty {
        ElementType::F32 => Storage::F32(l.to_vec::<f32>().map_err(xe)?),
        ElementType::S8 => Storage::I8(l.to_vec::<i8>().map_err(xe)?),
        ElementType::U8 => Storage::U8(l.to_vec::<u8>().map_err(xe)?),
        ElementType::S32 => Storage::I32(l.to_vec::<i32>().map_err(xe)?),
        ElementType::S64 => Storage::I64(l.to_vec::<i64>().map_err(xe)?),
        other => {
            return Err(Error::Xla(format!("unsupported literal type {other:?}")))
        }
    };
    Ok(Tensor { shape: dims, data })
}

fn xe(e: xla::Error) -> Error {
    Error::Xla(e.to_string())
}

/// Check a tensor against a manifest IoSpec (shape + dtype).  Thin shim
/// over the shared signature types ([`TensorSig`]) — the same types the
/// `graphs` lint parses out of the HLO text, so static analysis and this
/// runtime guard cannot drift apart.
pub fn check_spec(t: &Tensor, shape: &[usize], dtype: &str) -> Result<()> {
    TensorSig::from_manifest(shape, dtype)?.check_tensor(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i8_i32() {
        let t = Tensor::i8(&[4], vec![-7, 0, 1, 7]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap(), t);
        let t = Tensor::i32(&[2, 2], vec![1, -2, 3, -4]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap(), t);
    }

    #[test]
    fn spec_check() {
        let t = Tensor::zeros(&[2, 2]);
        assert!(check_spec(&t, &[2, 2], "f32").is_ok());
        assert!(check_spec(&t, &[2, 2], "i8").is_err());
        assert!(check_spec(&t, &[4], "f32").is_err());
    }
}

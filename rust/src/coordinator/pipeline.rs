//! Algorithm 1 — the layer-by-layer PTQ + Norm-Tweaking pipeline.

use std::time::Instant;

use crate::calib::CalibSet;
use crate::error::{Error, Result};
use crate::model::{ModelWeights, QuantLinear, QuantizedBlock, QuantizedModel};
use crate::quant::{awq, gptq, omniquant, rtn, smoothquant, QuantScheme, QuantizedWeight};
use crate::runtime::Runtime;
use crate::tensor::{mean_var_channels, pack_codes, Tensor};
use crate::tweak::tweaker::{LossKind, TweakTarget};
use crate::tweak::{LayerLrScheduler, TweakConfig, Tweaker};

use super::forward::{FloatModel, QuantModel};
use super::hessian::collect_hessians;
use super::metrics::{LayerMetrics, PipelineMetrics};

/// Which PTQ algorithm hosts the (optional) norm tweaking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMethod {
    Rtn,
    Gptq,
    /// SmoothQuant: outlier migration folded into the preceding norms, then
    /// RTN weights; pair with `act_bits` at eval time for W4A8.
    SmoothQuant,
    /// AWQ-lite: activation-aware scaling on the norm-fed linears.
    Awq,
    /// OmniQuant-lite: grid-searched weight clipping.
    OmniQuant,
}

impl QuantMethod {
    pub fn as_str(&self) -> &'static str {
        match self {
            QuantMethod::Rtn => "rtn",
            QuantMethod::Gptq => "gptq",
            QuantMethod::SmoothQuant => "smoothquant",
            QuantMethod::Awq => "awq",
            QuantMethod::OmniQuant => "omniquant",
        }
    }
}

/// Full pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub method: QuantMethod,
    pub scheme: QuantScheme,
    /// None = plain PTQ; Some = PTQ + Norm Tweaking
    pub tweak: Option<TweakConfig>,
    pub gptq: gptq::GptqParams,
    pub smooth_alpha: f32,
}

impl PipelineConfig {
    pub fn new(method: QuantMethod, scheme: QuantScheme) -> Self {
        PipelineConfig {
            method,
            scheme,
            tweak: None,
            gptq: gptq::GptqParams::default(),
            smooth_alpha: 0.5,
        }
    }

    pub fn with_tweak(mut self, t: TweakConfig) -> Self {
        self.tweak = Some(t);
        self
    }
}

fn to_quant_linear(qw: QuantizedWeight, bias: Tensor, scheme: &QuantScheme) -> Result<QuantLinear> {
    Ok(QuantLinear {
        k: qw.k,
        n: qw.n,
        packed: pack_codes(&qw.codes, scheme.pack_bits())
            .map_err(|e| Error::Quant(format!("pack: {e}")))?,
        scales: Tensor::f32(&[qw.g, qw.n], qw.scales),
        bias,
    })
}

/// Run Algorithm 1: quantize `weights` with `cfg` against `calib`,
/// returning the quantized model + pipeline metrics.
pub fn quantize_model(
    runtime: &Runtime,
    weights: &ModelWeights,
    calib: &CalibSet,
    cfg: &PipelineConfig,
) -> Result<(QuantizedModel, PipelineMetrics)> {
    let t_total = Instant::now();
    let mcfg = weights.config.clone();
    let cb = runtime.manifest.calib_batch;
    if calib.n_samples() != cb {
        return Err(Error::msg(format!(
            "calibration set has {} samples; pipeline graphs need {cb}",
            calib.n_samples()
        )));
    }

    let fm = FloatModel::new(runtime, weights)?;
    let mut qmodel = QuantizedModel::scaffold(weights, cfg.scheme)?;
    let tweaker = cfg.tweak.map(|t| {
        Tweaker::new(runtime, &mcfg.name, cfg.scheme.group_tag(), t)
    });
    let lr_sched = cfg
        .tweak
        .map(|t| LayerLrScheduler::new(t.lr0, t.lr_scale, mcfg.n_layer));

    let mut metrics = PipelineMetrics {
        model: mcfg.name.clone(),
        method: cfg.method.as_str().to_string(),
        bits: cfg.scheme.bits,
        group: cfg.scheme.group_size,
        tweaked: cfg.tweak.is_some(),
        calib_source: calib.source.clone(),
        ..Default::default()
    };

    // line 1 (calibration data) happened upstream; set up the two streams
    let mut x_f = fm.embed(&calib.tokens)?; // float stream
    let mut x_q = x_f.clone();              // quantized stream (Alg. 1 line 6)

    for layer in 0..mcfg.n_layer {
        let t_layer = Instant::now();

        // ---- float output + targets (Alg. 1 line 8) -------------------------
        let y_f = fm.block_fwd(layer, &x_f)?;
        let (mu_f, var_f) = fm.channel_stats(&y_f)?;

        // ---- quantize the four linears (Alg. 1 line 9) ----------------------
        let bw = weights.block(layer)?;
        let mut ln1_g = bw.ln1_g.clone();
        let mut ln1_b = bw.ln1_b.cloned();
        let mut ln2_g = bw.ln2_g.clone();
        let mut ln2_b = bw.ln2_b.cloned();

        let (qqkv, qproj, qfc1, qfc2) = match cfg.method {
            QuantMethod::Rtn => (
                rtn::quantize(bw.wqkv, &cfg.scheme)?,
                rtn::quantize(bw.wproj, &cfg.scheme)?,
                rtn::quantize(bw.wfc1, &cfg.scheme)?,
                rtn::quantize(bw.wfc2, &cfg.scheme)?,
            ),
            QuantMethod::OmniQuant => (
                omniquant::quantize(bw.wqkv, &cfg.scheme)?,
                omniquant::quantize(bw.wproj, &cfg.scheme)?,
                omniquant::quantize(bw.wfc1, &cfg.scheme)?,
                omniquant::quantize(bw.wfc2, &cfg.scheme)?,
            ),
            QuantMethod::Gptq => {
                let hs = collect_hessians(&fm, runtime, layer, &x_q)?;
                (
                    gptq::quantize(bw.wqkv, &hs[0], &cfg.scheme, &cfg.gptq)?,
                    gptq::quantize(bw.wproj, &hs[1], &cfg.scheme, &cfg.gptq)?,
                    gptq::quantize(bw.wfc1, &hs[2], &cfg.scheme, &cfg.gptq)?,
                    gptq::quantize(bw.wfc2, &hs[3], &cfg.scheme, &cfg.gptq)?,
                )
            }
            QuantMethod::SmoothQuant => {
                // taps give the activation ranges feeding each linear
                let taps = fm.block_taps(layer, &x_q)?;
                let mk_stats = |t: &Tensor| -> Result<smoothquant::ActStats> {
                    let k = *t.shape.last().unwrap();
                    let mut st = smoothquant::ActStats::new(k);
                    st.update(&t.clone().reshape(&[t.numel() / k, k])?)?;
                    Ok(st)
                };
                let sp = smoothquant::SmoothParams { alpha: cfg.smooth_alpha };
                // migrate the norm-fed linears (qkv via ln1, fc1 via ln2)
                let s_qkv = smoothquant::smoothing_factors(bw.wqkv, &mk_stats(&taps[0])?, &sp)?;
                let w_qkv = smoothquant::scale_weight(bw.wqkv, &s_qkv)?;
                let (g1, b1) = smoothquant::fold_into_norm(&ln1_g, ln1_b.as_ref(), &s_qkv)?;
                ln1_g = g1;
                ln1_b = b1;
                let s_fc1 = smoothquant::smoothing_factors(bw.wfc1, &mk_stats(&taps[2])?, &sp)?;
                let w_fc1 = smoothquant::scale_weight(bw.wfc1, &s_fc1)?;
                let (g2, b2) = smoothquant::fold_into_norm(&ln2_g, ln2_b.as_ref(), &s_fc1)?;
                ln2_g = g2;
                ln2_b = b2;
                (
                    rtn::quantize(&w_qkv, &cfg.scheme)?,
                    rtn::quantize(bw.wproj, &cfg.scheme)?,
                    rtn::quantize(&w_fc1, &cfg.scheme)?,
                    rtn::quantize(bw.wfc2, &cfg.scheme)?,
                )
            }
            QuantMethod::Awq => {
                let taps = fm.block_taps(layer, &x_q)?;
                let mk = |t: &Tensor| -> Result<(smoothquant::ActStats, Tensor)> {
                    let k = *t.shape.last().unwrap();
                    let flat = t.clone().reshape(&[t.numel() / k, k])?;
                    let mut st = smoothquant::ActStats::new(k);
                    st.update(&flat)?;
                    // subsample rows for the grid-search objective
                    let rows = flat.shape[0].min(64);
                    let v = flat.as_f32()?[..rows * k].to_vec();
                    Ok((st, Tensor::f32(&[rows, k], v)))
                };
                let (st_qkv, xs_qkv) = mk(&taps[0])?;
                let r_qkv = awq::quantize(bw.wqkv, &st_qkv, &xs_qkv, &cfg.scheme)?;
                let (g1, b1) =
                    smoothquant::fold_into_norm(&ln1_g, ln1_b.as_ref(), &r_qkv.in_scales)?;
                ln1_g = g1;
                ln1_b = b1;
                let (st_fc1, xs_fc1) = mk(&taps[2])?;
                let r_fc1 = awq::quantize(bw.wfc1, &st_fc1, &xs_fc1, &cfg.scheme)?;
                let (g2, b2) =
                    smoothquant::fold_into_norm(&ln2_g, ln2_b.as_ref(), &r_fc1.in_scales)?;
                ln2_g = g2;
                ln2_b = b2;
                (
                    r_qkv.qw,
                    rtn::quantize(bw.wproj, &cfg.scheme)?,
                    r_fc1.qw,
                    rtn::quantize(bw.wfc2, &cfg.scheme)?,
                )
            }
        };
        let quant_millis = t_layer.elapsed().as_millis();

        // ---- assemble the quantized block (Alg. 1 line 10: freeze linears) --
        let mut blk = QuantizedBlock {
            ln1_g,
            ln1_b,
            qkv: to_quant_linear(qqkv, bw.bqkv.clone(), &cfg.scheme)?,
            proj: to_quant_linear(qproj, bw.bproj.clone(), &cfg.scheme)?,
            ln2_g,
            ln2_b,
            fc1: to_quant_linear(qfc1, bw.bfc1.clone(), &cfg.scheme)?,
            fc2: to_quant_linear(qfc2, bw.bfc2.clone(), &cfg.scheme)?,
        };

        // ---- norm tweaking (Alg. 1 lines 11-15) ------------------------------
        let t_tweak = Instant::now();
        let mut loss_before = None;
        let mut loss_after = None;
        let mut lr_used = None;
        if let (Some(tw), Some(sched)) = (&tweaker, &lr_sched) {
            let lr = sched.lr(layer);
            let target = match tw.config.loss {
                LossKind::Dist => TweakTarget::Stats {
                    mu: mu_f.clone(),
                    var: var_f.clone(),
                },
                _ => TweakTarget::Full { y_f: y_f.clone() },
            };
            let outcome = tw.tweak_layer(&mut blk, mcfg.norm, &x_q, &target, lr)?;
            loss_before = outcome.losses.first().copied();
            loss_after = outcome.losses.last().copied();
            lr_used = Some(lr);
        }
        let tweak_millis = t_tweak.elapsed().as_millis();

        // ---- advance the two streams (Alg. 1 lines 4-7) ----------------------
        qmodel.blocks.push(blk);
        let qm_view = QuantModel::new(runtime, &qmodel)?;
        let y_q = qm_view.block_fwd_q(layer, &x_q)?;

        // Figure-1 drift of this layer's output
        let (mu_q, var_q) = mean_var_channels(&y_q)?;
        let mu_f_v = mu_f.as_f32()?;
        let var_f_v = var_f.as_f32()?;
        let d = mu_q.len();
        let delta_mu = (0..d)
            .map(|i| (mu_f_v[i] - mu_q[i]).abs())
            .sum::<f32>()
            / d as f32;
        let delta_var = (0..d)
            .map(|i| (var_f_v[i] - var_q[i]).abs())
            .sum::<f32>()
            / d as f32;

        if std::env::var_os("NT_QUIET").is_none() {
            eprintln!(
                "[pipeline] layer {layer}: Δμ={delta_mu:.5} loss {loss_before:?} -> \
                 {loss_after:?} ({quant_millis} ms quant, {tweak_millis} ms tweak)"
            );
        }
        metrics.layers.push(LayerMetrics {
            layer,
            delta_mu,
            delta_var,
            loss_before,
            loss_after,
            lr_used,
            quant_millis,
            tweak_millis,
        });

        x_f = y_f;
        x_q = y_q;
    }

    metrics.total_millis = t_total.elapsed().as_millis();
    metrics.compression_ratio =
        qmodel.quantized_bytes() as f32 / qmodel.float_bytes() as f32;
    Ok((qmodel, metrics))
}

//! Algorithm 1 — the layer-by-layer PTQ + Norm-Tweaking pipeline.
//!
//! The host PTQ method is a [`Quantizer`] plugin resolved from
//! `PipelineConfig::method` through the string-keyed registry
//! (`crate::quant::quantizer`); the pipeline itself is method-agnostic — it
//! builds a [`LayerContext`] per block and lets the plugin pull whatever
//! side inputs it declares (Hessians, activation taps, norm folds).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::calib::CalibSet;
use crate::error::{Error, Result};
use crate::model::{ModelWeights, QuantLinear, QuantizedBlock, QuantizedModel};
use crate::obs::global;
use crate::quant::quantizer::{resolve, LayerContext, Quantizer, QuantizerParams};
use crate::quant::{QuantScheme, QuantizedWeight};
use crate::runtime::{ArtifactManifest, Runtime};
use crate::tensor::{mean_var_channels, pack_codes, Tensor};
use crate::util::json;
use crate::tweak::tweaker::{LossKind, TweakTarget};
use crate::tweak::{LayerLrScheduler, TweakConfig, Tweaker};

use super::forward::{FloatModel, QuantModel};
use super::metrics::{LayerMetrics, PipelineMetrics};

/// Full pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Quantizer spec resolved through the plugin registry: any registered
    /// name, or a `+`-composition such as `"smoothquant+gptq"`.
    pub method: String,
    pub scheme: QuantScheme,
    /// None = plain PTQ; Some = PTQ + Norm Tweaking
    pub tweak: Option<TweakConfig>,
    /// Tunables handed to plugin constructors (GPTQ damping, smooth alpha).
    pub params: QuantizerParams,
    /// Per-layer scheme overrides (mixed precision). Overrides must share
    /// the base scheme's group grain — the AOT forward graphs are compiled
    /// per grain — but may change the bit width freely.
    pub layer_schemes: BTreeMap<usize, QuantScheme>,
    /// Provenance note when `layer_schemes` came from the automatic
    /// mixed-precision planner (`crate::policy`); echoed into
    /// `PipelineMetrics` and the persisted experiment records.
    pub plan_note: Option<String>,
}

impl PipelineConfig {
    pub fn new(method: impl Into<String>, scheme: QuantScheme) -> Self {
        PipelineConfig {
            method: method.into(),
            scheme,
            tweak: None,
            params: QuantizerParams::default(),
            layer_schemes: BTreeMap::new(),
            plan_note: None,
        }
    }

    pub fn with_tweak(mut self, t: TweakConfig) -> Self {
        self.tweak = Some(t);
        self
    }

    /// Override the quantization scheme for one layer (mixed precision).
    pub fn with_layer_scheme(mut self, layer: usize, scheme: QuantScheme) -> Self {
        self.layer_schemes.insert(layer, scheme);
        self
    }

    /// Record where an automatically planned `layer_schemes` came from.
    pub fn with_plan_note(mut self, note: impl Into<String>) -> Self {
        self.plan_note = Some(note.into());
        self
    }

    /// The scheme in effect for `layer`.
    pub fn scheme_for(&self, layer: usize) -> QuantScheme {
        self.layer_schemes.get(&layer).copied().unwrap_or(self.scheme)
    }

    /// Check every layer override against the model depth and the base
    /// scheme's grain/pack-width constraints. Public so the planner's test
    /// suite (and callers assembling plans by hand) can prove an emitted
    /// plan is legal without running the pipeline.
    pub fn validate(&self, n_layer: usize) -> Result<()> {
        let base_tag = self.scheme.group_tag();
        for (&layer, s) in &self.layer_schemes {
            if layer >= n_layer {
                return Err(Error::Config(format!(
                    "layer scheme override for layer {layer}, model has {n_layer}"
                )));
            }
            if s.group_tag() != base_tag {
                return Err(Error::Config(format!(
                    "layer {layer} scheme grain {} != base grain {base_tag} \
                     (forward graphs are compiled per grain)",
                    s.group_tag()
                )));
            }
            s.pack_bits()?;
        }
        self.scheme.pack_bits()?;
        Ok(())
    }
}

/// Fail-fast artifact validation, run at pipeline startup: the scheme's
/// grain must have exported graph variants, and the tweak loss's
/// `tweak_step*` graph must exist for this model — one clear
/// [`Error::Artifact`] listing what the manifest exports, instead of a
/// graph-lookup failure deep inside the tweak loop.
///
/// Lint-backed: the checks live in `crate::analysis::scheme_rules`
/// (diagnostic codes NT0308/NT0309, shared with `normtweak check`); this
/// wrapper collects them and preserves the historical abort-with-`Err`
/// behavior.
pub fn validate_scheme_artifacts(
    manifest: &ArtifactManifest,
    model: &str,
    cfg: &PipelineConfig,
) -> Result<()> {
    let ctx = crate::analysis::CheckContext {
        manifest: Some(manifest.clone()),
        model_name: Some(model.to_string()),
        plan: Some(crate::analysis::PlanSpec {
            method: cfg.method.clone(),
            scheme: cfg.scheme,
            layer_schemes: cfg.layer_schemes.iter().map(|(&l, &s)| (l, s)).collect(),
            tweak_loss: cfg.tweak.map(|t| t.loss),
        }),
        ..crate::analysis::CheckContext::default()
    };
    let mut report = crate::analysis::Report::new();
    crate::analysis::scheme_rules::artifact_diags(&ctx, &mut report);
    report.into_result(Error::Artifact)
}

fn to_quant_linear(qw: QuantizedWeight, bias: Tensor, scheme: &QuantScheme) -> Result<QuantLinear> {
    let bits = scheme.pack_bits()?;
    Ok(QuantLinear::new(
        qw.k,
        qw.n,
        pack_codes(&qw.codes, bits).map_err(|e| Error::Quant(format!("pack: {e}")))?,
        Tensor::f32(&[qw.g, qw.n], qw.scales),
        bias,
    ))
}

/// Run Algorithm 1: quantize `weights` with `cfg` against `calib`,
/// returning the quantized model + pipeline metrics.
pub fn quantize_model(
    runtime: &Runtime,
    weights: &ModelWeights,
    calib: &CalibSet,
    cfg: &PipelineConfig,
) -> Result<(QuantizedModel, PipelineMetrics)> {
    let t_total = Instant::now();
    let mcfg = weights.config.clone();
    let cb = runtime.manifest.calib_batch;
    if calib.n_samples() != cb {
        return Err(Error::msg(format!(
            "calibration set has {} samples; pipeline graphs need {cb}",
            calib.n_samples()
        )));
    }
    cfg.validate(mcfg.n_layer)?;
    validate_scheme_artifacts(&runtime.manifest, &mcfg.name, cfg)?;
    let quantizer: Box<dyn Quantizer> = resolve(&cfg.method, &cfg.params)?;

    let fm = FloatModel::new(runtime, weights)?;
    let mut qmodel = QuantizedModel::scaffold(weights, cfg.scheme)?;
    let tweaker = cfg.tweak.map(|t| {
        Tweaker::new(runtime, &mcfg.name, &cfg.scheme.group_tag(), t)
    });
    let lr_sched = cfg
        .tweak
        .map(|t| LayerLrScheduler::new(t.lr0, t.lr_scale, mcfg.n_layer));

    let mut metrics = PipelineMetrics {
        model: mcfg.name.clone(),
        method: quantizer.name().to_string(),
        bits: cfg.scheme.bits,
        group: cfg.scheme.group_size,
        tweaked: cfg.tweak.is_some(),
        calib_source: calib.source.clone(),
        plan: cfg.plan_note.clone(),
        ..Default::default()
    };

    // ---- tracing: one `pipeline` track, phase spans per layer ------------
    let trace = runtime.trace().map(|t| (t.clone(), t.track("pipeline")));
    let layer_arg = |layer: usize| vec![("layer", json::n(layer as f64))];

    // line 1 (calibration data) happened upstream; set up the two streams
    let mut x_f = fm.embed(&calib.tokens)?; // float stream
    let mut x_q = x_f.clone();              // quantized stream (Alg. 1 line 6)

    for layer in 0..mcfg.n_layer {
        let t_layer = Instant::now();
        let ts_layer = trace.as_ref().map(|(t, _)| t.now());
        let scheme = cfg.scheme_for(layer);

        // ---- float output + targets (Alg. 1 line 8) -------------------------
        let y_f = fm.block_fwd(layer, &x_f)?;
        let (mu_f, var_f) = fm.channel_stats(&y_f)?;
        if let Some((t, tid)) = &trace {
            t.complete(*tid, "float_ref", ts_layer.unwrap_or(0), layer_arg(layer));
        }

        // ---- quantize the four linears (Alg. 1 line 9) ----------------------
        // One trait call replaces the per-method dispatch: the plugin pulls
        // taps/Hessians lazily and folds norm scales through the context.
        let ts_quant = trace.as_ref().map(|(t, _)| t.now());
        let bw = weights.block(layer)?;
        let mut ctx = LayerContext::new(&fm, layer, &x_q, bw, scheme);
        let bq = quantizer.quantize_layer(&mut ctx)?;
        let norms = ctx.into_norms();
        let quant_millis = t_layer.elapsed().as_millis();
        if let Some((t, tid)) = &trace {
            let mut args = layer_arg(layer);
            args.push(("method", json::s(quantizer.name())));
            t.complete(*tid, "quantize", ts_quant.unwrap_or(0), args);
        }
        global()
            .histogram("pipeline.quant_us")
            .record(t_layer.elapsed().as_micros().min(u128::from(u64::MAX)) as u64);

        // ---- assemble the quantized block (Alg. 1 line 10: freeze linears) --
        let ts_pack = trace.as_ref().map(|(t, _)| t.now());
        let mut blk = QuantizedBlock {
            ln1_g: norms.ln1_g,
            ln1_b: norms.ln1_b,
            qkv: to_quant_linear(bq.qkv, bw.bqkv.clone(), &scheme)?,
            proj: to_quant_linear(bq.proj, bw.bproj.clone(), &scheme)?,
            ln2_g: norms.ln2_g,
            ln2_b: norms.ln2_b,
            fc1: to_quant_linear(bq.fc1, bw.bfc1.clone(), &scheme)?,
            fc2: to_quant_linear(bq.fc2, bw.bfc2.clone(), &scheme)?,
        };
        if let Some((t, tid)) = &trace {
            t.complete(*tid, "pack", ts_pack.unwrap_or(0), layer_arg(layer));
        }

        // ---- norm tweaking (Alg. 1 lines 11-15) ------------------------------
        let t_tweak = Instant::now();
        let ts_tweak = trace.as_ref().map(|(t, _)| t.now());
        let mut loss_before = None;
        let mut loss_after = None;
        let mut lr_used = None;
        if let (Some(tw), Some(sched)) = (&tweaker, &lr_sched) {
            let lr = sched.lr(layer);
            let target = match tw.config.loss {
                LossKind::Dist => TweakTarget::Stats {
                    mu: mu_f.clone(),
                    var: var_f.clone(),
                },
                _ => TweakTarget::Full { y_f: y_f.clone() },
            };
            let outcome = tw.tweak_layer(&mut blk, mcfg.norm, &x_q, &target, lr)?;
            loss_before = outcome.losses.first().copied();
            loss_after = outcome.losses.last().copied();
            lr_used = Some(lr);
        }
        let tweak_millis = t_tweak.elapsed().as_millis();
        if cfg.tweak.is_some() {
            if let Some((t, tid)) = &trace {
                t.complete(*tid, "tweak", ts_tweak.unwrap_or(0), layer_arg(layer));
            }
            global()
                .histogram("pipeline.tweak_us")
                .record(t_tweak.elapsed().as_micros().min(u128::from(u64::MAX)) as u64);
        }

        // ---- advance the two streams (Alg. 1 lines 4-7) ----------------------
        let ts_adv = trace.as_ref().map(|(t, _)| t.now());
        qmodel.blocks.push(blk);
        let qm_view = QuantModel::new(runtime, &qmodel)?;
        let y_q = qm_view.block_fwd_q(layer, &x_q)?;

        // Figure-1 drift of this layer's output
        let (mu_q, var_q) = mean_var_channels(&y_q)?;
        let mu_f_v = mu_f.as_f32()?;
        let var_f_v = var_f.as_f32()?;
        let d = mu_q.len();
        let delta_mu = (0..d)
            .map(|i| (mu_f_v[i] - mu_q[i]).abs())
            .sum::<f32>()
            / d as f32;
        let delta_var = (0..d)
            .map(|i| (var_f_v[i] - var_q[i]).abs())
            .sum::<f32>()
            / d as f32;

        if let Some((t, tid)) = &trace {
            t.complete(*tid, "advance", ts_adv.unwrap_or(0), layer_arg(layer));
            let mut args = layer_arg(layer);
            args.push(("delta_mu", json::n(f64::from(delta_mu))));
            t.complete_at(
                *tid,
                "layer",
                ts_layer.unwrap_or(0),
                t.now().saturating_sub(ts_layer.unwrap_or(0)),
                args,
            );
        }
        crate::log_info!(
            "pipeline",
            "layer {layer}: Δμ={delta_mu:.5} loss {loss_before:?} -> \
             {loss_after:?} ({quant_millis} ms quant, {tweak_millis} ms tweak)"
        );
        metrics.layers.push(LayerMetrics {
            layer,
            delta_mu,
            delta_var,
            loss_before,
            loss_after,
            lr_used,
            quant_millis,
            tweak_millis,
        });

        x_f = y_f;
        x_q = y_q;
    }

    metrics.total_millis = t_total.elapsed().as_millis();
    metrics.compression_ratio =
        qmodel.quantized_bytes() as f32 / qmodel.float_bytes() as f32;
    Ok((qmodel, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_for_prefers_override() {
        let cfg = PipelineConfig::new("rtn", QuantScheme::w2_g64())
            .with_layer_scheme(1, QuantScheme::w3_g64());
        assert_eq!(cfg.scheme_for(0), QuantScheme::w2_g64());
        assert_eq!(cfg.scheme_for(1), QuantScheme::w3_g64());
        assert_eq!(cfg.scheme_for(2), QuantScheme::w2_g64());
    }

    #[test]
    fn validate_rejects_mixed_grain_and_bad_layers() {
        let cfg = PipelineConfig::new("rtn", QuantScheme::w2_g64())
            .with_layer_scheme(0, QuantScheme::w4_perchannel());
        assert!(cfg.validate(4).is_err()); // pc grain under a g64 base
        let cfg = PipelineConfig::new("rtn", QuantScheme::w2_g64())
            .with_layer_scheme(9, QuantScheme::w3_g64());
        assert!(cfg.validate(4).is_err()); // layer out of range
        let cfg = PipelineConfig::new("rtn", QuantScheme::w2_g64())
            .with_layer_scheme(3, QuantScheme::w3_g64());
        assert!(cfg.validate(4).is_ok());
    }
}

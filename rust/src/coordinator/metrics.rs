//! Pipeline observability: per-layer records that back Figure 1 (activation
//! drift) and Table 3 (runtime).

use crate::util::json::{arr, n, obj, s, Json};

/// Per-layer measurements taken while the pipeline runs.
#[derive(Debug, Clone)]
pub struct LayerMetrics {
    pub layer: usize,
    /// mean |mu_f - mu_q| over channels of the layer *output* (Figure 1's y-axis)
    pub delta_mu: f32,
    /// mean |var_f - var_q| over channels
    pub delta_var: f32,
    /// Eq. 2 loss before tweaking (if tweaked)
    pub loss_before: Option<f32>,
    /// Eq. 2 loss after the last tweak iteration
    pub loss_after: Option<f32>,
    pub lr_used: Option<f32>,
    pub quant_millis: u128,
    pub tweak_millis: u128,
}

impl LayerMetrics {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("layer", n(self.layer as f64)),
            ("delta_mu", n(self.delta_mu as f64)),
            ("delta_var", n(self.delta_var as f64)),
            ("loss_before", self.loss_before.map(|x| n(x as f64)).unwrap_or(Json::Null)),
            ("loss_after", self.loss_after.map(|x| n(x as f64)).unwrap_or(Json::Null)),
            ("lr_used", self.lr_used.map(|x| n(x as f64)).unwrap_or(Json::Null)),
            ("quant_millis", n(self.quant_millis as f64)),
            ("tweak_millis", n(self.tweak_millis as f64)),
        ])
    }
}

/// Whole-run measurements.
#[derive(Debug, Clone, Default)]
pub struct PipelineMetrics {
    pub model: String,
    pub method: String,
    pub bits: u8,
    pub group: Option<usize>,
    pub tweaked: bool,
    pub calib_source: String,
    /// provenance of the mixed-precision plan, when `layer_schemes` came
    /// from the automatic planner (None for uniform or hand-typed schemes)
    pub plan: Option<String>,
    pub layers: Vec<LayerMetrics>,
    pub total_millis: u128,
    /// packed quantized bytes / float bytes of the same matrices
    pub compression_ratio: f32,
}

impl PipelineMetrics {
    /// Figure-1 series: (layer, delta_mu) pairs.
    pub fn drift_series(&self) -> Vec<(usize, f32)> {
        self.layers.iter().map(|l| (l.layer, l.delta_mu)).collect()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(self.model.clone())),
            ("method", s(self.method.clone())),
            ("bits", n(self.bits as f64)),
            ("group", self.group.map(|g| n(g as f64)).unwrap_or(Json::Null)),
            ("tweaked", Json::Bool(self.tweaked)),
            ("calib_source", s(self.calib_source.clone())),
            ("plan", self.plan.clone().map(s).unwrap_or(Json::Null)),
            ("total_millis", n(self.total_millis as f64)),
            ("compression_ratio", n(self.compression_ratio as f64)),
            ("layers", arr(self.layers.iter().map(|l| l.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrips() {
        let m = PipelineMetrics {
            model: "nt-tiny".into(),
            method: "gptq".into(),
            bits: 4,
            group: Some(64),
            tweaked: true,
            calib_source: "gen-v2".into(),
            plan: Some("auto-bits 2.25: model=nt-tiny".into()),
            layers: vec![LayerMetrics {
                layer: 0,
                delta_mu: 0.5,
                delta_var: 0.1,
                loss_before: Some(1.0),
                loss_after: Some(0.5),
                lr_used: Some(1e-3),
                quant_millis: 10,
                tweak_millis: 5,
            }],
            total_millis: 15,
            compression_ratio: 0.125,
        };
        let j = m.to_json().emit();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("model").unwrap().as_str().unwrap(), "nt-tiny");
        assert_eq!(back.get("layers").unwrap().as_arr().unwrap().len(), 1);
        assert!(back
            .get("plan")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("auto-bits"));
        assert_eq!(m.drift_series(), vec![(0, 0.5)]);
    }
}

//! L3 coordination: the Algorithm-1 quantization pipeline.
//!
//! The coordinator owns the two activation streams over the calibration
//! batch — float (`fOut`) and quantized (`qOut`) — and advances them one
//! transformer layer at a time: quantize layer *l* through the resolved
//! `Quantizer` plugin (`crate::quant::quantizer`), optionally norm-tweak it
//! against the float stream's channel statistics, then feed `qOut_l`
//! forward (Algorithm 1 line 6).

mod forward;
mod hessian;
mod metrics;
mod pipeline;

pub use forward::{pad_batch, FloatModel, QuantModel};
pub(crate) use forward::arena_for;
pub use hessian::{collect_hessians, hessian_from_tap, hessian_from_tap_cpu};
pub use metrics::{LayerMetrics, PipelineMetrics};
pub use pipeline::{quantize_model, validate_scheme_artifacts, PipelineConfig};

use crate::calib::corpus::spec_by_name;
use crate::calib::gen::{generate_calib, GenVariant};
use crate::calib::random::random_calib;
use crate::calib::{corpus, CalibSet};
use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::runtime::Runtime;

/// Build a calibration set from a named source:
/// `gen-v1` / `gen-v2` (model self-generation), `random`, or one of the
/// named corpora (`train`, `wiki-syn`, `ptb-syn`, `c4-syn`).
pub fn build_calib(
    runtime: &Runtime,
    weights: &ModelWeights,
    source: &str,
    n: usize,
    seed: u64,
) -> Result<CalibSet> {
    let seq = weights.config.seq;
    match source {
        "gen-v1" | "gen-v2" => {
            let variant = if source == "gen-v1" { GenVariant::V1 } else { GenVariant::V2 };
            let fm = FloatModel::new(runtime, weights)?;
            generate_calib(&fm, variant, n, seq, seed)
        }
        "random" => Ok(random_calib(&corpus::train_spec(), n, seq, seed)),
        name => {
            let spec = spec_by_name(name)
                .ok_or_else(|| Error::Config(format!("unknown calib source {name}")))?;
            let stream = corpus::token_stream(&spec, n * seq);
            CalibSet::from_stream(&stream, n, seq, name)
        }
    }
}

//! GPTQ Hessian collection: accumulate `2 XᵀX` Gram matrices of the linear
//! inputs. The quantizer plugin API requests these lazily per linear through
//! `LayerContext::take_hessian`, which routes here — via the AOT `xtx` graph
//! when a runtime is live, or a CPU matmul for offline/test contexts.

// Justified unwraps: taps arrive pre-validated (non-empty shapes) from
// the capture path
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::error::Result;
use crate::quant::gptq::Hessian;
use crate::runtime::Runtime;
use crate::tensor::{matmul, transpose2d, Tensor};

use super::forward::FloatModel;

/// Hessian of one flattened `[rows, K]` activation tap through the AOT
/// `xtx` graph — the Gram matmul stays inside XLA.
pub fn hessian_from_tap(runtime: &Runtime, model: &str, flat: &Tensor) -> Result<Hessian> {
    let rows = flat.shape[0];
    let k = flat.shape[1];
    let xtx = runtime
        .run(model, &format!("xtx.k{k}"), &[flat])?
        .into_iter()
        .next()
        .unwrap();
    let mut h = Hessian::new(k);
    h.accumulate(&xtx, rows)?;
    Ok(h)
}

/// CPU fallback for contexts without a runtime (registry parity tests).
pub fn hessian_from_tap_cpu(flat: &Tensor) -> Result<Hessian> {
    let rows = flat.shape[0];
    let k = flat.shape[1];
    let xtx = matmul(&transpose2d(flat)?, flat)?;
    let mut h = Hessian::new(k);
    h.accumulate(&xtx, rows)?;
    Ok(h)
}

/// Hessians for (wqkv, wproj, wfc1, wfc2) of one layer, from the current
/// quantized-stream input `x_q`.
pub fn collect_hessians(
    fm: &FloatModel,
    runtime: &Runtime,
    layer: usize,
    x_q: &Tensor,
) -> Result<[Hessian; 4]> {
    let taps = fm.block_taps(layer, x_q)?;
    let model = &fm.weights.config.name;
    let mut out: Vec<Hessian> = Vec::with_capacity(4);
    for tap in &taps {
        let k = *tap.shape.last().unwrap();
        let rows: usize = tap.numel() / k;
        let flat = tap.clone().reshape(&[rows, k])?;
        out.push(hessian_from_tap(runtime, model, &flat)?);
    }
    Ok(out.try_into().expect("4 taps"))
}

//! GPTQ Hessian collection: tap the four linear-layer inputs of a block on
//! the (quantized-stream) calibration batch and accumulate `2 XᵀX` via the
//! AOT `xtx` graph — the Gram matmul stays inside XLA.

use crate::error::Result;
use crate::quant::gptq::Hessian;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::forward::FloatModel;

/// Hessians for (wqkv, wproj, wfc1, wfc2) of one layer, from the current
/// quantized-stream input `x_q`.
pub fn collect_hessians(
    fm: &FloatModel,
    runtime: &Runtime,
    layer: usize,
    x_q: &Tensor,
) -> Result<[Hessian; 4]> {
    let taps = fm.block_taps(layer, x_q)?;
    let model = &fm.weights.config.name;
    let mut out: Vec<Hessian> = Vec::with_capacity(4);
    for tap in &taps {
        let k = *tap.shape.last().unwrap();
        let rows: usize = tap.numel() / k;
        let flat = tap.clone().reshape(&[rows, k])?;
        let xtx = runtime
            .run(model, &format!("xtx.k{k}"), &[&flat])?
            .into_iter()
            .next()
            .unwrap();
        let mut h = Hessian::new(k);
        h.accumulate(&xtx, rows)?;
        out.push(h);
    }
    Ok(out.try_into().expect("4 taps"))
}

//! Model execution over the AOT graphs: float and quantized runners.
//!
//! Both runners compose `embed → block × L → head` from per-layer graphs —
//! exactly the granularity Algorithm 1 needs — with batch padding to the
//! exported buckets.

use crate::calib::vocab::PAD;
use crate::error::{Error, Result};
use crate::eval::LanguageModel;
use crate::model::{ModelConfig, ModelWeights, NormKind, QuantizedModel};
use crate::quant::act::fake_quant_per_row;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Pad a [B, ...] tensor up to `bucket` rows (zeros); returns (padded, b).
pub fn pad_batch(t: &Tensor, bucket: usize) -> Result<Tensor> {
    let b = t.shape[0];
    if b == bucket {
        return Ok(t.clone());
    }
    if b > bucket {
        return Err(Error::Shape(format!("batch {b} > bucket {bucket}")));
    }
    let per = t.numel() / b;
    let mut shape = t.shape.clone();
    shape[0] = bucket;
    Ok(match &t.data {
        crate::tensor::Storage::F32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, 0.0);
            Tensor::f32(&shape, d)
        }
        crate::tensor::Storage::I32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, PAD);
            Tensor::i32(&shape, d)
        }
        _ => return Err(Error::Shape("pad_batch: unsupported dtype".into())),
    })
}

fn slice_batch(t: Tensor, b: usize) -> Tensor {
    if t.shape[0] == b {
        return t;
    }
    let per = t.numel() / t.shape[0];
    let mut shape = t.shape.clone();
    shape[0] = b;
    match t.data {
        crate::tensor::Storage::F32(v) => Tensor::f32(&shape, v[..b * per].to_vec()),
        crate::tensor::Storage::I32(v) => Tensor::i32(&shape, v[..b * per].to_vec()),
        _ => unreachable!("slice_batch on unsupported dtype"),
    }
}

/// Float model runner (the `fOut` stream + FP16-analog baseline evals).
pub struct FloatModel<'rt, 'w> {
    pub runtime: &'rt Runtime,
    pub weights: &'w ModelWeights,
}

impl<'rt, 'w> FloatModel<'rt, 'w> {
    pub fn new(runtime: &'rt Runtime, weights: &'w ModelWeights) -> Result<Self> {
        runtime.manifest.verify_model(&weights.config)?;
        Ok(FloatModel { runtime, weights })
    }

    fn name(&self) -> &str {
        &self.weights.config.name
    }

    /// tokens i32[B, S] → x0 f32[B, S, d] (padded internally to a bucket).
    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, self.weights.get("tok_emb")?, self.weights.get("pos_emb")?],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One float block forward.
    pub fn block_fwd(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let bw = self.weights.block(layer)?;
        let mut args = vec![&padded];
        args.extend(bw.flat());
        let outs = self
            .runtime
            .run(self.name(), &format!("block_fwd.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// The four GPTQ tap activations of a layer (calib bucket only).
    pub fn block_taps(&self, layer: usize, x: &Tensor) -> Result<Vec<Tensor>> {
        let cb = self.runtime.manifest.calib_batch;
        if x.shape[0] != cb {
            return Err(Error::Shape(format!(
                "taps need the calib batch {cb}, got {}",
                x.shape[0]
            )));
        }
        let bw = self.weights.block(layer)?;
        let mut args = vec![x];
        args.extend(bw.flat());
        self.runtime
            .run(self.name(), &format!("block_taps.b{cb}"), &args)
    }

    /// Final norm + tied logits.
    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let mut args = vec![&padded, self.weights.get("lnf.g")?];
        if self.weights.config.norm == NormKind::LayerNorm {
            args.push(self.weights.get("lnf.b")?);
        }
        args.push(self.weights.get("tok_emb")?);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// Per-channel (mu, var) of an activation tensor via the stats graph.
    pub fn channel_stats(&self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let cb = self.runtime.manifest.calib_batch;
        let outs = self
            .runtime
            .run(self.name(), &format!("channel_stats.b{cb}"), &[x])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }
}

impl LanguageModel for FloatModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.weights.config.n_layer {
            x = self.block_fwd(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }
}

/// Quantized model runner (the `qOut` stream + quantized evals/serving).
///
/// `act_bits` (Some(8)/Some(4)) applies dynamic per-token activation
/// fake-quant to every block input and the head input — the joint W+A modes
/// of Tables 4 and 10.
pub struct QuantModel<'rt, 'q> {
    pub runtime: &'rt Runtime,
    pub model: &'q QuantizedModel,
    pub act_bits: Option<u8>,
}

impl<'rt, 'q> QuantModel<'rt, 'q> {
    pub fn new(runtime: &'rt Runtime, model: &'q QuantizedModel) -> Result<Self> {
        runtime.manifest.verify_model(&model.config)?;
        // a checkpoint quantized against differently-exported artifacts
        // (e.g. re-exported with a narrower --groups list) must fail here,
        // not at graph lookup inside the first served batch
        runtime.validate_grain(&model.scheme.group_tag())?;
        Ok(QuantModel { runtime, model, act_bits: None })
    }

    pub fn with_act_bits(mut self, bits: Option<u8>) -> Self {
        self.act_bits = bits;
        self
    }

    fn name(&self) -> &str {
        &self.model.config.name
    }

    fn group_tag(&self) -> String {
        self.model.scheme.group_tag()
    }

    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, &self.model.tok_emb, &self.model.pos_emb],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One quantized block forward (with optional activation fake-quant).
    pub fn block_fwd_q(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let blk = &self.model.blocks[layer];

        let cqkv = blk.qkv.codes_tensor();
        let cproj = blk.proj.codes_tensor();
        let cfc1 = blk.fc1.codes_tensor();
        let cfc2 = blk.fc2.codes_tensor();

        let mut args: Vec<&Tensor> = vec![&padded, &blk.ln1_g];
        if let Some(b1) = &blk.ln1_b {
            args.push(b1);
        }
        args.extend([&cqkv, &blk.qkv.scales, &blk.qkv.bias,
                     &cproj, &blk.proj.scales, &blk.proj.bias, &blk.ln2_g]);
        if let Some(b2) = &blk.ln2_b {
            args.push(b2);
        }
        args.extend([&cfc1, &blk.fc1.scales, &blk.fc1.bias,
                     &cfc2, &blk.fc2.scales, &blk.fc2.bias]);

        let outs = self.runtime.run(
            self.name(),
            &format!("block_fwd_q.{}.b{bucket}", self.group_tag()),
            &args,
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let mut args = vec![&padded, &self.model.lnf_g];
        if let Some(bb) = &self.model.lnf_b {
            args.push(bb);
        }
        args.push(&self.model.tok_emb);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }
}

impl LanguageModel for QuantModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.model.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.model.config.n_layer {
            x = self.block_fwd_q(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_slice_roundtrip() {
        let t = Tensor::f32(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_batch(&t, 8).unwrap();
        assert_eq!(p.shape, vec![8, 2]);
        assert_eq!(p.as_f32().unwrap()[..6], [1., 2., 3., 4., 5., 6.]);
        assert_eq!(p.as_f32().unwrap()[6..], [0.0; 10]);
        let s = slice_batch(p, 3);
        assert_eq!(s, t);
    }

    #[test]
    fn pad_tokens_uses_pad_id() {
        let t = Tensor::i32(&[1, 3], vec![5, 6, 7]);
        let p = pad_batch(&t, 2).unwrap();
        assert_eq!(p.as_i32().unwrap(), &[5, 6, 7, PAD, PAD, PAD]);
    }

    #[test]
    fn pad_rejects_oversize() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(pad_batch(&t, 2).is_err());
    }
}

//! Model execution over the AOT graphs: float and quantized runners.
//!
//! Both runners compose `embed → block × L → head` from per-layer graphs —
//! exactly the granularity Algorithm 1 needs — with batch padding to the
//! exported buckets.
//!
//! When the manifest carries a `decode` record, both runners also override
//! the [`LanguageModel`] session API with the slot-arena fast path: each
//! runner owns one [`KvArena`] (allocated once at construction, sized by
//! `decode.slots`), `prefill` runs the `block_fwd_kv` prefill graphs once
//! per prompt batch and writes every newcomer's cache rows into reserved
//! arena slots, and `decode_step` advances slot-resident sessions through
//! the fixed-shape `embed_dec → block_dec[_q] × L → head_dec` step graphs
//! with the arena tensors threaded as carried state via
//! [`Runtime::run_carry`] — zero per-step cache assembly of any kind.
//! Sessions admitted while the arena is full (or degraded by a failed
//! step) get [`KvCache::Recompute`] instead and ride the full-context
//! fallback; without the record the fallback serves everything — a
//! feature-gated degradation, never a failure.

// Justified unwraps: graph outputs and token rows are shape-checked at
// load time; `last()`/`next()` on them cannot fail
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::calib::vocab::PAD;
use crate::error::{Error, Result};
use crate::eval::decode::{
    self, lock_arena, ArenaSlot, DecodeSession, KvArena, KvCache, SharedKvArena,
};
use crate::eval::LanguageModel;
use crate::model::{ModelConfig, ModelWeights, NormKind, QuantizedBlock, QuantizedModel};
use crate::quant::act::fake_quant_per_row;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Pad a [B, ...] tensor up to `bucket` rows (zeros); returns (padded, b).
pub fn pad_batch(t: &Tensor, bucket: usize) -> Result<Tensor> {
    let b = t.shape[0];
    if b == bucket {
        return Ok(t.clone());
    }
    if b > bucket {
        return Err(Error::Shape(format!("batch {b} > bucket {bucket}")));
    }
    let per = t.numel() / b;
    let mut shape = t.shape.clone();
    shape[0] = bucket;
    Ok(match &t.data {
        crate::tensor::Storage::F32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, 0.0);
            Tensor::f32(&shape, d)
        }
        crate::tensor::Storage::I32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, PAD);
            Tensor::i32(&shape, d)
        }
        _ => return Err(Error::Shape("pad_batch: unsupported dtype".into())),
    })
}

fn slice_batch(t: Tensor, b: usize) -> Tensor {
    if t.shape[0] == b {
        return t;
    }
    let per = t.numel() / t.shape[0];
    let mut shape = t.shape.clone();
    shape[0] = b;
    match t.data {
        crate::tensor::Storage::F32(v) => Tensor::f32(&shape, v[..b * per].to_vec()),
        crate::tensor::Storage::I32(v) => Tensor::i32(&shape, v[..b * per].to_vec()),
        _ => unreachable!("slice_batch on unsupported dtype"),
    }
}

/// Padded `[B, seq]` token tensor for a prompt batch — the recompute
/// fallback's [`decode::padded_row`] convention (validation + pad token 0),
/// so both paths feed identical per-row inputs.  Malformed rows are
/// `Error::Config`.
fn prompt_tensor(prompts: &[Vec<i32>], seq: usize) -> Result<Tensor> {
    let b = prompts.len();
    let mut toks = Vec::with_capacity(b * seq);
    for p in prompts {
        toks.extend(decode::padded_row(p, seq)?);
    }
    Ok(Tensor::i32(&[b, seq], toks))
}

/// The slot arena a runner's manifest calls for: `Some` iff the manifest
/// has a decode record covering `name`.  Allocated once per runner at
/// construction — `decode.slots` rows per layer, `[slots, H, S, Dh]`.
pub(crate) fn arena_for(runtime: &Runtime, name: &str) -> Option<SharedKvArena> {
    let dec = runtime.manifest.decode.as_ref()?;
    let spec = runtime.manifest.decode_for(name)?;
    Some(KvArena::shared(
        spec.n_layer,
        spec.shape[0],
        spec.shape[1],
        spec.shape[2],
        dec.slots,
    ))
}

/// Per-row logits at each prompt's own last position, sliced out of a
/// batched `[B, S, V]` prefill head output.
fn prefill_logit_rows(prompts: &[Vec<i32>], logits: &Tensor) -> Result<Vec<Vec<f32>>> {
    let (seq, vocab) = (logits.shape[1], logits.shape[2]);
    let lv = logits.as_f32()?;
    Ok(prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let pos = p.len() - 1;
            lv[(i * seq + pos) * vocab..][..vocab].to_vec()
        })
        .collect())
}

/// Partition a step batch into slot-resident sessions and the rest
/// (recompute fallbacks, plus any externally-built layered sessions) — the
/// two halves advance through different paths and must not share a graph.
fn split_slotted<'a>(
    sessions: &'a mut [&mut DecodeSession],
) -> (Vec<&'a mut DecodeSession>, Vec<&'a mut DecodeSession>) {
    let mut slotted = Vec::new();
    let mut rest = Vec::new();
    for s in sessions.iter_mut() {
        if matches!(s.kv, KvCache::Slot(_)) {
            slotted.push(&mut **s);
        } else {
            rest.push(&mut **s);
        }
    }
    (slotted, rest)
}

/// Append a quantized block's weight arguments in the canonical manifest
/// order — the single source shared by `block_fwd_q`, `block_fwd_q_kv`,
/// and `block_dec_q`, so a signature change cannot drift between them.
/// (`codes_tensor` is cached inside the block, so this is cheap even on
/// the per-token decode hot path.)
fn extend_qblock_args<'a>(blk: &'a QuantizedBlock, args: &mut Vec<&'a Tensor>) {
    args.push(&blk.ln1_g);
    if let Some(b1) = &blk.ln1_b {
        args.push(b1);
    }
    args.extend([blk.qkv.codes_tensor(), &blk.qkv.scales, &blk.qkv.bias,
                 blk.proj.codes_tensor(), &blk.proj.scales, &blk.proj.bias,
                 &blk.ln2_g]);
    if let Some(b2) = &blk.ln2_b {
        args.push(b2);
    }
    args.extend([blk.fc1.codes_tensor(), &blk.fc1.scales, &blk.fc1.bias,
                 blk.fc2.codes_tensor(), &blk.fc2.scales, &blk.fc2.bias]);
}

/// Shared prefill driver: one batched `embed → per-layer KV block → head`
/// pass over the whole admission group, then slot admission — every
/// newcomer's cache rows are written into reserved arena slots in one
/// place.  The closures supply the model-specific graph calls (float vs
/// quantized); padding, the layer loop, and admission are identical by
/// construction — one place to change the protocol.
///
/// When the arena is absent, full, or degraded, the group still gets
/// correct sessions: the logits just computed are kept and the sessions
/// carry [`KvCache::Recompute`] — admission never fails for capacity.
fn run_prefill(
    cfg: &ModelConfig,
    prompts: &[Vec<i32>],
    arena: Option<&SharedKvArena>,
    embed: impl Fn(&Tensor) -> Result<Tensor>,
    block_kv: impl Fn(usize, &Tensor) -> Result<(Tensor, Tensor, Tensor)>,
    head: impl Fn(&Tensor) -> Result<Tensor>,
) -> Result<Vec<DecodeSession>> {
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let tokens = prompt_tensor(prompts, cfg.seq)?;
    let mut x = embed(&tokens)?;
    let mut layer_kv = Vec::with_capacity(cfg.n_layer);
    for l in 0..cfg.n_layer {
        let (nx, k, v) = block_kv(l, &x)?;
        x = nx;
        layer_kv.push((k, v));
    }
    let rows = prefill_logit_rows(prompts, &head(&x)?)?;

    let ids = arena.and_then(|a| lock_arena(a).try_reserve(prompts.len()));
    let (Some(a), Some(ids)) = (arena, ids) else {
        // overflow admission: the group rides the recompute fallback on
        // the logits already computed above
        return Ok(prompts
            .iter()
            .zip(rows)
            .map(|(p, logits)| DecodeSession {
                tokens: p.clone(),
                logits,
                kv: KvCache::Recompute,
            })
            .collect());
    };
    {
        let mut g = lock_arena(a);
        let mut first_err = None;
        'layers: for (l, (k, v)) in layer_kv.iter().enumerate() {
            for (row, &slot) in ids.iter().enumerate() {
                if let Err(e) = g.write_row(l, slot, k, v, row) {
                    first_err = Some(e);
                    break 'layers;
                }
            }
        }
        if let Some(e) = first_err {
            // hand the reservation back before surfacing the error — a
            // failed admission must not leak slots
            for &slot in &ids {
                g.release(slot);
            }
            return Err(e);
        }
        for (p, &slot) in prompts.iter().zip(&ids) {
            g.note(slot, *p.last().unwrap(), (p.len() - 1) as i32);
        }
    }
    Ok(prompts
        .iter()
        .zip(rows)
        .zip(ids)
        .map(|((p, logits), slot)| DecodeSession {
            tokens: p.clone(),
            logits,
            kv: KvCache::Slot(ArenaSlot::new(a.clone(), slot)),
        })
        .collect())
}

/// Shared one-token step driver over the slot arena: embed_dec →
/// per-layer carried block step (`block_step(layer, bucket, x, pos, kv)`)
/// → head_dec, always at the fixed `slots` bucket.  Each layer's arena
/// tensors are moved out, carried through the graph, and moved back — no
/// per-session assembly, copies, or allocations anywhere in the loop.
/// `head_act_bits` applies the W+A activation fake-quant to the head
/// input (quantized models only).
///
/// Row inputs: participants feed their newest `(token, position)`; every
/// other live slot re-feeds its shadow, so the graph's in-place cache
/// update rewrites values already there (deterministic kernels make that
/// bitwise idempotent); free slots feed `(0, 0)` and their rows are
/// overwritten by the next admission's prefill.
#[allow(clippy::too_many_arguments)]
fn run_decode_step(
    runtime: &Runtime,
    name: &str,
    cfg: &ModelConfig,
    sessions: &mut [&mut DecodeSession],
    arena: &SharedKvArena,
    tok_emb: &Tensor,
    pos_emb: &Tensor,
    block_step: impl Fn(usize, usize, &Tensor, &Tensor, Vec<Tensor>) -> Result<(Tensor, Vec<Tensor>)>,
    head_act_bits: Option<u8>,
    lnf_g: &Tensor,
    lnf_b: Option<&Tensor>,
) -> Result<()> {
    if sessions.is_empty() {
        return Ok(());
    }
    // participants: (slot, newest token, its position)
    let mut rows = Vec::with_capacity(sessions.len());
    for s in sessions.iter() {
        let slot = match &s.kv {
            KvCache::Slot(h) => h.index(),
            _ => {
                return Err(Error::Shape(
                    "arena decode step over a session without a slot".into(),
                ))
            }
        };
        if s.tokens.is_empty() {
            return Err(Error::Config("decode: empty session".into()));
        }
        if s.tokens.len() > cfg.seq {
            return Err(Error::Config(format!(
                "decode session at {} tokens exceeds the model context {}",
                s.tokens.len(),
                cfg.seq
            )));
        }
        rows.push((slot, *s.tokens.last().unwrap(), (s.tokens.len() - 1) as i32));
    }
    let bucket;
    let (tok_t, pos_t) = {
        let g = lock_arena(arena);
        bucket = g.slots();
        let mut tok = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for slot in 0..bucket {
            if let Some((t, p)) = g.shadow(slot) {
                tok[slot] = t;
                pos[slot] = p;
            }
        }
        for &(slot, t, p) in &rows {
            tok[slot] = t;
            pos[slot] = p;
        }
        (Tensor::i32(&[bucket, 1], tok), Tensor::i32(&[bucket], pos))
    };
    let mut x = {
        let outs = runtime.run(
            name,
            &format!("embed_dec.b{bucket}"),
            &[&tok_t, &pos_t, tok_emb, pos_emb],
        )?;
        outs.into_iter().next().unwrap()
    };
    for l in 0..cfg.n_layer {
        let kv = {
            let (k, v) = lock_arena(arena).take_layer(l)?;
            vec![k, v]
        };
        // if the graph call dies here the layer stays taken: the arena is
        // degraded, refuses admissions, and heals once the slots drain
        let (nx, mut carried) = block_step(l, bucket, &x, &pos_t, kv)?;
        x = nx;
        let v2 = carried
            .pop()
            .ok_or_else(|| Error::Shape("decode step carried no V cache".into()))?;
        let k2 = carried
            .pop()
            .ok_or_else(|| Error::Shape("decode step carried no K cache".into()))?;
        lock_arena(arena).put_layer(l, k2, v2)?;
    }
    let xh = match head_act_bits {
        Some(bits) => fake_quant_per_row(&x, bits)?,
        None => x,
    };
    let mut args: Vec<&Tensor> = vec![&xh, lnf_g];
    if let Some(b) = lnf_b {
        args.push(b);
    }
    args.push(tok_emb);
    let outs = runtime.run(name, &format!("head_dec.b{bucket}"), &args)?;
    // logits come back slot-indexed: each session reads its own row
    let vocab = *outs[0].shape.last().unwrap();
    let lv = outs[0].as_f32()?;
    let mut g = lock_arena(arena);
    for (s, &(slot, t, p)) in sessions.iter_mut().zip(&rows) {
        s.logits = lv[slot * vocab..][..vocab].to_vec();
        g.note(slot, t, p);
    }
    Ok(())
}

/// Float model runner (the `fOut` stream + FP16-analog baseline evals).
pub struct FloatModel<'rt, 'w> {
    pub runtime: &'rt Runtime,
    pub weights: &'w ModelWeights,
    /// Slot-arena KV store for the decode fast path (`None` without a
    /// manifest decode record — sessions then ride the recompute fallback).
    pub arena: Option<SharedKvArena>,
}

impl<'rt, 'w> FloatModel<'rt, 'w> {
    pub fn new(runtime: &'rt Runtime, weights: &'w ModelWeights) -> Result<Self> {
        runtime.manifest.verify_model(&weights.config)?;
        // a drifted decode cache record must fail here, not mid-request
        runtime.manifest.verify_decode(&weights.config)?;
        let arena = arena_for(runtime, &weights.config.name);
        Ok(FloatModel { runtime, weights, arena })
    }

    fn name(&self) -> &str {
        &self.weights.config.name
    }

    /// tokens i32[B, S] → x0 f32[B, S, d] (padded internally to a bucket).
    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, self.weights.get("tok_emb")?, self.weights.get("pos_emb")?],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One float block forward.
    pub fn block_fwd(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let bw = self.weights.block(layer)?;
        let mut args = vec![&padded];
        args.extend(bw.flat());
        let outs = self
            .runtime
            .run(self.name(), &format!("block_fwd.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// The four GPTQ tap activations of a layer (calib bucket only).
    pub fn block_taps(&self, layer: usize, x: &Tensor) -> Result<Vec<Tensor>> {
        let cb = self.runtime.manifest.calib_batch;
        if x.shape[0] != cb {
            return Err(Error::Shape(format!(
                "taps need the calib batch {cb}, got {}",
                x.shape[0]
            )));
        }
        let bw = self.weights.block(layer)?;
        let mut args = vec![x];
        args.extend(bw.flat());
        self.runtime
            .run(self.name(), &format!("block_taps.b{cb}"), &args)
    }

    /// Final norm + tied logits.
    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let mut args = vec![&padded, self.weights.get("lnf.g")?];
        if self.weights.config.norm == NormKind::LayerNorm {
            args.push(self.weights.get("lnf.b")?);
        }
        args.push(self.weights.get("tok_emb")?);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// Per-channel (mu, var) of an activation tensor via the stats graph.
    pub fn channel_stats(&self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let cb = self.runtime.manifest.calib_batch;
        let outs = self
            .runtime
            .run(self.name(), &format!("channel_stats.b{cb}"), &[x])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// One prefill block forward: like [`Self::block_fwd`] but also returns
    /// the per-head K/V cache tensors `[B, H, S, Dh]`.
    pub fn block_fwd_kv(&self, layer: usize, x: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let bw = self.weights.block(layer)?;
        let mut args = vec![&padded];
        args.extend(bw.flat());
        let outs = self
            .runtime
            .run(self.name(), &format!("block_fwd_kv.b{bucket}"), &args)?;
        let mut it = outs.into_iter();
        let (x2, k, v) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        Ok((slice_batch(x2, b), slice_batch(k, b), slice_batch(v, b)))
    }
}

impl LanguageModel for FloatModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.weights.config.n_layer {
            x = self.block_fwd(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }

    fn supports_decode(&self) -> bool {
        self.runtime.manifest.decode_for(&self.weights.config.name).is_some()
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        if !self.supports_decode() {
            return decode::recompute_prefill(self, prompts);
        }
        run_prefill(
            &self.weights.config,
            prompts,
            self.arena.as_ref(),
            |t| self.embed(t),
            |l, x| self.block_fwd_kv(l, x),
            |x| self.head(x),
        )
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        let Some(arena) = &self.arena else {
            return decode::recompute_decode_step(self, sessions);
        };
        let (mut slotted, mut rest) = split_slotted(sessions);
        if !rest.is_empty() {
            decode::recompute_decode_step(self, &mut rest)?;
        }
        if slotted.is_empty() {
            return Ok(());
        }
        if lock_arena(arena).is_degraded() {
            // demote-and-recompute: a degraded arena cannot step; the
            // demotions free the slots and let it heal
            return decode::recompute_decode_step(self, &mut slotted);
        }
        let cfg = &self.weights.config;
        let lnf_b = match cfg.norm {
            NormKind::LayerNorm => Some(self.weights.get("lnf.b")?),
            NormKind::RmsNorm => None,
        };
        run_decode_step(
            self.runtime,
            self.name(),
            cfg,
            &mut slotted,
            arena,
            self.weights.get("tok_emb")?,
            self.weights.get("pos_emb")?,
            |l, bucket, x, pos, kv| {
                let bw = self.weights.block(l)?;
                let mut args: Vec<&Tensor> = vec![x, pos];
                args.extend(bw.flat());
                let (mut fresh, carried) = self.runtime.run_carry(
                    self.name(),
                    &format!("block_dec.b{bucket}"),
                    &args,
                    kv,
                )?;
                Ok((fresh.remove(0), carried))
            },
            None,
            self.weights.get("lnf.g")?,
            lnf_b,
        )
    }

    fn kv_arena(&self) -> Option<SharedKvArena> {
        self.arena.clone()
    }
}

/// Quantized model runner (the `qOut` stream + quantized evals/serving).
///
/// `act_bits` (Some(8)/Some(4)) applies dynamic per-token activation
/// fake-quant to every block input and the head input — the joint W+A modes
/// of Tables 4 and 10.
pub struct QuantModel<'rt, 'q> {
    pub runtime: &'rt Runtime,
    pub model: &'q QuantizedModel,
    pub act_bits: Option<u8>,
    /// Slot-arena KV store for the decode fast path (`None` without a
    /// manifest decode record — sessions then ride the recompute fallback).
    pub arena: Option<SharedKvArena>,
}

impl<'rt, 'q> QuantModel<'rt, 'q> {
    pub fn new(runtime: &'rt Runtime, model: &'q QuantizedModel) -> Result<Self> {
        runtime.manifest.verify_model(&model.config)?;
        // a checkpoint quantized against differently-exported artifacts
        // (e.g. re-exported with a narrower --groups list) must fail here,
        // not at graph lookup inside the first served batch; likewise a
        // drifted decode cache record
        runtime.validate_grain(&model.scheme.group_tag())?;
        runtime.manifest.verify_decode(&model.config)?;
        let arena = arena_for(runtime, &model.config.name);
        Ok(QuantModel { runtime, model, act_bits: None, arena })
    }

    pub fn with_act_bits(mut self, bits: Option<u8>) -> Self {
        self.act_bits = bits;
        self
    }

    fn name(&self) -> &str {
        &self.model.config.name
    }

    fn group_tag(&self) -> String {
        self.model.scheme.group_tag()
    }

    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, &self.model.tok_emb, &self.model.pos_emb],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One quantized block forward (with optional activation fake-quant).
    pub fn block_fwd_q(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&padded];
        extend_qblock_args(blk, &mut args);

        let outs = self.runtime.run(
            self.name(),
            &format!("block_fwd_q.{}.b{bucket}", self.group_tag()),
            &args,
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let mut args = vec![&padded, &self.model.lnf_g];
        if let Some(bb) = &self.model.lnf_b {
            args.push(bb);
        }
        args.push(&self.model.tok_emb);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One quantized prefill block forward (with optional activation
    /// fake-quant): [`Self::block_fwd_q`] plus the K/V cache tensors.
    pub fn block_fwd_q_kv(&self, layer: usize, x: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&padded];
        extend_qblock_args(blk, &mut args);

        let outs = self.runtime.run(
            self.name(),
            &format!("block_fwd_q_kv.{}.b{bucket}", self.group_tag()),
            &args,
        )?;
        let mut it = outs.into_iter();
        let (x2, k, v) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        Ok((slice_batch(x2, b), slice_batch(k, b), slice_batch(v, b)))
    }

    /// One quantized one-token decode step over the carried arena caches.
    fn block_dec_q(
        &self,
        layer: usize,
        bucket: usize,
        x: &Tensor,
        pos: &Tensor,
        kv: Vec<Tensor>,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&xq, pos];
        extend_qblock_args(blk, &mut args);

        let (mut fresh, carried) = self.runtime.run_carry(
            self.name(),
            &format!("block_dec_q.{}.b{bucket}", self.group_tag()),
            &args,
            kv,
        )?;
        Ok((fresh.remove(0), carried))
    }
}

impl LanguageModel for QuantModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.model.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.model.config.n_layer {
            x = self.block_fwd_q(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }

    fn supports_decode(&self) -> bool {
        self.runtime.manifest.decode_for(&self.model.config.name).is_some()
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        if !self.supports_decode() {
            return decode::recompute_prefill(self, prompts);
        }
        run_prefill(
            &self.model.config,
            prompts,
            self.arena.as_ref(),
            |t| self.embed(t),
            |l, x| self.block_fwd_q_kv(l, x),
            |x| self.head(x),
        )
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        let Some(arena) = &self.arena else {
            return decode::recompute_decode_step(self, sessions);
        };
        let (mut slotted, mut rest) = split_slotted(sessions);
        if !rest.is_empty() {
            decode::recompute_decode_step(self, &mut rest)?;
        }
        if slotted.is_empty() {
            return Ok(());
        }
        if lock_arena(arena).is_degraded() {
            // demote-and-recompute: a degraded arena cannot step; the
            // demotions free the slots and let it heal
            return decode::recompute_decode_step(self, &mut slotted);
        }
        run_decode_step(
            self.runtime,
            self.name(),
            &self.model.config,
            &mut slotted,
            arena,
            &self.model.tok_emb,
            &self.model.pos_emb,
            |l, bucket, x, pos, kv| self.block_dec_q(l, bucket, x, pos, kv),
            self.act_bits,
            &self.model.lnf_g,
            self.model.lnf_b.as_ref(),
        )
    }

    fn kv_arena(&self) -> Option<SharedKvArena> {
        self.arena.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_slice_roundtrip() {
        let t = Tensor::f32(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_batch(&t, 8).unwrap();
        assert_eq!(p.shape, vec![8, 2]);
        assert_eq!(p.as_f32().unwrap()[..6], [1., 2., 3., 4., 5., 6.]);
        assert_eq!(p.as_f32().unwrap()[6..], [0.0; 10]);
        let s = slice_batch(p, 3);
        assert_eq!(s, t);
    }

    #[test]
    fn pad_tokens_uses_pad_id() {
        let t = Tensor::i32(&[1, 3], vec![5, 6, 7]);
        let p = pad_batch(&t, 2).unwrap();
        assert_eq!(p.as_i32().unwrap(), &[5, 6, 7, PAD, PAD, PAD]);
    }

    #[test]
    fn pad_rejects_oversize() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(pad_batch(&t, 2).is_err());
    }
}

//! Model execution over the AOT graphs: float and quantized runners.
//!
//! Both runners compose `embed → block × L → head` from per-layer graphs —
//! exactly the granularity Algorithm 1 needs — with batch padding to the
//! exported buckets.
//!
//! When the manifest carries a `decode` record, both runners also override
//! the [`LanguageModel`] session API: `prefill` runs the `block_fwd_kv`
//! prefill graphs once per prompt batch and seeds per-request KV caches,
//! and `decode_step` advances any mix of sessions by one token through the
//! fixed-shape `embed_dec → block_dec[_q] × L → head_dec` step graphs
//! (caches threaded as carried state via [`Runtime::run_carry`]).  Without
//! the record the trait's full-context recompute fallback serves instead —
//! a feature-gated degradation, never a failure.

// Justified unwraps: graph outputs and token rows are shape-checked at
// load time; `last()`/`next()` on them cannot fail
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::calib::vocab::PAD;
use crate::error::{Error, Result};
use crate::eval::decode::{self, DecodeSession, KvCache};
use crate::eval::LanguageModel;
use crate::model::{ModelConfig, ModelWeights, NormKind, QuantizedBlock, QuantizedModel};
use crate::quant::act::fake_quant_per_row;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Pad a [B, ...] tensor up to `bucket` rows (zeros); returns (padded, b).
pub fn pad_batch(t: &Tensor, bucket: usize) -> Result<Tensor> {
    let b = t.shape[0];
    if b == bucket {
        return Ok(t.clone());
    }
    if b > bucket {
        return Err(Error::Shape(format!("batch {b} > bucket {bucket}")));
    }
    let per = t.numel() / b;
    let mut shape = t.shape.clone();
    shape[0] = bucket;
    Ok(match &t.data {
        crate::tensor::Storage::F32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, 0.0);
            Tensor::f32(&shape, d)
        }
        crate::tensor::Storage::I32(v) => {
            let mut d = v.clone();
            d.resize(bucket * per, PAD);
            Tensor::i32(&shape, d)
        }
        _ => return Err(Error::Shape("pad_batch: unsupported dtype".into())),
    })
}

fn slice_batch(t: Tensor, b: usize) -> Tensor {
    if t.shape[0] == b {
        return t;
    }
    let per = t.numel() / t.shape[0];
    let mut shape = t.shape.clone();
    shape[0] = b;
    match t.data {
        crate::tensor::Storage::F32(v) => Tensor::f32(&shape, v[..b * per].to_vec()),
        crate::tensor::Storage::I32(v) => Tensor::i32(&shape, v[..b * per].to_vec()),
        _ => unreachable!("slice_batch on unsupported dtype"),
    }
}

/// Padded `[B, seq]` token tensor for a prompt batch — the recompute
/// fallback's [`decode::padded_row`] convention (validation + pad token 0),
/// so both paths feed identical per-row inputs.  Malformed rows are
/// `Error::Config`.
fn prompt_tensor(prompts: &[Vec<i32>], seq: usize) -> Result<Tensor> {
    let b = prompts.len();
    let mut toks = Vec::with_capacity(b * seq);
    for p in prompts {
        toks.extend(decode::padded_row(p, seq)?);
    }
    Ok(Tensor::i32(&[b, seq], toks))
}

/// Split batched prefill outputs into per-request sessions: row `i` gets
/// its logits at its own last prompt position plus its `[1, H, S, Dh]`
/// slice of every layer's K/V cache.
fn sessions_from_prefill(
    prompts: &[Vec<i32>],
    logits: &Tensor,
    layer_kv: &[(Tensor, Tensor)],
) -> Result<Vec<DecodeSession>> {
    let (seq, vocab) = (logits.shape[1], logits.shape[2]);
    let lv = logits.as_f32()?;
    let mut out = Vec::with_capacity(prompts.len());
    for (i, p) in prompts.iter().enumerate() {
        let kv: Vec<(Tensor, Tensor)> = layer_kv
            .iter()
            .map(|(k, v)| Ok((decode::cache_row(k, i)?, decode::cache_row(v, i)?)))
            .collect::<Result<_>>()?;
        let pos = p.len() - 1;
        out.push(DecodeSession {
            tokens: p.clone(),
            logits: lv[(i * seq + pos) * vocab..][..vocab].to_vec(),
            kv: KvCache::Layers(kv),
        });
    }
    Ok(out)
}

/// Build one step's `[bucket, 1]` token and `[bucket]` position inputs
/// (pad rows decode token 0 at position 0 and are discarded).
fn step_inputs(
    sessions: &[&mut DecodeSession],
    bucket: usize,
    seq: usize,
) -> Result<(Tensor, Tensor)> {
    let mut tok = vec![0i32; bucket];
    let mut pos = vec![0i32; bucket];
    for (i, s) in sessions.iter().enumerate() {
        if s.tokens.is_empty() {
            return Err(Error::Config("decode: empty session".into()));
        }
        if s.tokens.len() > seq {
            return Err(Error::Config(format!(
                "decode session at {} tokens exceeds the model context {seq}",
                s.tokens.len()
            )));
        }
        tok[i] = *s.tokens.last().unwrap();
        pos[i] = (s.tokens.len() - 1) as i32;
    }
    Ok((Tensor::i32(&[bucket, 1], tok), Tensor::i32(&[bucket], pos)))
}

/// Copy one step's `[bucket, 1, V]` logits back into the live sessions.
fn set_step_logits(sessions: &mut [&mut DecodeSession], logits: &Tensor) -> Result<()> {
    let vocab = *logits.shape.last().unwrap();
    let lv = logits.as_f32()?;
    for (i, s) in sessions.iter_mut().enumerate() {
        s.logits = lv[i * vocab..][..vocab].to_vec();
    }
    Ok(())
}

/// Whether every session carries a layered cache (a mixed batch falls back
/// to recompute — it cannot ride one decode graph).
fn all_layered(sessions: &[&mut DecodeSession]) -> bool {
    sessions.iter().all(|s| matches!(s.kv, KvCache::Layers(_)))
}

/// Append a quantized block's weight arguments in the canonical manifest
/// order — the single source shared by `block_fwd_q`, `block_fwd_q_kv`,
/// and `block_dec_q`, so a signature change cannot drift between them.
/// (`codes_tensor` is cached inside the block, so this is cheap even on
/// the per-token decode hot path.)
fn extend_qblock_args<'a>(blk: &'a QuantizedBlock, args: &mut Vec<&'a Tensor>) {
    args.push(&blk.ln1_g);
    if let Some(b1) = &blk.ln1_b {
        args.push(b1);
    }
    args.extend([blk.qkv.codes_tensor(), &blk.qkv.scales, &blk.qkv.bias,
                 blk.proj.codes_tensor(), &blk.proj.scales, &blk.proj.bias,
                 &blk.ln2_g]);
    if let Some(b2) = &blk.ln2_b {
        args.push(b2);
    }
    args.extend([blk.fc1.codes_tensor(), &blk.fc1.scales, &blk.fc1.bias,
                 blk.fc2.codes_tensor(), &blk.fc2.scales, &blk.fc2.bias]);
}

/// Shared prefill driver: embed → per-layer KV block → head, split into
/// per-request sessions.  The closures supply the model-specific graph
/// calls (float vs quantized); padding, the layer loop, and cache slicing
/// are identical by construction — one place to change the protocol.
fn run_prefill(
    cfg: &ModelConfig,
    prompts: &[Vec<i32>],
    embed: impl Fn(&Tensor) -> Result<Tensor>,
    block_kv: impl Fn(usize, &Tensor) -> Result<(Tensor, Tensor, Tensor)>,
    head: impl Fn(&Tensor) -> Result<Tensor>,
) -> Result<Vec<DecodeSession>> {
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let tokens = prompt_tensor(prompts, cfg.seq)?;
    let mut x = embed(&tokens)?;
    let mut layer_kv = Vec::with_capacity(cfg.n_layer);
    for l in 0..cfg.n_layer {
        let (nx, k, v) = block_kv(l, &x)?;
        x = nx;
        layer_kv.push((k, v));
    }
    sessions_from_prefill(prompts, &head(&x)?, &layer_kv)
}

/// Shared one-token step driver: embed_dec → per-layer carried block step
/// (`block_step(layer, bucket, x, pos, kv)`) → head_dec, with the caches
/// stacked/scattered around each layer call and the refreshed logits
/// written back into the sessions.  `head_act_bits` applies the W+A
/// activation fake-quant to the head input (quantized models only).
#[allow(clippy::too_many_arguments)]
fn run_decode_step(
    runtime: &Runtime,
    name: &str,
    cfg: &ModelConfig,
    sessions: &mut [&mut DecodeSession],
    tok_emb: &Tensor,
    pos_emb: &Tensor,
    block_step: impl Fn(usize, usize, &Tensor, &Tensor, Vec<Tensor>) -> Result<(Tensor, Vec<Tensor>)>,
    head_act_bits: Option<u8>,
    lnf_g: &Tensor,
    lnf_b: Option<&Tensor>,
) -> Result<()> {
    if sessions.is_empty() {
        return Ok(());
    }
    let dec = runtime.manifest.decode.as_ref().ok_or_else(|| {
        Error::Artifact("decode step driven without a manifest decode record".into())
    })?;
    let bucket = dec.bucket_for(sessions.len())?;
    let (tok_t, pos_t) = step_inputs(sessions, bucket, cfg.seq)?;
    let mut x = {
        let outs = runtime.run(
            name,
            &format!("embed_dec.b{bucket}"),
            &[&tok_t, &pos_t, tok_emb, pos_emb],
        )?;
        outs.into_iter().next().unwrap()
    };
    for l in 0..cfg.n_layer {
        let (k, v) = decode::stack_layer(sessions, l, bucket)?;
        let (nx, carried) = block_step(l, bucket, &x, &pos_t, vec![k, v])?;
        x = nx;
        decode::scatter_layer(sessions, l, &carried[0], &carried[1])?;
    }
    let xh = match head_act_bits {
        Some(bits) => fake_quant_per_row(&x, bits)?,
        None => x,
    };
    let mut args: Vec<&Tensor> = vec![&xh, lnf_g];
    if let Some(b) = lnf_b {
        args.push(b);
    }
    args.push(tok_emb);
    let outs = runtime.run(name, &format!("head_dec.b{bucket}"), &args)?;
    set_step_logits(sessions, &outs[0])
}

/// Float model runner (the `fOut` stream + FP16-analog baseline evals).
pub struct FloatModel<'rt, 'w> {
    pub runtime: &'rt Runtime,
    pub weights: &'w ModelWeights,
}

impl<'rt, 'w> FloatModel<'rt, 'w> {
    pub fn new(runtime: &'rt Runtime, weights: &'w ModelWeights) -> Result<Self> {
        runtime.manifest.verify_model(&weights.config)?;
        // a drifted decode cache record must fail here, not mid-request
        runtime.manifest.verify_decode(&weights.config)?;
        Ok(FloatModel { runtime, weights })
    }

    fn name(&self) -> &str {
        &self.weights.config.name
    }

    /// tokens i32[B, S] → x0 f32[B, S, d] (padded internally to a bucket).
    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, self.weights.get("tok_emb")?, self.weights.get("pos_emb")?],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One float block forward.
    pub fn block_fwd(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let bw = self.weights.block(layer)?;
        let mut args = vec![&padded];
        args.extend(bw.flat());
        let outs = self
            .runtime
            .run(self.name(), &format!("block_fwd.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// The four GPTQ tap activations of a layer (calib bucket only).
    pub fn block_taps(&self, layer: usize, x: &Tensor) -> Result<Vec<Tensor>> {
        let cb = self.runtime.manifest.calib_batch;
        if x.shape[0] != cb {
            return Err(Error::Shape(format!(
                "taps need the calib batch {cb}, got {}",
                x.shape[0]
            )));
        }
        let bw = self.weights.block(layer)?;
        let mut args = vec![x];
        args.extend(bw.flat());
        self.runtime
            .run(self.name(), &format!("block_taps.b{cb}"), &args)
    }

    /// Final norm + tied logits.
    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let mut args = vec![&padded, self.weights.get("lnf.g")?];
        if self.weights.config.norm == NormKind::LayerNorm {
            args.push(self.weights.get("lnf.b")?);
        }
        args.push(self.weights.get("tok_emb")?);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// Per-channel (mu, var) of an activation tensor via the stats graph.
    pub fn channel_stats(&self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let cb = self.runtime.manifest.calib_batch;
        let outs = self
            .runtime
            .run(self.name(), &format!("channel_stats.b{cb}"), &[x])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// One prefill block forward: like [`Self::block_fwd`] but also returns
    /// the per-head K/V cache tensors `[B, H, S, Dh]`.
    pub fn block_fwd_kv(&self, layer: usize, x: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        let b = x.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(x, bucket)?;
        let bw = self.weights.block(layer)?;
        let mut args = vec![&padded];
        args.extend(bw.flat());
        let outs = self
            .runtime
            .run(self.name(), &format!("block_fwd_kv.b{bucket}"), &args)?;
        let mut it = outs.into_iter();
        let (x2, k, v) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        Ok((slice_batch(x2, b), slice_batch(k, b), slice_batch(v, b)))
    }
}

impl LanguageModel for FloatModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.weights.config.n_layer {
            x = self.block_fwd(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }

    fn supports_decode(&self) -> bool {
        self.runtime.manifest.decode_for(&self.weights.config.name).is_some()
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        if !self.supports_decode() {
            return decode::recompute_prefill(self, prompts);
        }
        run_prefill(
            &self.weights.config,
            prompts,
            |t| self.embed(t),
            |l, x| self.block_fwd_kv(l, x),
            |x| self.head(x),
        )
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        if !self.supports_decode() || !all_layered(sessions) {
            return decode::recompute_decode_step(self, sessions);
        }
        let cfg = &self.weights.config;
        let lnf_b = match cfg.norm {
            NormKind::LayerNorm => Some(self.weights.get("lnf.b")?),
            NormKind::RmsNorm => None,
        };
        run_decode_step(
            self.runtime,
            self.name(),
            cfg,
            sessions,
            self.weights.get("tok_emb")?,
            self.weights.get("pos_emb")?,
            |l, bucket, x, pos, kv| {
                let bw = self.weights.block(l)?;
                let mut args: Vec<&Tensor> = vec![x, pos];
                args.extend(bw.flat());
                let (mut fresh, carried) = self.runtime.run_carry(
                    self.name(),
                    &format!("block_dec.b{bucket}"),
                    &args,
                    kv,
                )?;
                Ok((fresh.remove(0), carried))
            },
            None,
            self.weights.get("lnf.g")?,
            lnf_b,
        )
    }
}

/// Quantized model runner (the `qOut` stream + quantized evals/serving).
///
/// `act_bits` (Some(8)/Some(4)) applies dynamic per-token activation
/// fake-quant to every block input and the head input — the joint W+A modes
/// of Tables 4 and 10.
pub struct QuantModel<'rt, 'q> {
    pub runtime: &'rt Runtime,
    pub model: &'q QuantizedModel,
    pub act_bits: Option<u8>,
}

impl<'rt, 'q> QuantModel<'rt, 'q> {
    pub fn new(runtime: &'rt Runtime, model: &'q QuantizedModel) -> Result<Self> {
        runtime.manifest.verify_model(&model.config)?;
        // a checkpoint quantized against differently-exported artifacts
        // (e.g. re-exported with a narrower --groups list) must fail here,
        // not at graph lookup inside the first served batch; likewise a
        // drifted decode cache record
        runtime.validate_grain(&model.scheme.group_tag())?;
        runtime.manifest.verify_decode(&model.config)?;
        Ok(QuantModel { runtime, model, act_bits: None })
    }

    pub fn with_act_bits(mut self, bits: Option<u8>) -> Self {
        self.act_bits = bits;
        self
    }

    fn name(&self) -> &str {
        &self.model.config.name
    }

    fn group_tag(&self) -> String {
        self.model.scheme.group_tag()
    }

    pub fn embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(tokens, bucket)?;
        let outs = self.runtime.run(
            self.name(),
            &format!("embed.b{bucket}"),
            &[&padded, &self.model.tok_emb, &self.model.pos_emb],
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One quantized block forward (with optional activation fake-quant).
    pub fn block_fwd_q(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&padded];
        extend_qblock_args(blk, &mut args);

        let outs = self.runtime.run(
            self.name(),
            &format!("block_fwd_q.{}.b{bucket}", self.group_tag()),
            &args,
        )?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let mut args = vec![&padded, &self.model.lnf_g];
        if let Some(bb) = &self.model.lnf_b {
            args.push(bb);
        }
        args.push(&self.model.tok_emb);
        let outs = self
            .runtime
            .run(self.name(), &format!("head.b{bucket}"), &args)?;
        Ok(slice_batch(outs.into_iter().next().unwrap(), b))
    }

    /// One quantized prefill block forward (with optional activation
    /// fake-quant): [`Self::block_fwd_q`] plus the K/V cache tensors.
    pub fn block_fwd_q_kv(&self, layer: usize, x: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let b = xq.shape[0];
        let bucket = self.runtime.manifest.bucket_for(b)?;
        let padded = pad_batch(&xq, bucket)?;
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&padded];
        extend_qblock_args(blk, &mut args);

        let outs = self.runtime.run(
            self.name(),
            &format!("block_fwd_q_kv.{}.b{bucket}", self.group_tag()),
            &args,
        )?;
        let mut it = outs.into_iter();
        let (x2, k, v) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        Ok((slice_batch(x2, b), slice_batch(k, b), slice_batch(v, b)))
    }

    /// One quantized one-token decode step over the stacked caches.
    fn block_dec_q(
        &self,
        layer: usize,
        bucket: usize,
        x: &Tensor,
        pos: &Tensor,
        kv: Vec<Tensor>,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let xq = match self.act_bits {
            Some(bits) => fake_quant_per_row(x, bits)?,
            None => x.clone(),
        };
        let blk = &self.model.blocks[layer];
        let mut args: Vec<&Tensor> = vec![&xq, pos];
        extend_qblock_args(blk, &mut args);

        let (mut fresh, carried) = self.runtime.run_carry(
            self.name(),
            &format!("block_dec_q.{}.b{bucket}", self.group_tag()),
            &args,
            kv,
        )?;
        Ok((fresh.remove(0), carried))
    }
}

impl LanguageModel for QuantModel<'_, '_> {
    fn config(&self) -> &ModelConfig {
        &self.model.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(tokens)?;
        for l in 0..self.model.config.n_layer {
            x = self.block_fwd_q(l, &x)?;
        }
        self.head(&x)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }

    fn supports_decode(&self) -> bool {
        self.runtime.manifest.decode_for(&self.model.config.name).is_some()
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        if !self.supports_decode() {
            return decode::recompute_prefill(self, prompts);
        }
        run_prefill(
            &self.model.config,
            prompts,
            |t| self.embed(t),
            |l, x| self.block_fwd_q_kv(l, x),
            |x| self.head(x),
        )
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        if !self.supports_decode() || !all_layered(sessions) {
            return decode::recompute_decode_step(self, sessions);
        }
        run_decode_step(
            self.runtime,
            self.name(),
            &self.model.config,
            sessions,
            &self.model.tok_emb,
            &self.model.pos_emb,
            |l, bucket, x, pos, kv| self.block_dec_q(l, bucket, x, pos, kv),
            self.act_bits,
            &self.model.lnf_g,
            self.model.lnf_b.as_ref(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_slice_roundtrip() {
        let t = Tensor::f32(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_batch(&t, 8).unwrap();
        assert_eq!(p.shape, vec![8, 2]);
        assert_eq!(p.as_f32().unwrap()[..6], [1., 2., 3., 4., 5., 6.]);
        assert_eq!(p.as_f32().unwrap()[6..], [0.0; 10]);
        let s = slice_batch(p, 3);
        assert_eq!(s, t);
    }

    #[test]
    fn pad_tokens_uses_pad_id() {
        let t = Tensor::i32(&[1, 3], vec![5, 6, 7]);
        let p = pad_batch(&t, 2).unwrap();
        assert_eq!(p.as_i32().unwrap(), &[5, 6, 7, PAD, PAD, PAD]);
    }

    #[test]
    fn pad_rejects_oversize() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(pad_batch(&t, 2).is_err());
    }
}

//! In-tree replacements for the usual ecosystem crates (the image builds
//! fully offline with only the `xla` closure cached): a scoped thread pool,
//! a JSON value parser/emitter, a TOML-subset parser, and a micro-bench
//! harness used by `rust/benches/`.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod tomlmini;

//! In-tree replacements for the usual ecosystem crates (the image builds
//! fully offline with only the `xla` closure cached): a scoped thread pool,
//! a JSON value parser/emitter, a TOML-subset parser, a micro-bench
//! harness used by `rust/benches/`, and FNV-1a content hashing for
//! artifact provenance.

pub mod bench;
pub mod hash;
pub mod json;
pub mod parallel;
pub mod tomlmini;

//! TOML-subset parser for run configs: `[section]` headers and
//! `key = value` lines (strings, ints, floats, bools, flat string arrays).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    StrArr(Vec<String>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        match self {
            TomlValue::Float(f) => Some(*f as f32),
            TomlValue::Int(i) => Some(*i as f32),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str_arr(&self) -> Option<&[String]> {
        match self {
            TomlValue::StrArr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value (top-level keys in section "").
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: bad section", lineno + 1)))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let value = parse_value(v.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // only strip # outside quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(rest) = v.strip_prefix('"') {
        let s = rest
            .strip_suffix('"')
            .ok_or_else(|| Error::Config("unterminated string".into()))?;
        return Ok(TomlValue::Str(s.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| Error::Config("unterminated array".into()))?;
        let mut out = Vec::new();
        for item in inner.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            match parse_value(item)? {
                TomlValue::Str(s) => out.push(s),
                _ => return Err(Error::Config("only string arrays supported".into())),
            }
        }
        return Ok(TomlValue::StrArr(out));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::Config(format!("cannot parse value '{v}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [run]
            model = "nt-small"   # comment
            steps = 42
            lr = 1e-3
            on = true
            sets = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_usize(), Some(1));
        assert_eq!(doc.get("run", "model").unwrap().as_str(), Some("nt-small"));
        assert_eq!(doc.get("run", "steps").unwrap().as_usize(), Some(42));
        assert!((doc.get("run", "lr").unwrap().as_f32().unwrap() - 1e-3).abs() < 1e-9);
        assert_eq!(doc.get("run", "on").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("run", "sets").unwrap().as_str_arr().unwrap().len(), 2);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_reported_with_line() {
        let err = TomlDoc::parse("[run\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(TomlDoc::parse("x ~ 1").is_err());
        assert!(TomlDoc::parse("x = zap").is_err());
    }
}

//! Content hashing for artifact provenance — FNV-1a 64-bit.
//!
//! The search/recipe layer records what bytes an artifact *was* when a
//! decision was made (sensitivity profiles pin the float checkpoint,
//! recipes pin the profile and the manifest), so a later run can detect
//! that the input drifted instead of silently replaying a stale decision.
//! FNV-1a is not cryptographic — it defends against accidental drift
//! (re-exported weights, regenerated profiles), not adversaries, and it
//! keeps the crate dependency-free.

use std::path::Path;

use crate::error::Result;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a rendered as the canonical 16-digit lowercase hex string used in
/// every persisted provenance field.
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a(bytes))
}

/// Hash a file's exact on-disk bytes (no parse, no normalization — two
/// JSON files that differ only in whitespace hash differently on purpose:
/// the recorded hash pins the bytes that were read).
pub fn file_hex(path: impl AsRef<Path>) -> Result<String> {
    Ok(fnv1a_hex(&std::fs::read(path)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_hex(b""), "cbf29ce484222325");
    }

    #[test]
    fn file_hash_matches_bytes_and_detects_drift() {
        let dir = std::env::temp_dir().join("nt_hash_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        std::fs::write(&p, b"payload").unwrap();
        assert_eq!(file_hex(&p).unwrap(), fnv1a_hex(b"payload"));
        std::fs::write(&p, b"payload2").unwrap();
        assert_ne!(file_hex(&p).unwrap(), fnv1a_hex(b"payload"));
        assert!(file_hex(dir.join("missing.bin")).is_err());
    }
}

//! Minimal JSON: a recursive-descent parser into [`Json`] and an emitter.
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough for `manifest.json` and experiment
//! records; serde is not available offline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::msg(format!("trailing JSON at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact emit.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for emitting records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::msg("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::msg(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::msg(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::msg(format!("bad object sep '{}'", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => return Err(Error::msg(format!("bad array sep '{}'", c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::msg("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::msg("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::msg("bad escape")),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = utf8_len(c);
                    s.push_str(
                        std::str::from_utf8(&self.b[self.i - 1..self.i - 1 + len])
                            .map_err(|_| Error::msg("bad utf8 in string"))?,
                    );
                    self.i += len - 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        // the matched bytes are all ASCII, but surface a parse error rather
        // than panic if that ever stops holding
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::msg("bad number (non-utf8 bytes)"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::msg(format!("bad number '{text}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\n");
        let emitted = v.emit();
        let back = Json::parse(&emitted).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{"format": 1, "graphs": [{"name": "embed.b8",
            "inputs": [{"shape": [8, 128], "dtype": "i32"}]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize().unwrap(), 1);
        let g = &v.get("graphs").unwrap().as_arr().unwrap()[0];
        let shape = g.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 128);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""Aβγ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aβγ");
    }

    #[test]
    fn builders_emit() {
        let v = obj(vec![("x", n(1.5)), ("s", s("a\"b"))]);
        assert_eq!(v.emit(), r#"{"s":"a\"b","x":1.5}"#);
    }
}

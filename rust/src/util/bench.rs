//! Micro-benchmark harness (criterion stand-in) used by `rust/benches/`.
//!
//! Warmup + timed iterations, reporting mean / p50 / min per iteration and a
//! derived throughput line.  Deliberately simple: wall-clock monotonic time,
//! enough samples to be stable on an otherwise idle CI box.

use std::time::{Duration, Instant};

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} min  ({} iters)",
            self.name, self.mean, self.p50, self.min, self.iters
        )
    }

    /// items/second at the mean time, given items-per-iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: times[iters / 2],
        min: times[0],
    }
}

/// Time an operation for at least `budget`, auto-scaling iterations.
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_micros(1));
    let iters = ((budget.as_secs_f64() / one.as_secs_f64()).ceil() as usize).clamp(3, 1000);
    bench(name, 1, iters, f)
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.p50 && r.p50 <= r.mean * 3);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn bench_for_scales_iters() {
        let r = bench_for("quick", Duration::from_millis(5), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }
}

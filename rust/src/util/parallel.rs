//! Scoped data-parallel helpers over `std::thread` (rayon stand-in).

// Justified unwraps: worker-pool mutexes guard plain counters/iterators; a
// poisoned lock means a worker already panicked and the test run is lost
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use std::sync::Mutex;

/// Number of worker threads to use for `n_items` of work.
pub fn n_threads(n_items: usize) -> usize {
    std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(1)
        .min(n_items)
        .max(1)
}

/// Apply `f(chunk_index, chunk)` over `data.chunks_mut(chunk)` in parallel
/// (work-stealing via a shared iterator).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n_chunks = data.len().div_ceil(chunk.max(1));
    let threads = n_threads(n_chunks);
    if threads <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk.max(1)).enumerate() {
            f(i, c);
        }
        return;
    }
    let work = Mutex::new(data.chunks_mut(chunk).enumerate());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let item = work.lock().unwrap().next();
                match item {
                    Some((i, c)) => f(i, c),
                    None => break,
                }
            });
        }
    });
}

/// Parallel map over `0..n`, collecting results in index order.
pub fn par_map<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = n_threads(n);
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(out.iter_mut().enumerate());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let slot = slots.lock().unwrap().next();
                match slot {
                    Some((i, cell)) => {
                        *cell = Some(f(i));
                    }
                    None => break,
                }
            });
        }
    });
    out.into_iter().map(|x| x.expect("filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u32; 1003];
        par_chunks_mut(&mut v, 64, |i, c| {
            for x in c.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        assert_eq!(v[0], 1);
        assert_eq!(v[64], 2);
        assert_eq!(*v.last().unwrap(), 16); // chunk 15 -> value 16
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<usize> = par_map(0, |i| i);
        assert!(out.is_empty());
        let mut v = vec![1];
        par_chunks_mut(&mut v, 8, |_, c| c[0] = 9);
        assert_eq!(v, vec![9]);
    }
}

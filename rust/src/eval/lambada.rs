//! LAMBADA-syn: last-token accuracy on successor-cloze items (Table 2's
//! metric — see DESIGN.md §2 for the substitution rationale).

use crate::calib::corpus::lambada_syn;
use crate::error::Result;
use crate::tensor::Tensor;

use super::{argmax, LanguageModel};

/// The eval set: tokens + answer positions.
#[derive(Debug, Clone)]
pub struct LambadaSet {
    /// i32 [N, S]
    pub tokens: Tensor,
    pub answer_pos: Vec<usize>,
}

impl LambadaSet {
    /// Generate deterministically (same items as `artifacts/lambada_syn.ntz`).
    pub fn generate(seed: u64, n_items: usize, seq: usize) -> Self {
        let (items, pos) = lambada_syn(seed, n_items, seq);
        LambadaSet {
            tokens: Tensor::i32(&[n_items, seq], items),
            answer_pos: pos,
        }
    }

    /// The standard set used across the experiment tables.
    pub fn standard(seq: usize) -> Self {
        Self::generate(0x1A3B, 256, seq)
    }

    pub fn len(&self) -> usize {
        self.answer_pos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.answer_pos.is_empty()
    }
}

/// Accuracy (%) of `model` on the set, batched at `batch` items per call.
pub fn accuracy(model: &dyn LanguageModel, set: &LambadaSet, batch: usize) -> Result<f32> {
    let n = set.len();
    let seq = set.tokens.shape[1];
    let vocab = model.config().vocab;
    let toks = set.tokens.as_i32()?;
    let mut correct = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let chunk = Tensor::i32(&[b, seq], toks[i * seq..(i + b) * seq].to_vec());
        let logits = model.logits(&chunk)?;
        let lv = logits.as_f32()?;
        for r in 0..b {
            let p = set.answer_pos[i + r];
            let row = &lv[(r * seq + (p - 1)) * vocab..(r * seq + (p - 1)) * vocab + vocab];
            let pred = argmax(row) as i32;
            let truth = toks[(i + r) * seq + p];
            if pred == truth {
                correct += 1;
            }
        }
        i += b;
    }
    Ok(100.0 * correct as f32 / n as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_generation_deterministic() {
        let a = LambadaSet::generate(1, 8, 64);
        let b = LambadaSet::generate(1, 8, 64);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.answer_pos, b.answer_pos);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn answers_within_sequence() {
        let s = LambadaSet::standard(128);
        for &p in &s.answer_pos {
            assert!(p > 0 && p < 128);
        }
    }
}

//! Evaluation harness: LAMBADA-syn accuracy, perplexity, the multi-task
//! multiple-choice suite (LM-Eval-Harness analog), generation (full-context
//! and KV-cached incremental decode), and the subjective-eval scorer.

pub mod decode;
pub mod generate;
pub mod lambada;
pub mod ppl;
pub mod subjective;
pub mod tasks;

use crate::error::Result;
use crate::model::ModelConfig;
use crate::tensor::Tensor;

pub use decode::{ArenaSlot, DecodeSession, KvArena, KvCache, SharedKvArena};

/// Anything that maps token batches to logits — implemented by the float
/// and quantized runners in `coordinator::forward`.
///
/// Generation runs through the *session* API: [`Self::prefill`] turns
/// prompts into [`DecodeSession`]s, [`Self::decode_step`] advances any
/// subset of sessions by one token.  The default implementations fall back
/// to full-context recompute over [`Self::logits`], so every existing
/// implementor (mocks included) keeps working unchanged; runners whose
/// artifacts carry the manifest's `decode` record override them with the
/// KV-cached graphs and report [`Self::supports_decode`].
pub trait LanguageModel {
    fn config(&self) -> &ModelConfig;
    /// tokens i32[B, S] → logits f32[B, S, V]
    fn logits(&self, tokens: &Tensor) -> Result<Tensor>;
    /// Largest batch `logits` accepts in one call (`None` = unbounded).
    /// Runners backed by fixed-shape AOT graphs report the largest exported
    /// batch bucket; the serving loop splits oversized drains to fit.
    fn max_batch(&self) -> Option<usize> {
        None
    }
    /// Batch sizes the serving engine should prime at start-up (one warm-up
    /// generation per bucket, so first riders don't pay compile/dispatch
    /// latency).  Runners backed by AOT graphs report every exported batch
    /// bucket; the default primes only `max_batch`, and an empty vec
    /// disables warm-up for this model.
    fn warm_buckets(&self) -> Vec<usize> {
        self.max_batch().into_iter().collect()
    }
    /// Whether decode steps run O(1) over a KV cache (`true` for runners
    /// with exported decode graphs).  `false` means the session API is
    /// served by full-context recompute — correct, just O(S) per token.
    fn supports_decode(&self) -> bool {
        false
    }
    /// Batched prefill: run each prompt once and return a
    /// [`DecodeSession`] per row holding its next-token logits (and the
    /// per-layer KV cache when supported).  Rows may have ragged lengths;
    /// each session's logits sit at that row's own last position.
    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        decode::recompute_prefill(self, prompts)
    }
    /// Batched one-token step: for every session (whose caller just pushed
    /// the newly chosen token onto `tokens`), refresh `logits` to the new
    /// last position — consuming O(1) graph work when a cache is present.
    /// Any subset of live sessions may ride one step (continuous batching).
    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        decode::recompute_decode_step(self, sessions)
    }
    /// The slot-arena KV store backing this model's decode sessions, if it
    /// has one.  Runners with exported decode graphs share their arena here
    /// so the scheduler can watch occupancy; the recompute fallback has
    /// none.
    fn kv_arena(&self) -> Option<SharedKvArena> {
        None
    }
}

/// Log-softmax over the last dim of a logits row.
pub(crate) fn log_softmax_row(row: &[f32]) -> Vec<f32> {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
    row.iter().map(|&v| v - lse).collect()
}

/// Argmax index of a slice.
pub(crate) fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax_row(&[1.0, 2.0, 3.0]);
        let total: f32 = ls.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }
}

//! Batched text generation over any [`LanguageModel`] — used by the GenData
//! calibration scheme, the subjective eval, and the serving engine.
//!
//! Built on the incremental-decode session API: one [`LanguageModel::prefill`]
//! per batch, then one [`LanguageModel::decode_step`] per generated position.
//! Runners with exported decode graphs (the manifest's `decode` record)
//! advance O(1) per token over their KV caches; everything else falls back
//! to full-context recompute — numerically the historical fixed-shape
//! S=128 path.  Greedy output is token-identical across the two paths on
//! matched kernels (pinned by `rust/tests/decode_parity.rs`; real
//! artifacts admit only argmax near-ties within the Pallas↔oracle kernel
//! tolerance — see `eval::decode`).

use crate::calib::rng::SplitMix64;
use crate::error::{Error, Result};

use super::{argmax, DecodeSession, LanguageModel};

/// Sampling configuration for one generation run.
///
/// `PartialEq` is kept for callers that group requests by config; the
/// continuous-batching engine no longer needs it (each request samples from
/// its own seeded stream), but `generate` still drives one shared stream
/// per batch for reproducibility of the calibration/eval paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleConfig {
    /// softmax temperature for the stochastic stage (0 = greedy everywhere)
    pub temperature: f32,
    /// number of leading tokens sampled stochastically (LLM-QAT's stage 1);
    /// everything after is greedy (stage 2)
    pub stochastic_prefix: usize,
    pub seed: u64,
}

impl Default for SampleConfig {
    fn default() -> Self {
        SampleConfig { temperature: 1.0, stochastic_prefix: 4, seed: 0x5EED }
    }
}

/// Pick the next token for a session under `cfg`, feeding `rng`.
///
/// The stochastic stage covers positions before
/// `max(prompt_len, stochastic_prefix)`; everything after is greedy.
pub(crate) fn sample_next(
    session: &DecodeSession,
    prompt_len: usize,
    cfg: &SampleConfig,
    rng: &mut SplitMix64,
) -> i32 {
    if session.tokens.len() < prompt_len.max(cfg.stochastic_prefix) && cfg.temperature > 0.0 {
        sample_temperature(&session.logits, cfg.temperature, rng)
    } else {
        argmax(&session.logits) as i32
    }
}

/// Generate continuations for a batch of prompts.
///
/// `prompts[i]` is the existing token prefix of row i; all rows are extended
/// to `target_len` tokens.  Returns the full sequences.  Malformed inputs
/// (empty prompt rows, targets beyond the model context) are
/// [`Error::Config`] — a bad serve request must never abort the scheduler
/// thread that calls this.
pub fn generate(
    model: &dyn LanguageModel,
    prompts: &[Vec<i32>],
    target_len: usize,
    cfg: &SampleConfig,
) -> Result<Vec<Vec<i32>>> {
    let seq = model.config().seq;
    if target_len > seq {
        return Err(Error::Config(format!(
            "generation target {target_len} exceeds the model context {seq}"
        )));
    }
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    if let Some(i) = prompts.iter().position(|p| p.is_empty()) {
        return Err(Error::Config(format!("prompt row {i} is empty")));
    }
    let Some(min_len) = prompts.iter().map(|p| p.len()).min() else {
        return Ok(Vec::new()); // unreachable: emptiness was handled above
    };
    if target_len <= min_len {
        // nothing to generate for any row
        return Ok(prompts.to_vec());
    }

    let mut rng = SplitMix64::new(cfg.seed);
    let mut sessions = model.prefill(prompts)?;
    let mut cur = min_len;
    while cur < target_len {
        // rows at the frontier sample from their pending logits, in row
        // order, sharing one rng stream (the historical consumption order)
        let mut stepping: Vec<usize> = Vec::new();
        for (i, s) in sessions.iter_mut().enumerate() {
            if s.tokens.len() > cur {
                continue; // this row is ahead (longer prompt)
            }
            let tok = sample_next(s, prompts[i].len(), cfg, &mut rng);
            s.tokens.push(tok);
            if s.tokens.len() < target_len {
                stepping.push(i);
            }
        }
        cur += 1;
        if !stepping.is_empty() {
            // collect &mut refs to just the stepped rows (ascending order)
            let mut refs: Vec<&mut DecodeSession> = Vec::with_capacity(stepping.len());
            let mut rest = &mut sessions[..];
            let mut consumed = 0;
            for &i in &stepping {
                let (head, tail) = rest.split_at_mut(i - consumed + 1);
                refs.push(&mut head[i - consumed]);
                rest = tail;
                consumed = i + 1;
            }
            model.decode_step(&mut refs)?;
        }
    }
    Ok(sessions.into_iter().map(|s| s.tokens).collect())
}

/// Temperature sampling from a logits row.
pub(crate) fn sample_temperature(row: &[f32], temp: f32, rng: &mut SplitMix64) -> i32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = row
        .iter()
        .map(|&v| (((v - m) / temp) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let r = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * total;
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if r < acc {
            return i as i32;
        }
    }
    (row.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::tensor::Tensor;

    /// Fake model that always prefers token (last_token + 1) % vocab.
    struct Incrementing(ModelConfig);

    impl LanguageModel for Incrementing {
        fn config(&self) -> &ModelConfig {
            &self.0
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            let v = self.0.vocab;
            let tv = tokens.as_i32()?;
            let mut out = vec![0.0f32; b * s * v];
            for i in 0..b {
                for t in 0..s {
                    let next = ((tv[i * s + t] + 1) as usize) % v;
                    out[(i * s + t) * v + next] = 10.0;
                }
            }
            Ok(Tensor::f32(&[b, s, v], out))
        }
    }

    #[test]
    fn greedy_generation_follows_model() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let m = Incrementing(cfg);
        let cfg = SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 1 };
        let out = generate(&m, &[vec![5], vec![10, 11]], 6, &cfg).unwrap();
        assert_eq!(out[0], vec![5, 6, 7, 8, 9, 10]);
        assert_eq!(out[1], vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let m = Incrementing(cfg);
        let sc = SampleConfig { temperature: 1.0, stochastic_prefix: 4, seed: 9 };
        let a = generate(&m, &[vec![3]], 8, &sc).unwrap();
        let b = generate(&m, &[vec![3]], 8, &sc).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn malformed_requests_are_config_errors_not_panics() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let seq = cfg.seq;
        let m = Incrementing(cfg);
        let sc = SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 1 };
        // target beyond the fixed-shape context
        let err = generate(&m, &[vec![1]], seq + 1, &sc).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // empty prompt row
        let err = generate(&m, &[vec![1], vec![]], 4, &sc).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // empty batch and already-satisfied targets are no-ops
        assert!(generate(&m, &[], 4, &sc).unwrap().is_empty());
        let out = generate(&m, &[vec![7, 8, 9]], 2, &sc).unwrap();
        assert_eq!(out, vec![vec![7, 8, 9]]);
    }
}

//! Batched text generation over any [`LanguageModel`] — used by the GenData
//! calibration scheme, the subjective eval, and the serving loop.
//!
//! Full-context recompute per step (no KV cache: the AOT graphs are
//! fixed-shape; S=128 keeps this affordable — documented in DESIGN.md).

use crate::calib::rng::SplitMix64;
use crate::error::Result;
use crate::tensor::Tensor;

use super::{argmax, LanguageModel};

/// Sampling configuration for one generation run.
///
/// `PartialEq` matters to the serving engine: only requests with identical
/// sample configs may ride one batch (`generate` takes a single config).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleConfig {
    /// softmax temperature for the stochastic stage (0 = greedy everywhere)
    pub temperature: f32,
    /// number of leading tokens sampled stochastically (LLM-QAT's stage 1);
    /// everything after is greedy (stage 2)
    pub stochastic_prefix: usize,
    pub seed: u64,
}

impl Default for SampleConfig {
    fn default() -> Self {
        SampleConfig { temperature: 1.0, stochastic_prefix: 4, seed: 0x5EED }
    }
}

/// Generate continuations for a batch of prompts.
///
/// `prompts[i]` is the existing token prefix of row i; all rows are extended
/// to `target_len` tokens.  Returns the full sequences.
pub fn generate(
    model: &dyn LanguageModel,
    prompts: &[Vec<i32>],
    target_len: usize,
    cfg: &SampleConfig,
) -> Result<Vec<Vec<i32>>> {
    let seq = model.config().seq;
    let vocab = model.config().vocab;
    assert!(target_len <= seq);
    let b = prompts.len();
    let mut rng = SplitMix64::new(cfg.seed);
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    let min_len = seqs.iter().map(|s| s.len()).min().unwrap_or(0);
    assert!(min_len >= 1, "prompts must be non-empty");

    let mut cur = min_len;
    while cur < target_len {
        // pad all rows to seq, run one batched forward
        let mut toks = Vec::with_capacity(b * seq);
        for s in &seqs {
            let mut row = s.clone();
            row.resize(seq, 0);
            toks.extend(row);
        }
        let logits = model.logits(&Tensor::i32(&[b, seq], toks))?;
        let lv = logits.as_f32()?;
        for (i, s) in seqs.iter_mut().enumerate() {
            if s.len() > cur {
                continue; // this row is ahead (longer prompt)
            }
            let pos = s.len() - 1;
            let row = &lv[(i * seq + pos) * vocab..(i * seq + pos) * vocab + vocab];
            let new_tok = if s.len() < prompts[i].len().max(cfg.stochastic_prefix)
                && cfg.temperature > 0.0
            {
                sample_temperature(row, cfg.temperature, &mut rng)
            } else {
                argmax(row) as i32
            };
            s.push(new_tok);
        }
        cur += 1;
    }
    Ok(seqs)
}

/// Temperature sampling from a logits row.
fn sample_temperature(row: &[f32], temp: f32, rng: &mut SplitMix64) -> i32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = row
        .iter()
        .map(|&v| (((v - m) / temp) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let r = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * total;
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if r < acc {
            return i as i32;
        }
    }
    (row.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    /// Fake model that always prefers token (last_token + 1) % vocab.
    struct Incrementing(ModelConfig);

    impl LanguageModel for Incrementing {
        fn config(&self) -> &ModelConfig {
            &self.0
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            let v = self.0.vocab;
            let tv = tokens.as_i32()?;
            let mut out = vec![0.0f32; b * s * v];
            for i in 0..b {
                for t in 0..s {
                    let next = ((tv[i * s + t] + 1) as usize) % v;
                    out[(i * s + t) * v + next] = 10.0;
                }
            }
            Ok(Tensor::f32(&[b, s, v], out))
        }
    }

    #[test]
    fn greedy_generation_follows_model() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let m = Incrementing(cfg);
        let cfg = SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 1 };
        let out = generate(&m, &[vec![5], vec![10, 11]], 6, &cfg).unwrap();
        assert_eq!(out[0], vec![5, 6, 7, 8, 9, 10]);
        assert_eq!(out[1], vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let m = Incrementing(cfg);
        let sc = SampleConfig { temperature: 1.0, stochastic_prefix: 4, seed: 9 };
        let a = generate(&m, &[vec![3]], 8, &sc).unwrap();
        let b = generate(&m, &[vec![3]], 8, &sc).unwrap();
        assert_eq!(a, b);
    }
}

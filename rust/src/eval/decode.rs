//! Incremental decode: per-request KV-cache sessions over the AOT decode
//! graphs, with a full-context recompute fallback that works on *every*
//! [`LanguageModel`] (mocks included).
//!
//! A [`DecodeSession`] is the unit of continuous batching: it owns one
//! request's token history, the logits row for its next position, and its
//! cache residency.  Sessions are created batched by
//! [`LanguageModel::prefill`] and advanced batched by
//! [`LanguageModel::decode_step`]; the serving engine moves sessions in
//! and out of a step batch freely, because each session is self-contained
//! (rows of one step may sit at different sequence depths).
//!
//! # The slot arena
//!
//! On runners whose artifacts carry the manifest `decode` record, caches
//! live in a [`KvArena`]: per layer, one owned `(K, V)` tensor pair of
//! shape `[slots, H, S, Dh]` allocated once (slots = the manifest's
//! `decode.slots`, the largest exported decode bucket).  A session is
//! *admitted into a slot* ([`KvCache::Slot`]): prefill writes its rows
//! into the arena once, every decode step threads the arena tensors
//! through the step graph as carried state (zero per-step stacking,
//! scattering, or row copies), and retirement — dropping the session —
//! frees the slot through [`ArenaSlot`]'s `Drop`.
//!
//! Decode steps always run at the fixed `slots` bucket.  Rows whose
//! sessions participate in the step feed their newest token; every other
//! *live* slot re-feeds the last `(token, position)` it wrote (the arena's
//! shadow state), so the graph's in-place cache update rewrites the same
//! values — deterministic kernels make the rewrite bitwise idempotent —
//! and any subset of sessions can ride one step without corrupting its
//! batch-mates.  Free slots feed `(0, 0)`; whatever lands in their rows is
//! fully overwritten by the next admission's prefill.
//!
//! Greedy decode through a session is **token-identical** to the classic
//! full-recompute [`super::generate::generate`] path: causal attention
//! makes the next-token logits of a row depend only on its own prefix, so
//! recomputing the prefix (fallback) and replaying it from the cache
//! (decode graphs) rank the same argmax token.  `rust/tests/decode_parity.rs`
//! pins this on matched kernels, and the engine's response cache relies on
//! it.  (On real artifacts the step graphs run the jnp oracle kernels while
//! the full-context graphs run Pallas — equal to ~2e-4 — so the only
//! admissible divergence is an argmax near-tie inside that tolerance; the
//! artifact-gated test in `integration_eval.rs` enforces the bound.)

use std::sync::{Arc, Mutex, MutexGuard};

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::{argmax, LanguageModel};

/// The cache side of a session.
pub enum KvCache {
    /// The model keeps no incremental state: every decode step re-runs the
    /// full fixed-shape forward over the session's token history.  Always
    /// correct, O(S) per token — the path taken when the manifest has no
    /// `decode` record, by plain mocks, and by sessions admitted while the
    /// arena was full.
    Recompute,
    /// Per-layer `(k, v)` cache tensors, each `f32[1, H, S, Dh]`, owned by
    /// the session itself.  The legacy stacked-decode representation: a
    /// step batch is assembled by [`stack_layer`] and disassembled by
    /// [`scatter_layer`] around every graph call.  Kept for external
    /// callers and the parity tests; the runners now admit into the arena.
    Layers(Vec<(Tensor, Tensor)>),
    /// Slot-resident: the session's caches live inside a shared
    /// [`KvArena`] at this slot and are indexed by the decode graphs in
    /// place — zero per-step assembly.  Dropping the handle (retirement)
    /// frees the slot.
    Slot(ArenaSlot),
}

/// One request's decode state: token history, next-token logits, cache.
pub struct DecodeSession {
    /// prompt + generated tokens so far
    pub tokens: Vec<i32>,
    /// logits row (length = vocab) for the token at position
    /// `tokens.len()` — refreshed by `prefill` / `decode_step`
    pub logits: Vec<f32>,
    /// model-specific cache state
    pub kv: KvCache,
}

impl DecodeSession {
    /// Next write position (== current sequence length).
    pub fn pos(&self) -> usize {
        self.tokens.len()
    }

    /// Greedy choice from the current logits row.
    pub fn greedy_next(&self) -> i32 {
        argmax(&self.logits) as i32
    }
}

/// A [`KvArena`] behind the lock that every slot handle shares.  The
/// scheduler is single-threaded, so the lock is uncontended; it exists so
/// [`ArenaSlot`]s can free their slot from `Drop` wherever the session
/// dies.
pub type SharedKvArena = Arc<Mutex<KvArena>>;

/// Lock a shared arena, recovering from poisoning (the arena holds no
/// invariants a panicked holder could have half-applied that matter more
/// than serving the next request — a degraded arena already refuses
/// reservations on its own flag).
pub fn lock_arena(arena: &SharedKvArena) -> MutexGuard<'_, KvArena> {
    arena.lock().unwrap_or_else(|e| e.into_inner())
}

/// The slot-arena KV store of one model runner: per layer, one owned
/// `(K, V)` tensor pair of shape `[slots, H, S, Dh]`, allocated once, plus
/// a free list and the per-slot *shadow* — the last `(token, position)`
/// written into each live slot, re-fed on steps the slot's session sits
/// out so the graph's cache update is an idempotent rewrite.
///
/// Slot lifecycle: [`KvArena::try_reserve`] at admission →
/// [`KvArena::write_row`] per layer from the batched prefill outputs →
/// [`KvArena::take_layer`]/[`KvArena::put_layer`] around each decode
/// step's carried graph call → [`KvArena::release`] (via [`ArenaSlot`]'s
/// `Drop`) at retirement.
///
/// If a step graph fails between `take_layer` and `put_layer`, the layer
/// keeps its placeholder and the arena reports [`KvArena::is_degraded`]:
/// reservations stop, resident sessions are demoted to recompute by the
/// runners, and once the last slot drains the arena re-zeroes the taken
/// layers and heals itself.
pub struct KvArena {
    slots: usize,
    n_head: usize,
    seq: usize,
    d_head: usize,
    /// per layer: (K, V), each `[slots, n_head, seq, d_head]`
    layers: Vec<(Tensor, Tensor)>,
    /// layers currently moved out by [`KvArena::take_layer`]
    taken: Vec<bool>,
    /// free slot indices (pop order: lowest first)
    free: Vec<usize>,
    /// per-slot shadow: last `(token, position)` written, `None` when free
    /// or not yet prefilled
    shadow: Vec<Option<(i32, i32)>>,
}

impl KvArena {
    /// Allocate a zeroed arena for `n_layer` layers of `[slots, n_head,
    /// seq, d_head]` caches.
    pub fn new(n_layer: usize, n_head: usize, seq: usize, d_head: usize, slots: usize) -> Self {
        let shape = [slots, n_head, seq, d_head];
        KvArena {
            slots,
            n_head,
            seq,
            d_head,
            layers: (0..n_layer)
                .map(|_| (Tensor::zeros(&shape), Tensor::zeros(&shape)))
                .collect(),
            taken: vec![false; n_layer],
            free: (0..slots).rev().collect(),
            shadow: vec![None; slots],
        }
    }

    /// [`KvArena::new`] wrapped for sharing with slot handles.
    pub fn shared(n_layer: usize, n_head: usize, seq: usize, d_head: usize, slots: usize) -> SharedKvArena {
        Arc::new(Mutex::new(KvArena::new(n_layer, n_head, seq, d_head, slots)))
    }

    /// Total slot capacity (== the fixed decode bucket the arena steps at).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Number of layers the arena holds caches for.
    pub fn n_layer(&self) -> usize {
        self.layers.len()
    }

    /// Slots currently reserved by live sessions.
    pub fn occupancy(&self) -> usize {
        self.slots - self.free.len()
    }

    /// A step graph failed mid-carry and left a layer without its cache
    /// tensors: the arena refuses reservations until it drains and heals.
    pub fn is_degraded(&self) -> bool {
        self.taken.iter().any(|&t| t)
    }

    /// Reserve `n` slots, or `None` if the arena is degraded or has fewer
    /// than `n` free (admission then falls back to recompute sessions).
    pub fn try_reserve(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.is_degraded() || self.free.len() < n {
            return None;
        }
        Some((0..n).filter_map(|_| self.free.pop()).collect())
    }

    /// Return a slot to the free list and clear its shadow.  Releasing an
    /// already-free slot is a no-op (a demoted session may race its own
    /// retirement).  Draining the last slot heals a degraded arena by
    /// re-zeroing the layers a failed step left behind.
    pub fn release(&mut self, slot: usize) {
        if slot >= self.slots || self.free.contains(&slot) {
            return;
        }
        self.shadow[slot] = None;
        self.free.push(slot);
        if self.occupancy() == 0 && self.is_degraded() {
            let shape = [self.slots, self.n_head, self.seq, self.d_head];
            for (l, taken) in self.taken.iter_mut().enumerate() {
                if *taken {
                    self.layers[l] = (Tensor::zeros(&shape), Tensor::zeros(&shape));
                    *taken = false;
                }
            }
        }
    }

    /// Record the last `(token, position)` written into `slot` — the value
    /// its row re-feeds on steps this slot's session sits out.
    pub fn note(&mut self, slot: usize, token: i32, position: i32) {
        if let Some(s) = self.shadow.get_mut(slot) {
            *s = Some((token, position));
        }
    }

    /// The shadow of `slot` (`None` for free / not-yet-prefilled slots).
    pub fn shadow(&self, slot: usize) -> Option<(i32, i32)> {
        self.shadow.get(slot).copied().flatten()
    }

    /// Copy row `row` of a batched `[B, H, S, Dh]` prefill output pair into
    /// `slot` of layer `layer` — the one copy a request pays, at admission.
    pub fn write_row(
        &mut self,
        layer: usize,
        slot: usize,
        k: &Tensor,
        v: &Tensor,
        row: usize,
    ) -> Result<()> {
        if slot >= self.slots {
            return Err(Error::Shape(format!(
                "kv arena: slot {slot} out of range (slots = {})",
                self.slots
            )));
        }
        if self.taken.get(layer).copied().unwrap_or(true) {
            return Err(Error::Shape(format!(
                "kv arena: layer {layer} unavailable (out of range or mid-step)"
            )));
        }
        let per = self.n_head * self.seq * self.d_head;
        let (ks, kn) = row_span(k, row)?;
        let (vs, vn) = row_span(v, row)?;
        if kn != per || vn != per {
            return Err(Error::Shape(format!(
                "kv arena: prefill row of {kn}/{vn} elements does not match \
                 the arena row of {per}"
            )));
        }
        let (lk, lv) = &mut self.layers[layer];
        lk.as_f32_mut()?[slot * per..][..per].copy_from_slice(&k.as_f32()?[ks..ks + kn]);
        lv.as_f32_mut()?[slot * per..][..per].copy_from_slice(&v.as_f32()?[vs..vs + vn]);
        Ok(())
    }

    /// Move layer `layer`'s `(K, V)` tensors out for a carried graph call.
    /// The arena is degraded until [`KvArena::put_layer`] hands them back.
    pub fn take_layer(&mut self, layer: usize) -> Result<(Tensor, Tensor)> {
        if self.taken.get(layer).copied().unwrap_or(true) {
            return Err(Error::Shape(format!(
                "kv arena: layer {layer} unavailable (out of range or mid-step)"
            )));
        }
        self.taken[layer] = true;
        let placeholder = (Tensor::zeros(&[1]), Tensor::zeros(&[1]));
        Ok(std::mem::replace(&mut self.layers[layer], placeholder))
    }

    /// Store the carried `(K, V)` back into layer `layer` (shape-checked).
    pub fn put_layer(&mut self, layer: usize, k: Tensor, v: Tensor) -> Result<()> {
        if !self.taken.get(layer).copied().unwrap_or(false) {
            return Err(Error::Shape(format!(
                "kv arena: put_layer({layer}) without a matching take_layer"
            )));
        }
        let want = [self.slots, self.n_head, self.seq, self.d_head];
        if k.shape != want || v.shape != want {
            return Err(Error::Shape(format!(
                "kv arena: carried layer {layer} shapes {:?}/{:?} != {want:?}",
                k.shape, v.shape
            )));
        }
        self.layers[layer] = (k, v);
        self.taken[layer] = false;
        Ok(())
    }
}

/// A session's reservation inside a [`KvArena`].  Dropping the handle
/// releases the slot — retirement is just letting the session go.
pub struct ArenaSlot {
    arena: SharedKvArena,
    slot: usize,
}

impl ArenaSlot {
    pub fn new(arena: SharedKvArena, slot: usize) -> Self {
        ArenaSlot { arena, slot }
    }

    /// The slot index (== this session's row in every arena tensor and in
    /// the step graph's batch dimension).
    pub fn index(&self) -> usize {
        self.slot
    }

    /// The arena this slot lives in.
    pub fn arena(&self) -> &SharedKvArena {
        &self.arena
    }
}

impl Drop for ArenaSlot {
    fn drop(&mut self) {
        lock_arena(&self.arena).release(self.slot);
    }
}

impl std::fmt::Debug for ArenaSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArenaSlot").field("slot", &self.slot).finish()
    }
}

/// Validate one row against the model context and return it padded to the
/// full sequence (token 0 — the same padding the classic `generate` used,
/// so fallback logits are bit-identical to the historical path).  Shared
/// with the XLA runners' prefill so both paths keep one convention.
pub(crate) fn padded_row(row: &[i32], seq: usize) -> Result<Vec<i32>> {
    if row.is_empty() {
        return Err(Error::Config("decode: empty token row".into()));
    }
    if row.len() > seq {
        return Err(Error::Config(format!(
            "decode: row of {} tokens exceeds the model context {seq}",
            row.len()
        )));
    }
    let mut padded = row.to_vec();
    padded.resize(seq, 0);
    Ok(padded)
}

/// Full-context logits rows at each row's last position — the shared core
/// of both recompute fallbacks: one batched fixed-shape forward, rows
/// padded to `seq`.
fn recompute_rows<M: LanguageModel + ?Sized>(
    model: &M,
    rows: &[&[i32]],
) -> Result<Vec<Vec<f32>>> {
    let seq = model.config().seq;
    let vocab = model.config().vocab;
    let b = rows.len();
    let mut toks = Vec::with_capacity(b * seq);
    for row in rows {
        toks.extend(padded_row(row, seq)?);
    }
    let logits = model.logits(&Tensor::i32(&[b, seq], toks))?;
    let lv = logits.as_f32()?;
    Ok(rows
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let pos = row.len() - 1;
            lv[(i * seq + pos) * vocab..][..vocab].to_vec()
        })
        .collect())
}

/// Fallback prefill: one batched full-context forward, sessions carry no
/// cache ([`KvCache::Recompute`]).
pub fn recompute_prefill<M: LanguageModel + ?Sized>(
    model: &M,
    prompts: &[Vec<i32>],
) -> Result<Vec<DecodeSession>> {
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let rows: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let logits = recompute_rows(model, &rows)?;
    Ok(prompts
        .iter()
        .zip(logits)
        .map(|(p, l)| DecodeSession { tokens: p.clone(), logits: l, kv: KvCache::Recompute })
        .collect())
}

/// Fallback decode step: re-run the full forward over each session's
/// history and refresh its next-token logits.
///
/// A slot-resident session routed here is *demoted* to
/// [`KvCache::Recompute`] first (freeing its slot): the recompute forward
/// never updates the arena row, so the cache would silently go stale on
/// the next arena step.  Demotion keeps the session correct at O(S)/token
/// cost — the runners use this as the safety net when the arena degrades.
pub fn recompute_decode_step<M: LanguageModel + ?Sized>(
    model: &M,
    sessions: &mut [&mut DecodeSession],
) -> Result<()> {
    if sessions.is_empty() {
        return Ok(());
    }
    for s in sessions.iter_mut() {
        if matches!(s.kv, KvCache::Slot(_)) {
            s.kv = KvCache::Recompute; // drops the ArenaSlot -> frees the slot
        }
    }
    let logits = {
        let rows: Vec<&[i32]> = sessions.iter().map(|s| s.tokens.as_slice()).collect();
        recompute_rows(model, &rows)?
    };
    for (s, l) in sessions.iter_mut().zip(logits) {
        s.logits = l;
    }
    Ok(())
}

/// Bounds-checked `(offset, len)` of row `row` in the leading dimension of
/// a batched tensor — the flat span `[row * per .. row * per + per]` where
/// `per` is the product of the trailing dims.
pub(crate) fn row_span(t: &Tensor, row: usize) -> Result<(usize, usize)> {
    let b = *t.shape.first().ok_or_else(|| {
        Error::Shape("row_span: scalar tensor has no batch dimension".into())
    })?;
    if row >= b {
        return Err(Error::Shape(format!(
            "row_span: row {row} out of range (batch = {b})"
        )));
    }
    let per: usize = t.shape[1..].iter().product();
    Ok((row * per, per))
}

/// Slice row `i` of a `[B, H, S, Dh]` cache tensor into an owned
/// `[1, H, S, Dh]` per-session cache — copies only the row's span (one
/// memcpy; rows are contiguous in the leading dim).
pub fn cache_row(stacked: &Tensor, i: usize) -> Result<Tensor> {
    let (start, per) = row_span(stacked, i)?;
    let data = stacked.as_f32()?;
    let mut shape = stacked.shape.clone();
    shape[0] = 1;
    Ok(Tensor::f32(&shape, data[start..start + per].to_vec()))
}

/// Stack the layer-`layer` (K, V) caches of `sessions` into a pair of
/// `[bucket, H, S, Dh]` tensors (zero rows beyond the live sessions).
/// Errors if any session runs the recompute fallback — mixed batches
/// cannot ride one decode graph.
pub fn stack_layer(
    sessions: &[&mut DecodeSession],
    layer: usize,
    bucket: usize,
) -> Result<(Tensor, Tensor)> {
    let mut shape: Option<Vec<usize>> = None;
    let mut kd: Vec<f32> = Vec::new();
    let mut vd: Vec<f32> = Vec::new();
    for s in sessions {
        let (k, v) = match &s.kv {
            KvCache::Layers(l) => l.get(layer).ok_or_else(|| {
                Error::Shape(format!("decode session has no cache for layer {layer}"))
            })?,
            KvCache::Recompute => {
                return Err(Error::Shape(
                    "cannot stack a recompute-fallback session into a decode batch".into(),
                ))
            }
            KvCache::Slot(_) => {
                return Err(Error::Shape(
                    "slot-resident sessions ride the arena, not stacked decode batches".into(),
                ))
            }
        };
        if shape.is_none() {
            shape = Some(k.shape.clone());
            let per: usize = k.shape[1..].iter().product();
            kd.reserve(bucket * per);
            vd.reserve(bucket * per);
        }
        kd.extend_from_slice(k.as_f32()?);
        vd.extend_from_slice(v.as_f32()?);
    }
    let mut shape = shape.ok_or_else(|| Error::Shape("stack_layer: no sessions".into()))?;
    let per: usize = shape[1..].iter().product();
    kd.resize(bucket * per, 0.0);
    vd.resize(bucket * per, 0.0);
    shape[0] = bucket;
    Ok((Tensor::f32(&shape, kd), Tensor::f32(&shape, vd)))
}

/// Write the updated `[bucket, H, S, Dh]` caches of one layer back into the
/// live sessions (inverse of [`stack_layer`]; pad rows are dropped).
/// Rewrites each session's existing cache tensors in place when the shapes
/// match — no per-step allocation on the fallback path.
pub fn scatter_layer(
    sessions: &mut [&mut DecodeSession],
    layer: usize,
    k: &Tensor,
    v: &Tensor,
) -> Result<()> {
    for (i, s) in sessions.iter_mut().enumerate() {
        let layers = match &mut s.kv {
            KvCache::Layers(l) => l,
            KvCache::Recompute => {
                return Err(Error::Shape(
                    "cannot scatter a decode cache into a recompute session".into(),
                ))
            }
            KvCache::Slot(_) => {
                return Err(Error::Shape(
                    "slot-resident sessions ride the arena, not stacked decode batches".into(),
                ))
            }
        };
        let pair = layers.get_mut(layer).ok_or_else(|| {
            Error::Shape(format!("decode session has no cache for layer {layer}"))
        })?;
        let (ks, kn) = row_span(k, i)?;
        let (vs, vn) = row_span(v, i)?;
        let fits = |t: &Tensor, n: usize| t.as_f32().map(|d| d.len() == n).unwrap_or(false);
        if fits(&pair.0, kn) && fits(&pair.1, vn) {
            pair.0.as_f32_mut()?.copy_from_slice(&k.as_f32()?[ks..ks + kn]);
            pair.1.as_f32_mut()?.copy_from_slice(&v.as_f32()?[vs..vs + vn]);
        } else {
            *pair = (cache_row(k, i)?, cache_row(v, i)?);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    /// Prefix-sum mock: next-token preference depends on the *whole*
    /// prefix, so any cache/position bug shows up as a token mismatch.
    struct PrefixSum(ModelConfig);

    impl LanguageModel for PrefixSum {
        fn config(&self) -> &ModelConfig {
            &self.0
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            let v = self.0.vocab;
            let tv = tokens.as_i32()?;
            let mut out = vec![0.0f32; b * s * v];
            for i in 0..b {
                let mut sum = 0i64;
                for t in 0..s {
                    sum += tv[i * s + t] as i64;
                    let next = (sum.unsigned_abs() as usize + 1) % v;
                    out[(i * s + t) * v + next] = 5.0;
                }
            }
            Ok(Tensor::f32(&[b, s, v], out))
        }
    }

    #[test]
    fn recompute_prefill_sets_last_position_logits() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let sessions =
            recompute_prefill(&m, &[vec![3], vec![10, 20, 30]]).unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].pos(), 1);
        assert_eq!(sessions[1].pos(), 3);
        // row 0: sum=3 -> prefers 4; row 1: sum=60 -> prefers 61
        assert_eq!(sessions[0].greedy_next(), 4);
        assert_eq!(sessions[1].greedy_next(), 61);
        assert!(matches!(sessions[0].kv, KvCache::Recompute));
    }

    #[test]
    fn recompute_decode_step_advances_a_subset() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let mut sessions = recompute_prefill(&m, &[vec![1], vec![2]]).unwrap();
        // advance only row 1, as the continuous batcher does
        sessions[1].tokens.push(5);
        let (_a, b) = sessions.split_at_mut(1);
        let mut refs = vec![&mut b[0]];
        recompute_decode_step(&m, &mut refs).unwrap();
        assert_eq!(sessions[1].greedy_next(), 8); // 2 + 5 -> prefers 8
        assert_eq!(sessions[0].greedy_next(), 2); // untouched
    }

    #[test]
    fn empty_and_oversize_rows_are_config_errors() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let err = recompute_prefill(&m, &[vec![]]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let seq = m.config().seq;
        let err = recompute_prefill(&m, &[vec![1; seq + 1]]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // empty session batch is a no-op, not an error
        recompute_decode_step(&m, &mut []).unwrap();
        assert!(recompute_prefill(&m, &[]).unwrap().is_empty());
    }

    #[test]
    fn stack_scatter_roundtrip() {
        let mk = |base: f32| {
            vec![(
                Tensor::f32(&[1, 2, 2, 1], vec![base, base + 1.0, base + 2.0, base + 3.0]),
                Tensor::f32(&[1, 2, 2, 1], vec![-base; 4]),
            )]
        };
        let mut s0 = DecodeSession { tokens: vec![1], logits: vec![], kv: KvCache::Layers(mk(10.0)) };
        let mut s1 = DecodeSession { tokens: vec![2], logits: vec![], kv: KvCache::Layers(mk(20.0)) };
        let mut refs = vec![&mut s0, &mut s1];
        let (k, v) = stack_layer(&refs, 0, 4).unwrap();
        assert_eq!(k.shape, vec![4, 2, 2, 1]);
        assert_eq!(&k.as_f32().unwrap()[..4], &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(&k.as_f32().unwrap()[4..8], &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(&k.as_f32().unwrap()[8..], &[0.0; 8]);
        // mutate and scatter back
        let mut kd = k.as_f32().unwrap().to_vec();
        kd[0] = 99.0;
        let k2 = Tensor::f32(&k.shape, kd);
        scatter_layer(&mut refs, 0, &k2, &v).unwrap();
        match &s0.kv {
            KvCache::Layers(l) => {
                assert_eq!(l[0].0.shape, vec![1, 2, 2, 1]);
                assert_eq!(l[0].0.as_f32().unwrap()[0], 99.0);
                assert_eq!(l[0].1.as_f32().unwrap(), &[-10.0; 4]);
            }
            _ => panic!("expected layered cache"),
        }
    }

    #[test]
    fn mixed_cache_kinds_rejected_in_stack() {
        let mut s0 = DecodeSession { tokens: vec![1], logits: vec![], kv: KvCache::Recompute };
        let refs = vec![&mut s0];
        assert!(stack_layer(&refs, 0, 2).is_err());
    }

    #[test]
    fn arena_reserve_release_and_occupancy() {
        let mut a = KvArena::new(2, 2, 4, 1, 3);
        assert_eq!(a.slots(), 3);
        assert_eq!(a.occupancy(), 0);
        let ids = a.try_reserve(2).unwrap();
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(a.occupancy(), 2);
        // over-reservation refused without disturbing the free list
        assert!(a.try_reserve(2).is_none());
        assert_eq!(a.try_reserve(1).unwrap(), vec![2]);
        a.release(1);
        assert_eq!(a.occupancy(), 2);
        // double release is a no-op
        a.release(1);
        assert_eq!(a.occupancy(), 2);
        // freed slot is reused
        assert_eq!(a.try_reserve(1).unwrap(), vec![1]);
        // zero-slot reservation always succeeds
        assert_eq!(a.try_reserve(0).unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn arena_shadow_tracks_writes_and_clears_on_release() {
        let mut a = KvArena::new(1, 2, 4, 1, 2);
        let ids = a.try_reserve(1).unwrap();
        assert_eq!(a.shadow(ids[0]), None);
        a.note(ids[0], 7, 3);
        assert_eq!(a.shadow(ids[0]), Some((7, 3)));
        a.release(ids[0]);
        assert_eq!(a.shadow(ids[0]), None);
    }

    #[test]
    fn arena_write_row_copies_the_right_span() {
        let mut a = KvArena::new(1, 2, 2, 1, 2);
        // batched prefill output: 2 rows of 4 elements each
        let k = Tensor::f32(&[2, 2, 2, 1], (0..8).map(|x| x as f32).collect());
        let v = Tensor::f32(&[2, 2, 2, 1], (0..8).map(|x| -(x as f32)).collect());
        // write prefill row 1 into arena slot 0
        a.write_row(0, 0, &k, &v, 1).unwrap();
        let (lk, lv) = a.take_layer(0).unwrap();
        assert_eq!(&lk.as_f32().unwrap()[..4], &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(&lk.as_f32().unwrap()[4..], &[0.0; 4]);
        assert_eq!(&lv.as_f32().unwrap()[..4], &[-4.0, -5.0, -6.0, -7.0]);
        a.put_layer(0, lk, lv).unwrap();
        // mismatched row width is a shape error
        let small = Tensor::f32(&[2, 2], vec![0.0; 4]);
        assert!(a.write_row(0, 0, &small, &small, 0).is_err());
        // out-of-range slot / layer are shape errors
        assert!(a.write_row(0, 9, &k, &v, 0).is_err());
        assert!(a.write_row(9, 0, &k, &v, 0).is_err());
    }

    #[test]
    fn arena_take_put_layer_and_degradation() {
        let mut a = KvArena::new(2, 2, 2, 1, 2);
        let ids = a.try_reserve(1).unwrap();
        let (k, v) = a.take_layer(0).unwrap();
        assert_eq!(k.shape, vec![2, 2, 2, 1]);
        assert!(a.is_degraded());
        // a degraded arena refuses new reservations and double takes
        assert!(a.try_reserve(1).is_none());
        assert!(a.take_layer(0).is_err());
        assert!(a.write_row(0, ids[0], &k, &v, 0).is_err());
        // handing the tensors back heals immediately
        a.put_layer(0, k, v).unwrap();
        assert!(!a.is_degraded());
        // put without take, and wrong shapes, are rejected
        let (k, v) = a.take_layer(1).unwrap();
        assert!(a.put_layer(0, Tensor::zeros(&[1]), Tensor::zeros(&[1])).is_err());
        assert!(a
            .put_layer(1, Tensor::zeros(&[3, 2, 2, 1]), Tensor::zeros(&[3, 2, 2, 1]))
            .is_err());
        a.put_layer(1, k, v).unwrap();
    }

    #[test]
    fn arena_heals_after_failed_step_once_drained() {
        let mut a = KvArena::new(1, 1, 2, 1, 2);
        let ids = a.try_reserve(2).unwrap();
        let seed = Tensor::f32(&[1, 1, 2, 1], vec![1.0, 2.0]);
        a.write_row(0, ids[0], &seed, &seed, 0).unwrap();
        // simulate a step graph dying between take and put: the layer stays
        // a placeholder and the arena degrades
        let _lost = a.take_layer(0).unwrap();
        assert!(a.is_degraded());
        a.release(ids[0]);
        assert!(a.is_degraded(), "heal waits for the last resident");
        a.release(ids[1]);
        assert!(!a.is_degraded(), "drained arena re-zeroes taken layers");
        let (k, _v) = a.take_layer(0).unwrap();
        assert_eq!(k.shape, vec![2, 1, 2, 1]);
        assert_eq!(k.as_f32().unwrap(), &[0.0; 4]);
    }

    #[test]
    fn arena_slot_drop_frees_and_demotion_releases() {
        let arena = KvArena::shared(1, 1, 2, 1, 2);
        let ids = lock_arena(&arena).try_reserve(1).unwrap();
        let slot = ArenaSlot::new(arena.clone(), ids[0]);
        assert_eq!(slot.index(), 0);
        assert_eq!(lock_arena(&arena).occupancy(), 1);
        drop(slot);
        assert_eq!(lock_arena(&arena).occupancy(), 0);

        // a slot session routed to the recompute fallback is demoted (and
        // its slot freed) before the forward runs
        let ids = lock_arena(&arena).try_reserve(1).unwrap();
        let mut s = DecodeSession {
            tokens: vec![1],
            logits: vec![],
            kv: KvCache::Slot(ArenaSlot::new(arena.clone(), ids[0])),
        };
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let mut refs = vec![&mut s];
        recompute_decode_step(&m, &mut refs).unwrap();
        assert!(matches!(s.kv, KvCache::Recompute));
        assert_eq!(s.greedy_next(), 2);
        assert_eq!(lock_arena(&arena).occupancy(), 0);
    }

    #[test]
    fn slot_sessions_rejected_by_stack_and_scatter() {
        let arena = KvArena::shared(1, 1, 2, 1, 1);
        let ids = lock_arena(&arena).try_reserve(1).unwrap();
        let mut s = DecodeSession {
            tokens: vec![1],
            logits: vec![],
            kv: KvCache::Slot(ArenaSlot::new(arena, ids[0])),
        };
        let mut refs = vec![&mut s];
        assert!(stack_layer(&refs, 0, 1).is_err());
        let z = Tensor::zeros(&[1, 1, 2, 1]);
        assert!(scatter_layer(&mut refs, 0, &z, &z).is_err());
    }
}

//! Incremental decode: per-request KV-cache sessions over the AOT decode
//! graphs, with a full-context recompute fallback that works on *every*
//! [`LanguageModel`] (mocks included).
//!
//! A [`DecodeSession`] is the unit of continuous batching: it owns one
//! request's token history, the logits row for its next position, and —
//! when the model's artifacts carry the `decode` record — the per-layer
//! (K, V) cache tensors of that request.  Sessions are created batched by
//! [`LanguageModel::prefill`] and advanced batched by
//! [`LanguageModel::decode_step`]; the serving engine moves sessions in
//! and out of a step batch freely, because each session is self-contained
//! (rows of one step may sit at different sequence depths).
//!
//! Greedy decode through a session is **token-identical** to the classic
//! full-recompute [`super::generate::generate`] path: causal attention
//! makes the next-token logits of a row depend only on its own prefix, so
//! recomputing the prefix (fallback) and replaying it from the cache
//! (decode graphs) rank the same argmax token.  `rust/tests/decode_parity.rs`
//! pins this on matched kernels, and the engine's response cache relies on
//! it.  (On real artifacts the step graphs run the jnp oracle kernels while
//! the full-context graphs run Pallas — equal to ~2e-4 — so the only
//! admissible divergence is an argmax near-tie inside that tolerance; the
//! artifact-gated test in `integration_eval.rs` enforces the bound.)

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::{argmax, LanguageModel};

/// The cache side of a session.
pub enum KvCache {
    /// The model keeps no incremental state: every decode step re-runs the
    /// full fixed-shape forward over the session's token history.  Always
    /// correct, O(S) per token — the path taken when the manifest has no
    /// `decode` record and by plain mocks.
    Recompute,
    /// Per-layer `(k, v)` cache tensors, each `f32[1, H, S, Dh]`: the
    /// decode graphs append one position per step and attend over the live
    /// prefix only.  O(1) forwards per token.
    Layers(Vec<(Tensor, Tensor)>),
}

/// One request's decode state: token history, next-token logits, cache.
pub struct DecodeSession {
    /// prompt + generated tokens so far
    pub tokens: Vec<i32>,
    /// logits row (length = vocab) for the token at position
    /// `tokens.len()` — refreshed by `prefill` / `decode_step`
    pub logits: Vec<f32>,
    /// model-specific cache state
    pub kv: KvCache,
}

impl DecodeSession {
    /// Next write position (== current sequence length).
    pub fn pos(&self) -> usize {
        self.tokens.len()
    }

    /// Greedy choice from the current logits row.
    pub fn greedy_next(&self) -> i32 {
        argmax(&self.logits) as i32
    }
}

/// Validate one row against the model context and return it padded to the
/// full sequence (token 0 — the same padding the classic `generate` used,
/// so fallback logits are bit-identical to the historical path).  Shared
/// with the XLA runners' prefill so both paths keep one convention.
pub(crate) fn padded_row(row: &[i32], seq: usize) -> Result<Vec<i32>> {
    if row.is_empty() {
        return Err(Error::Config("decode: empty token row".into()));
    }
    if row.len() > seq {
        return Err(Error::Config(format!(
            "decode: row of {} tokens exceeds the model context {seq}",
            row.len()
        )));
    }
    let mut padded = row.to_vec();
    padded.resize(seq, 0);
    Ok(padded)
}

/// Full-context logits rows at each row's last position — the shared core
/// of both recompute fallbacks: one batched fixed-shape forward, rows
/// padded to `seq`.
fn recompute_rows<M: LanguageModel + ?Sized>(
    model: &M,
    rows: &[&[i32]],
) -> Result<Vec<Vec<f32>>> {
    let seq = model.config().seq;
    let vocab = model.config().vocab;
    let b = rows.len();
    let mut toks = Vec::with_capacity(b * seq);
    for row in rows {
        toks.extend(padded_row(row, seq)?);
    }
    let logits = model.logits(&Tensor::i32(&[b, seq], toks))?;
    let lv = logits.as_f32()?;
    Ok(rows
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let pos = row.len() - 1;
            lv[(i * seq + pos) * vocab..][..vocab].to_vec()
        })
        .collect())
}

/// Fallback prefill: one batched full-context forward, sessions carry no
/// cache ([`KvCache::Recompute`]).
pub fn recompute_prefill<M: LanguageModel + ?Sized>(
    model: &M,
    prompts: &[Vec<i32>],
) -> Result<Vec<DecodeSession>> {
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let rows: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let logits = recompute_rows(model, &rows)?;
    Ok(prompts
        .iter()
        .zip(logits)
        .map(|(p, l)| DecodeSession { tokens: p.clone(), logits: l, kv: KvCache::Recompute })
        .collect())
}

/// Fallback decode step: re-run the full forward over each session's
/// history and refresh its next-token logits.
pub fn recompute_decode_step<M: LanguageModel + ?Sized>(
    model: &M,
    sessions: &mut [&mut DecodeSession],
) -> Result<()> {
    if sessions.is_empty() {
        return Ok(());
    }
    let logits = {
        let rows: Vec<&[i32]> = sessions.iter().map(|s| s.tokens.as_slice()).collect();
        recompute_rows(model, &rows)?
    };
    for (s, l) in sessions.iter_mut().zip(logits) {
        s.logits = l;
    }
    Ok(())
}

/// Slice row `i` of a `[B, H, S, Dh]` cache tensor into an owned
/// `[1, H, S, Dh]` per-session cache (rows are contiguous in the leading
/// dim, so this is one memcpy).
pub fn cache_row(stacked: &Tensor, i: usize) -> Result<Tensor> {
    let per: usize = stacked.shape[1..].iter().product();
    let data = stacked.as_f32()?;
    let mut shape = stacked.shape.clone();
    shape[0] = 1;
    Ok(Tensor::f32(&shape, data[i * per..][..per].to_vec()))
}

/// Stack the layer-`layer` (K, V) caches of `sessions` into a pair of
/// `[bucket, H, S, Dh]` tensors (zero rows beyond the live sessions).
/// Errors if any session runs the recompute fallback — mixed batches
/// cannot ride one decode graph.
pub fn stack_layer(
    sessions: &[&mut DecodeSession],
    layer: usize,
    bucket: usize,
) -> Result<(Tensor, Tensor)> {
    let mut shape: Option<Vec<usize>> = None;
    let mut kd: Vec<f32> = Vec::new();
    let mut vd: Vec<f32> = Vec::new();
    for s in sessions {
        let (k, v) = match &s.kv {
            KvCache::Layers(l) => l.get(layer).ok_or_else(|| {
                Error::Shape(format!("decode session has no cache for layer {layer}"))
            })?,
            KvCache::Recompute => {
                return Err(Error::Shape(
                    "cannot stack a recompute-fallback session into a decode batch".into(),
                ))
            }
        };
        if shape.is_none() {
            shape = Some(k.shape.clone());
            let per: usize = k.shape[1..].iter().product();
            kd.reserve(bucket * per);
            vd.reserve(bucket * per);
        }
        kd.extend_from_slice(k.as_f32()?);
        vd.extend_from_slice(v.as_f32()?);
    }
    let mut shape = shape.ok_or_else(|| Error::Shape("stack_layer: no sessions".into()))?;
    let per: usize = shape[1..].iter().product();
    kd.resize(bucket * per, 0.0);
    vd.resize(bucket * per, 0.0);
    shape[0] = bucket;
    Ok((Tensor::f32(&shape, kd), Tensor::f32(&shape, vd)))
}

/// Write the updated `[bucket, H, S, Dh]` caches of one layer back into the
/// live sessions (inverse of [`stack_layer`]; pad rows are dropped).
pub fn scatter_layer(
    sessions: &mut [&mut DecodeSession],
    layer: usize,
    k: &Tensor,
    v: &Tensor,
) -> Result<()> {
    for (i, s) in sessions.iter_mut().enumerate() {
        let pair = (cache_row(k, i)?, cache_row(v, i)?);
        match &mut s.kv {
            KvCache::Layers(l) => l[layer] = pair,
            KvCache::Recompute => {
                return Err(Error::Shape(
                    "cannot scatter a decode cache into a recompute session".into(),
                ))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    /// Prefix-sum mock: next-token preference depends on the *whole*
    /// prefix, so any cache/position bug shows up as a token mismatch.
    struct PrefixSum(ModelConfig);

    impl LanguageModel for PrefixSum {
        fn config(&self) -> &ModelConfig {
            &self.0
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            let v = self.0.vocab;
            let tv = tokens.as_i32()?;
            let mut out = vec![0.0f32; b * s * v];
            for i in 0..b {
                let mut sum = 0i64;
                for t in 0..s {
                    sum += tv[i * s + t] as i64;
                    let next = (sum.unsigned_abs() as usize + 1) % v;
                    out[(i * s + t) * v + next] = 5.0;
                }
            }
            Ok(Tensor::f32(&[b, s, v], out))
        }
    }

    #[test]
    fn recompute_prefill_sets_last_position_logits() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let sessions =
            recompute_prefill(&m, &[vec![3], vec![10, 20, 30]]).unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].pos(), 1);
        assert_eq!(sessions[1].pos(), 3);
        // row 0: sum=3 -> prefers 4; row 1: sum=60 -> prefers 61
        assert_eq!(sessions[0].greedy_next(), 4);
        assert_eq!(sessions[1].greedy_next(), 61);
        assert!(matches!(sessions[0].kv, KvCache::Recompute));
    }

    #[test]
    fn recompute_decode_step_advances_a_subset() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let mut sessions = recompute_prefill(&m, &[vec![1], vec![2]]).unwrap();
        // advance only row 1, as the continuous batcher does
        sessions[1].tokens.push(5);
        let (_a, b) = sessions.split_at_mut(1);
        let mut refs = vec![&mut b[0]];
        recompute_decode_step(&m, &mut refs).unwrap();
        assert_eq!(sessions[1].greedy_next(), 8); // 2 + 5 -> prefers 8
        assert_eq!(sessions[0].greedy_next(), 2); // untouched
    }

    #[test]
    fn empty_and_oversize_rows_are_config_errors() {
        let m = PrefixSum(ModelConfig::builtin("nt-tiny").unwrap());
        let err = recompute_prefill(&m, &[vec![]]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let seq = m.config().seq;
        let err = recompute_prefill(&m, &[vec![1; seq + 1]]).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // empty session batch is a no-op, not an error
        recompute_decode_step(&m, &mut []).unwrap();
        assert!(recompute_prefill(&m, &[]).unwrap().is_empty());
    }

    #[test]
    fn stack_scatter_roundtrip() {
        let mk = |base: f32| {
            vec![(
                Tensor::f32(&[1, 2, 2, 1], vec![base, base + 1.0, base + 2.0, base + 3.0]),
                Tensor::f32(&[1, 2, 2, 1], vec![-base; 4]),
            )]
        };
        let mut s0 = DecodeSession { tokens: vec![1], logits: vec![], kv: KvCache::Layers(mk(10.0)) };
        let mut s1 = DecodeSession { tokens: vec![2], logits: vec![], kv: KvCache::Layers(mk(20.0)) };
        let mut refs = vec![&mut s0, &mut s1];
        let (k, v) = stack_layer(&refs, 0, 4).unwrap();
        assert_eq!(k.shape, vec![4, 2, 2, 1]);
        assert_eq!(&k.as_f32().unwrap()[..4], &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(&k.as_f32().unwrap()[4..8], &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(&k.as_f32().unwrap()[8..], &[0.0; 8]);
        // mutate and scatter back
        let mut kd = k.as_f32().unwrap().to_vec();
        kd[0] = 99.0;
        let k2 = Tensor::f32(&k.shape, kd);
        scatter_layer(&mut refs, 0, &k2, &v).unwrap();
        match &s0.kv {
            KvCache::Layers(l) => {
                assert_eq!(l[0].0.shape, vec![1, 2, 2, 1]);
                assert_eq!(l[0].0.as_f32().unwrap()[0], 99.0);
                assert_eq!(l[0].1.as_f32().unwrap(), &[-10.0; 4]);
            }
            _ => panic!("expected layered cache"),
        }
    }

    #[test]
    fn mixed_cache_kinds_rejected_in_stack() {
        let mut s0 = DecodeSession { tokens: vec![1], logits: vec![], kv: KvCache::Recompute };
        let refs = vec![&mut s0];
        assert!(stack_layer(&refs, 0, 2).is_err());
    }
}

//! The multi-task multiple-choice suite — our LM-Eval-Harness analog
//! (Tables 7 / 11).
//!
//! Each task is a generator of (context, candidates, answer_idx) items scored
//! by length-normalized continuation log-likelihood — the exact scoring rule
//! the harness uses for HellaSwag/PIQA/etc.  Task grammars differ in
//! structure and language mix so the suite probes distinct capabilities:
//!
//! | task           | analog     | structure                                  |
//! |----------------|------------|--------------------------------------------|
//! | hellaswag-syn  | HellaSwag  | 4-way sentence continuation (en)           |
//! | piqa-syn       | PIQA       | 2-way continuation, physical-chain grammar |
//! | winogrande-syn | WinoGrande | 2-way binding disambiguation               |
//! | openbookqa-syn | OpenBookQA | 4-way cross-language successor lookup      |
//! | boolq-syn      | BoolQ      | 2-way grammatical-vs-corrupted judgement   |

// Justified unwraps: task names come from the static TASK_NAMES table and
// contexts are built non-empty by construction
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::calib::corpus::{sentence, successor};
use crate::calib::rng::SplitMix64;
use crate::calib::vocab::{BOS, LANGS, PERIOD};
use crate::error::Result;
use crate::tensor::Tensor;

use super::{log_softmax_row, LanguageModel};

/// One multiple-choice item.
#[derive(Debug, Clone)]
pub struct McItem {
    pub context: Vec<i32>,
    pub candidates: Vec<Vec<i32>>,
    pub answer: usize,
}

/// A named task = a bag of items.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: &'static str,
    pub items: Vec<McItem>,
}

pub const TASK_NAMES: &[&str] = &[
    "hellaswag-syn",
    "piqa-syn",
    "winogrande-syn",
    "openbookqa-syn",
    "boolq-syn",
];

/// Build a task by name with `n` items.
pub fn build_task(name: &str, n: usize, seed: u64) -> Task {
    let mut rng = SplitMix64::new(seed ^ 0x7A5C);
    let mut items = Vec::with_capacity(n);
    while items.len() < n {
        let item = match name {
            "hellaswag-syn" => hellaswag_item(&mut rng, 4),
            "piqa-syn" => hellaswag_item(&mut rng, 2),
            "winogrande-syn" => winogrande_item(&mut rng),
            "openbookqa-syn" => openbook_item(&mut rng),
            "boolq-syn" => boolq_item(&mut rng),
            _ => panic!("unknown task {name}"),
        };
        items.push(item);
    }
    Task { name: TASK_NAMES.iter().find(|t| **t == name).unwrap(), items }
}

/// 4-way (or 2-way) continuation: the true continuation follows the grammar
/// successor chain; distractors are random in-bucket chains.
fn hellaswag_item(rng: &mut SplitMix64, n_cand: usize) -> McItem {
    let lang = &LANGS[rng.below(2) as usize]; // en/zhs — well-learned
    let b = (lang.hi - lang.lo) as u64;
    let mut ctx = vec![BOS];
    let mut s = sentence(rng, lang);
    s.pop(); // drop PERIOD
    ctx.extend(&s);
    let mut w = *ctx.last().unwrap() as u32;
    // true continuation: 3 successor steps
    let mut truth = Vec::new();
    for _ in 0..3 {
        w = successor(w, lang);
        truth.push(w as i32);
    }
    let mut candidates = vec![truth];
    for _ in 1..n_cand {
        let mut c = Vec::new();
        for _ in 0..3 {
            c.push((lang.lo + rng.below(b) as u32) as i32);
        }
        candidates.push(c);
    }
    // rotate the answer position deterministically
    let answer = (rng.below(n_cand as u64)) as usize;
    candidates.swap(0, answer);
    McItem { context: ctx, candidates, answer }
}

/// 2-way binding disambiguation: which value was bound to the queried key.
fn winogrande_item(rng: &mut SplitMix64) -> McItem {
    let lang = &LANGS[rng.below(5) as usize];
    let b = (lang.hi - lang.lo) as u64;
    let k1 = (lang.lo + rng.below(b) as u32) as i32;
    let mut k2 = k1;
    while k2 == k1 {
        k2 = (lang.lo + rng.below(b) as u32) as i32;
    }
    // values follow the grammar: v = succ(k) — learnable without induction
    let v1 = successor(k1 as u32, lang) as i32;
    let v2 = successor(k2 as u32, lang) as i32;
    let ctx = vec![BOS, k1, v1, PERIOD, k2, v2, PERIOD, k1];
    let answer = (rng.below(2)) as usize;
    let mut candidates = vec![vec![v1], vec![v2]];
    if answer == 1 {
        candidates.swap(0, 1);
    }
    McItem { context: ctx, candidates, answer }
}

/// 4-way "knowledge lookup": context names a token, candidates are successor
/// chains in *different* languages; only the in-bucket one is grammatical.
fn openbook_item(rng: &mut SplitMix64) -> McItem {
    let li = rng.below(5) as usize;
    let lang = &LANGS[li];
    let b = (lang.hi - lang.lo) as u64;
    let w0 = lang.lo + rng.below(b) as u32;
    let ctx = vec![BOS, w0 as i32];
    let truth = vec![successor(w0, lang) as i32, successor(successor(w0, lang), lang) as i32];
    let mut candidates = vec![truth];
    for off in 1..4usize {
        let ol = &LANGS[(li + off) % 5];
        let ob = (ol.hi - ol.lo) as u64;
        let x = ol.lo + rng.below(ob) as u32;
        candidates.push(vec![x as i32, successor(x, ol) as i32]);
    }
    let answer = (rng.below(4)) as usize;
    candidates.swap(0, answer);
    McItem { context: ctx, candidates, answer }
}

/// 2-way judgement: grammatical successor pair vs corrupted pair.
fn boolq_item(rng: &mut SplitMix64) -> McItem {
    let lang = &LANGS[rng.below(5) as usize];
    let b = (lang.hi - lang.lo) as u64;
    let mut ctx = vec![BOS];
    let mut s = sentence(rng, lang);
    s.pop();
    ctx.extend(&s);
    let w = *ctx.last().unwrap() as u32;
    let good = vec![successor(w, lang) as i32, PERIOD];
    let bad = vec![(lang.lo + rng.below(b) as u32) as i32, PERIOD];
    let answer = (rng.below(2)) as usize;
    let candidates = if answer == 0 { vec![good, bad] } else { vec![bad, good] };
    // for boolq-syn the "correct" option is always the grammatical one
    let answer = candidates
        .iter()
        .position(|c| c[0] == successor(w, lang) as i32)
        .unwrap();
    McItem { context: ctx, candidates, answer }
}

/// Score a task: length-normalized continuation log-likelihood ranking.
pub fn score_task(model: &dyn LanguageModel, task: &Task, batch: usize) -> Result<f32> {
    let seq = model.config().seq;
    let vocab = model.config().vocab;

    // flatten every (context ++ candidate) into one padded row
    struct Row {
        item: usize,
        cand: usize,
        ctx_len: usize,
        cand_len: usize,
    }
    let mut rows_meta = Vec::new();
    let mut rows: Vec<i32> = Vec::new();
    for (ii, item) in task.items.iter().enumerate() {
        for (ci, cand) in item.candidates.iter().enumerate() {
            let mut row = item.context.clone();
            row.extend(cand);
            assert!(row.len() <= seq, "item too long");
            rows_meta.push(Row {
                item: ii,
                cand: ci,
                ctx_len: item.context.len(),
                cand_len: cand.len(),
            });
            row.resize(seq, 0);
            rows.extend(row);
        }
    }

    let n_rows = rows_meta.len();
    let mut scores = vec![vec![f32::NEG_INFINITY; 8]; task.items.len()];
    let mut r = 0;
    while r < n_rows {
        let b = batch.min(n_rows - r);
        let chunk = Tensor::i32(&[b, seq], rows[r * seq..(r + b) * seq].to_vec());
        let logits = model.logits(&chunk)?;
        let lv = logits.as_f32()?;
        for i in 0..b {
            let meta = &rows_meta[r + i];
            let mut ll = 0.0f32;
            for t in 0..meta.cand_len {
                let pos = meta.ctx_len + t; // token being predicted
                let row = &lv[(i * seq + pos - 1) * vocab..(i * seq + pos - 1) * vocab + vocab];
                let ls = log_softmax_row(row);
                let target = rows[(r + i) * seq + pos] as usize;
                ll += ls[target];
            }
            scores[meta.item][meta.cand] = ll / meta.cand_len as f32;
        }
        r += b;
    }

    let mut correct = 0usize;
    for (ii, item) in task.items.iter().enumerate() {
        let s = &scores[ii][..item.candidates.len()];
        let best = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == item.answer {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f32 / task.items.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_generate_deterministically() {
        for name in TASK_NAMES {
            let a = build_task(name, 8, 42);
            let b = build_task(name, 8, 42);
            assert_eq!(a.items.len(), 8);
            for (x, y) in a.items.iter().zip(&b.items) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn answers_in_range() {
        for name in TASK_NAMES {
            for item in build_task(name, 16, 7).items {
                assert!(item.answer < item.candidates.len());
                assert!(!item.context.is_empty());
                assert!(item.context.len() + item.candidates.iter().map(|c| c.len()).max().unwrap() <= 128);
            }
        }
    }

    #[test]
    fn hellaswag_truth_is_successor_chain() {
        let t = build_task("hellaswag-syn", 8, 3);
        for item in &t.items {
            let w = *item.context.last().unwrap() as u32;
            let lang = crate::calib::vocab::lang_of_token(w as i32).unwrap();
            let truth = &item.candidates[item.answer];
            assert_eq!(truth[0], successor(w, lang) as i32);
        }
    }
}

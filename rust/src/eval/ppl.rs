//! Perplexity on the held-out synthetic corpora (Tables 8 and 10).

use crate::calib::corpus::{spec_by_name, token_stream};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::{log_softmax_row, LanguageModel};

/// Perplexity of `model` over `n_tokens` of the named corpus
/// ("wiki-syn" | "ptb-syn" | "c4-syn" | "train"), evaluated in
/// non-overlapping windows of the model's sequence length.
pub fn perplexity(model: &dyn LanguageModel, corpus: &str, n_tokens: usize,
                  batch: usize) -> Result<f32> {
    let spec = spec_by_name(corpus)
        .ok_or_else(|| Error::Eval(format!("unknown corpus {corpus}")))?;
    let stream = token_stream(&spec, n_tokens + 1);
    perplexity_on_stream(model, &stream, batch)
}

/// Perplexity over an explicit token stream.
pub fn perplexity_on_stream(model: &dyn LanguageModel, stream: &[i32],
                            batch: usize) -> Result<f32> {
    let seq = model.config().seq;
    let vocab = model.config().vocab;
    let n_windows = (stream.len() - 1) / seq;
    if n_windows == 0 {
        return Err(Error::Eval("stream shorter than one window".into()));
    }
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    let mut w = 0;
    while w < n_windows {
        let b = batch.min(n_windows - w);
        let mut toks = Vec::with_capacity(b * seq);
        for r in 0..b {
            let off = (w + r) * seq;
            toks.extend(&stream[off..off + seq]);
        }
        let chunk = Tensor::i32(&[b, seq], toks);
        let logits = model.logits(&chunk)?;
        let lv = logits.as_f32()?;
        for r in 0..b {
            let off = (w + r) * seq;
            for t in 0..seq - 1 {
                let target = stream[off + t + 1];
                let row = &lv[(r * seq + t) * vocab..(r * seq + t) * vocab + vocab];
                let ls = log_softmax_row(row);
                total_nll -= ls[target as usize] as f64;
                total_tokens += 1;
            }
        }
        w += b;
    }
    Ok(((total_nll / total_tokens as f64).exp()) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    /// A uniform-logits fake model: PPL must equal vocab size.
    struct Uniform(ModelConfig);

    impl LanguageModel for Uniform {
        fn config(&self) -> &ModelConfig {
            &self.0
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let b = tokens.shape[0];
            let s = tokens.shape[1];
            Ok(Tensor::zeros(&[b, s, self.0.vocab]))
        }
    }

    #[test]
    fn uniform_model_ppl_is_vocab() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let v = cfg.vocab as f32;
        let m = Uniform(cfg);
        let ppl = perplexity(&m, "wiki-syn", 1024, 4).unwrap();
        assert!((ppl - v).abs() / v < 0.01, "ppl {ppl} vs vocab {v}");
    }

    #[test]
    fn unknown_corpus_errors() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let m = Uniform(cfg);
        assert!(perplexity(&m, "nope", 512, 4).is_err());
    }
}

//! Subjective evaluation (Table 5): generate from a fixed prompt and score
//! the generations mechanically — at our scale, "grammaticality" is
//! checkable against the corpus grammar, so the paper's human judgement
//! becomes an exact error counter.

use crate::calib::corpus::successor;
use crate::calib::vocab::{lang_of_token, token_to_word, BIND, BOS, EOS, PAD, PERIOD, QUERY};
use crate::error::Result;

use super::generate::{generate, SampleConfig};
use super::LanguageModel;

/// Mechanical quality report for one generated sequence.
#[derive(Debug, Clone, Default)]
pub struct GrammarReport {
    pub tokens: usize,
    /// content-token transitions that match the grammar successor
    pub successor_hits: usize,
    /// transitions that jump across language buckets mid-sentence
    /// (the "grammatical error" analog)
    pub bucket_violations: usize,
    /// 3-gram loops (the "repeated statements" logical error analog)
    pub repetition_loops: usize,
    pub successor_rate: f32,
}

/// Score a token sequence against the corpus grammar.
pub fn grammar_report(tokens: &[i32]) -> GrammarReport {
    let mut r = GrammarReport { tokens: tokens.len(), ..Default::default() };
    let mut transitions = 0usize;
    for w in tokens.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a < 8 || b < 8 {
            continue; // specials break sentences
        }
        let (Some(la), Some(lb)) = (lang_of_token(a), lang_of_token(b)) else {
            continue;
        };
        transitions += 1;
        if la.name != lb.name {
            r.bucket_violations += 1;
        } else if successor(a as u32, la) as i32 == b {
            r.successor_hits += 1;
        }
    }
    // repetition: identical 3-grams occurring 3+ times
    if tokens.len() >= 9 {
        use std::collections::HashMap;
        let mut counts: HashMap<&[i32], usize> = HashMap::new();
        for w in tokens.windows(3) {
            *counts.entry(w).or_default() += 1;
        }
        r.repetition_loops = counts.values().filter(|&&c| c >= 3).count();
    }
    r.successor_rate = if transitions > 0 {
        r.successor_hits as f32 / transitions as f32
    } else {
        0.0
    };
    r
}

/// Render tokens as readable pseudo-text.
pub fn render(tokens: &[i32]) -> String {
    tokens
        .iter()
        .take_while(|&&t| t != EOS || true)
        .filter(|&&t| t != PAD)
        .map(|&t| match t {
            BOS => "«".to_string(),
            EOS => "»".to_string(),
            PERIOD => ".".to_string(),
            BIND => ":=".to_string(),
            QUERY => "?".to_string(),
            t => token_to_word(t),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// The Table-5 experiment: generate `n` continuations of a fixed prompt and
/// return (rendered text, report) pairs.
pub fn subjective_eval(
    model: &dyn LanguageModel,
    prompt: &[i32],
    n: usize,
    len: usize,
) -> Result<Vec<(String, GrammarReport)>> {
    let prompts: Vec<Vec<i32>> = (0..n).map(|_| prompt.to_vec()).collect();
    let cfg = SampleConfig { temperature: 0.8, stochastic_prefix: prompt.len() + 2,
                             seed: 0xBEEF };
    let outs = generate(model, &prompts, len, &cfg)?;
    Ok(outs
        .iter()
        .map(|s| (render(s), grammar_report(s)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::corpus::sentence;
    use crate::calib::rng::SplitMix64;
    use crate::calib::vocab::LANGS;

    #[test]
    fn grammar_sentences_score_high() {
        let mut rng = SplitMix64::new(5);
        let mut toks = vec![BOS];
        for _ in 0..10 {
            toks.extend(sentence(&mut rng, &LANGS[0]));
        }
        let r = grammar_report(&toks);
        assert!(r.successor_rate > 0.6, "rate {}", r.successor_rate);
        assert_eq!(r.bucket_violations, 0);
    }

    #[test]
    fn random_tokens_score_low() {
        let mut rng = SplitMix64::new(6);
        let toks: Vec<i32> = (0..100).map(|_| (8 + rng.below(2040)) as i32).collect();
        let r = grammar_report(&toks);
        assert!(r.successor_rate < 0.1);
        assert!(r.bucket_violations > 10);
    }

    #[test]
    fn repetition_detected() {
        let toks: Vec<i32> = std::iter::repeat([10, 11, 12]).take(5).flatten().collect();
        let r = grammar_report(&toks);
        assert!(r.repetition_loops >= 1);
    }

    #[test]
    fn render_readable() {
        let s = render(&[BOS, 10, 11, PERIOD, EOS]);
        assert!(s.starts_with("«"));
        assert!(s.contains("en_"));
    }
}

//! Per-model and engine-wide serving statistics.
//!
//! Every request handed to the engine ends up in exactly one of the counting
//! buckets below: `served` (answered with tokens, including cache hits),
//! `deadline_missed` / `rejected` / `failed` (answered with an error), or
//! `cancelled` (caller dropped the ticket before it finished — no answer
//! owed; under continuous batching a mid-generation cancel frees its cache
//! slot immediately).  `Engine::shutdown` returns the final [`EngineStats`]
//! snapshot.
//!
//! The prefill/decode split: `prefill_tokens` counts *prompt* tokens pushed
//! through prefill dispatches and `decode_tokens` counts tokens produced by
//! incremental decode steps (each request's first generated token rides its
//! prefill and is counted by neither), with wall time split the same way —
//! so `bench_serve` can report prompt-processing and steady-state
//! token-generation throughput separately.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::obs::Hist;
use crate::util::json::{self, Json};

/// Counters for one registered model (one scheduler lane).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ModelStats {
    /// requests answered with tokens (cache hits included)
    pub served: usize,
    /// prefill dispatches issued (cache hits ride no batch)
    pub batches: usize,
    /// incremental decode steps dispatched (one per chunked step call)
    pub decode_steps: usize,
    /// priming batches run by engine warm-up (not counted in `batches`)
    pub warmup_batches: usize,
    /// tickets dropped/cancelled before their request finished
    pub cancelled: usize,
    /// requests whose deadline expired before completion (answered with
    /// `Error::Serve`)
    pub deadline_missed: usize,
    /// malformed requests (empty prompt, prompt longer than the context)
    /// answered with `Error::Serve`
    pub rejected: usize,
    /// requests answered with `Error::Serve` because a generation call of
    /// theirs failed
    pub failed: usize,
    /// greedy requests answered straight from the response cache
    pub cache_hits: usize,
    /// cacheable (greedy) requests that had to be generated
    pub cache_misses: usize,
    /// summed generation wall time across prefill + decode dispatches
    pub total_gen_micros: u128,
    /// prefill share of `total_gen_micros`
    pub total_prefill_micros: u128,
    /// decode-step share of `total_gen_micros`
    pub total_decode_micros: u128,
    /// prompt tokens processed by prefill dispatches
    pub prefill_tokens: u128,
    /// tokens produced by incremental decode steps
    pub decode_tokens: u128,
    /// summed submit-to-dispatch time across served requests
    pub total_queue_micros: u128,
    /// largest prefill or decode batch dispatched
    pub max_batch_seen: usize,
    /// first generation failure observed on this lane (riders were
    /// answered with a generic error; the root cause is preserved here —
    /// the deprecated `serve_loop` shim re-surfaces it as its return)
    pub first_error: Option<String>,
    /// submit→dispatch wait per answered request (µs)
    pub queue_us: Hist,
    /// wall time per prefill dispatch (µs)
    pub prefill_us: Hist,
    /// wall time per decode-step dispatch (µs)
    pub decode_step_us: Hist,
    /// submit→answer end-to-end time per served request (µs)
    pub e2e_us: Hist,
    /// occupied KV-arena slots sampled at each decode turn (empty on
    /// models without an arena — the recompute fallback)
    pub arena_occupancy: Hist,
    /// riders per admission round, post-triage (how full the batched
    /// prefill drains run)
    pub admission_batch: Hist,
}

impl ModelStats {
    /// Mean riders per generation batch (cache hits excluded).
    pub fn mean_batch(&self) -> f32 {
        if self.batches == 0 {
            0.0
        } else {
            // saturating: all fields are pub, so a hand-assembled snapshot
            // may hold cache_hits > served
            self.served.saturating_sub(self.cache_hits) as f32 / self.batches as f32
        }
    }

    /// Mean time a served request waited before its batch dispatched.
    pub fn mean_queue_micros(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_queue_micros as f64 / self.served as f64
        }
    }

    /// Cache hits over all cacheable (greedy) requests seen; 0 when the
    /// cache is disabled or no greedy traffic arrived.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Steady-state decode throughput: tokens produced by decode steps per
    /// second of decode wall time (0 when no decode step ran).
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.total_decode_micros == 0 {
            0.0
        } else {
            self.decode_tokens as f64 * 1e6 / self.total_decode_micros as f64
        }
    }

    /// Prompt-processing throughput of the prefill dispatches (0 when no
    /// prefill ran).
    pub fn prefill_tok_per_s(&self) -> f64 {
        if self.total_prefill_micros == 0 {
            0.0
        } else {
            self.prefill_tokens as f64 * 1e6 / self.total_prefill_micros as f64
        }
    }

    /// Project onto the legacy [`crate::serve::ServeStats`] shape (what the
    /// deprecated `serve::serve_loop` shim returns).
    ///
    /// **This projection is lossy.**  `ServeStats` predates the engine and
    /// keeps only the five aggregate counters below; everything the engine
    /// added is dropped:
    ///
    /// * `first_error` — a lane that failed mid-run projects to clean
    ///   aggregates.  Callers that care must read it off `ModelStats`
    ///   directly (as `serve_loop` and `bench_serve` do) before
    ///   projecting.
    /// * the prefill/decode split — `total_prefill_micros` /
    ///   `total_decode_micros` and the matching token counters collapse
    ///   into the combined `total_gen_micros`.
    /// * the latency histograms (`queue_us` / `prefill_us` /
    ///   `decode_step_us` / `e2e_us`) and every outcome counter other than
    ///   `served` (`cancelled`, `deadline_missed`, `rejected`, `failed`,
    ///   cache hit/miss counts, `warmup_batches`).
    pub fn to_serve_stats(&self) -> crate::serve::ServeStats {
        crate::serve::ServeStats {
            served: self.served,
            batches: self.batches,
            total_gen_micros: self.total_gen_micros,
            total_queue_micros: self.total_queue_micros,
            max_batch_seen: self.max_batch_seen,
        }
    }

    /// The per-phase `latency_us` block of the `BENCH_serve.json` schema
    /// that `trace_validate` audits: one `{count, p50, p90, p99, max}`
    /// object per engine-measured phase (`queue`, `prefill`, `decode_step`,
    /// `e2e`).
    ///
    /// The shape is stable regardless of what ran: a phase that never
    /// dispatched (the offline mock fallback records no real batches, a
    /// cache-only run records no decode steps) still emits its full object
    /// with `count: 0` and zeroed percentiles — keys are never omitted, so
    /// downstream parsers need exactly one schema.
    pub fn latency_us_json(&self) -> Json {
        json::obj(vec![
            ("queue", hist_json(&self.queue_us)),
            ("prefill", hist_json(&self.prefill_us)),
            ("decode_step", hist_json(&self.decode_step_us)),
            ("e2e", hist_json(&self.e2e_us)),
        ])
    }

    /// The `fast_path` block of the `BENCH_serve.json` schema: decode
    /// fast-path health — KV-arena occupancy per decode turn and riders
    /// per admission round.  Same stability rule as
    /// [`Self::latency_us_json`]: both keys always present, `count: 0`
    /// shapes when nothing was recorded (no arena, or no traffic).
    pub fn fast_path_json(&self) -> Json {
        json::obj(vec![
            ("arena_occupancy", hist_json(&self.arena_occupancy)),
            ("admission_batch_size", hist_json(&self.admission_batch)),
        ])
    }
}

/// Compact percentile view of one latency histogram; an empty histogram
/// yields `count: 0` with zeroed percentiles, never a missing key.
fn hist_json(h: &Hist) -> Json {
    json::obj(vec![
        ("count", json::n(h.count() as f64)),
        ("p50", json::n(h.percentile(50.0) as f64)),
        ("p90", json::n(h.percentile(90.0) as f64)),
        ("p99", json::n(h.percentile(99.0) as f64)),
        ("max", json::n(h.max() as f64)),
    ])
}

/// Live per-lane gauges, written by the scheduler as it runs and readable
/// at any moment through [`crate::engine::Client::stats_snapshot`] —
/// no shutdown required.  Relaxed atomics: each value is independently
/// coherent, the set is only loosely consistent (fine for polling).
#[derive(Debug)]
pub(crate) struct LaneGauges {
    pub(crate) model: String,
    pub(crate) max_slots: usize,
    pub(crate) queue_depth: AtomicUsize,
    pub(crate) active_slots: AtomicUsize,
    pub(crate) served: AtomicUsize,
    pub(crate) arena_slots: AtomicUsize,
    pub(crate) arena_occupancy: AtomicUsize,
}

impl LaneGauges {
    pub(crate) fn new(model: String, max_slots: usize) -> Self {
        LaneGauges {
            model,
            max_slots,
            queue_depth: AtomicUsize::new(0),
            active_slots: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            arena_slots: AtomicUsize::new(0),
            arena_occupancy: AtomicUsize::new(0),
        }
    }

    pub(crate) fn snapshot(&self) -> LaneSnapshot {
        LaneSnapshot {
            model: self.model.clone(),
            max_slots: self.max_slots,
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            active_slots: self.active_slots.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            arena_slots: self.arena_slots.load(Ordering::Relaxed),
            arena_occupancy: self.arena_occupancy.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one scheduler lane, from
/// [`crate::engine::Client::stats_snapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneSnapshot {
    /// registered model name
    pub model: String,
    /// continuous-batching slot budget (`ModelTuning::max_batch`)
    pub max_slots: usize,
    /// requests waiting in the lane queue
    pub queue_depth: usize,
    /// live decode sessions occupying slots
    pub active_slots: usize,
    /// requests answered with tokens so far
    pub served: usize,
    /// KV-arena capacity of the lane's model (0 = no arena: the model
    /// serves decode by full-context recompute)
    pub arena_slots: usize,
    /// KV-arena slots currently held by live sessions
    pub arena_occupancy: usize,
}

impl LaneSnapshot {
    /// Requests inside the engine right now (queued + occupying slots).
    pub fn in_flight(&self) -> usize {
        self.queue_depth + self.active_slots
    }
}

/// Final per-model statistics returned by `Engine::shutdown`.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// one entry per registered model, keyed by its registered name
    pub models: BTreeMap<String, ModelStats>,
}

impl EngineStats {
    /// Stats for one registered model.
    pub fn model(&self, name: &str) -> Option<&ModelStats> {
        self.models.get(name)
    }

    /// Requests answered with tokens across every model.
    pub fn total_served(&self) -> usize {
        self.models.values().map(|m| m.served).sum()
    }

    /// Generation batches dispatched across every model.
    pub fn total_batches(&self) -> usize {
        self.models.values().map(|m| m.batches).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_excludes_cache_hits() {
        let s = ModelStats {
            served: 10,
            cache_hits: 4,
            batches: 3,
            ..Default::default()
        };
        assert_eq!(s.mean_batch(), 2.0);
        assert_eq!(ModelStats::default().mean_batch(), 0.0);
    }

    #[test]
    fn token_throughput_split() {
        let s = ModelStats {
            prefill_tokens: 100,
            decode_tokens: 50,
            total_prefill_micros: 2_000_000,
            total_decode_micros: 500_000,
            ..Default::default()
        };
        assert_eq!(s.prefill_tok_per_s(), 50.0);
        assert_eq!(s.decode_tok_per_s(), 100.0);
        assert_eq!(ModelStats::default().decode_tok_per_s(), 0.0);
        assert_eq!(ModelStats::default().prefill_tok_per_s(), 0.0);
    }

    #[test]
    fn hit_rate_and_queue_means() {
        let s = ModelStats {
            served: 4,
            total_queue_micros: 400,
            cache_hits: 1,
            cache_misses: 3,
            ..Default::default()
        };
        assert_eq!(s.mean_queue_micros(), 100.0);
        assert_eq!(s.cache_hit_rate(), 0.25);
        assert_eq!(ModelStats::default().cache_hit_rate(), 0.0);
    }

    #[test]
    fn engine_totals_sum_models() {
        let mut e = EngineStats::default();
        e.models.insert(
            "a".into(),
            ModelStats { served: 3, batches: 2, ..Default::default() },
        );
        e.models.insert(
            "b".into(),
            ModelStats { served: 5, batches: 1, ..Default::default() },
        );
        assert_eq!(e.total_served(), 8);
        assert_eq!(e.total_batches(), 3);
        assert_eq!(e.model("a").unwrap().served, 3);
        assert!(e.model("zap").is_none());
    }

    #[test]
    fn legacy_projection_keeps_counters() {
        let s = ModelStats {
            served: 7,
            batches: 4,
            total_gen_micros: 123,
            total_queue_micros: 456,
            max_batch_seen: 3,
            cancelled: 1,
            ..Default::default()
        };
        let legacy = s.to_serve_stats();
        assert_eq!(legacy.served, 7);
        assert_eq!(legacy.batches, 4);
        assert_eq!(legacy.total_gen_micros, 123);
        assert_eq!(legacy.total_queue_micros, 456);
        assert_eq!(legacy.max_batch_seen, 3);
    }

    #[test]
    fn latency_histograms_record_and_clone() {
        let mut s = ModelStats::default();
        s.queue_us.record(10);
        s.e2e_us.record(250);
        s.e2e_us.record(300);
        let copy = s.clone();
        assert_eq!(copy, s);
        assert_eq!(copy.e2e_us.count(), 2);
        assert!(copy.prefill_us.is_empty());
    }

    #[test]
    fn empty_latency_block_keeps_full_schema() {
        // the mock-fallback / cache-only case: nothing dispatched, yet the
        // block must still carry every phase and every field (count: 0)
        let lat = ModelStats::default().latency_us_json();
        for phase in ["queue", "prefill", "decode_step", "e2e"] {
            let h = lat.get(phase).unwrap_or_else(|| panic!("missing phase {phase}"));
            for field in ["count", "p50", "p90", "p99", "max"] {
                assert_eq!(
                    h.get(field).and_then(|v| v.as_f64()),
                    Some(0.0),
                    "{phase}.{field} should be present and zero"
                );
            }
        }
    }

    #[test]
    fn latency_block_reports_recorded_phases() {
        let mut s = ModelStats::default();
        s.e2e_us.record(100);
        s.e2e_us.record(200);
        let lat = s.latency_us_json();
        let e2e = lat.get("e2e").unwrap();
        assert_eq!(e2e.get("count").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(e2e.get("max").and_then(|v| v.as_f64()), Some(200.0));
        // untouched phases stay at the count-zero shape, not absent
        assert_eq!(
            lat.get("queue").and_then(|q| q.get("count")).and_then(|v| v.as_f64()),
            Some(0.0)
        );
    }

    #[test]
    fn lane_gauges_snapshot_reads_live_values() {
        let g = LaneGauges::new("w4".into(), 8);
        g.queue_depth.store(3, Ordering::Relaxed);
        g.active_slots.store(2, Ordering::Relaxed);
        g.served.store(11, Ordering::Relaxed);
        g.arena_slots.store(8, Ordering::Relaxed);
        g.arena_occupancy.store(2, Ordering::Relaxed);
        let snap = g.snapshot();
        assert_eq!(snap.model, "w4");
        assert_eq!(snap.max_slots, 8);
        assert_eq!(snap.queue_depth, 3);
        assert_eq!(snap.active_slots, 2);
        assert_eq!(snap.served, 11);
        assert_eq!(snap.arena_slots, 8);
        assert_eq!(snap.arena_occupancy, 2);
        assert_eq!(snap.in_flight(), 5);
    }

    #[test]
    fn fast_path_block_keeps_full_schema() {
        // an arena-less (recompute) lane records nothing, yet both keys
        // must still be present with the count-zero shape
        let fp = ModelStats::default().fast_path_json();
        for key in ["arena_occupancy", "admission_batch_size"] {
            let h = fp.get(key).unwrap_or_else(|| panic!("missing key {key}"));
            assert_eq!(h.get("count").and_then(|v| v.as_f64()), Some(0.0));
        }
        let mut s = ModelStats::default();
        s.arena_occupancy.record(3);
        s.arena_occupancy.record(5);
        s.admission_batch.record(4);
        let fp = s.fast_path_json();
        assert_eq!(
            fp.get("arena_occupancy").and_then(|h| h.get("count")).and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(
            fp.get("arena_occupancy").and_then(|h| h.get("max")).and_then(|v| v.as_f64()),
            Some(5.0)
        );
        assert_eq!(
            fp.get("admission_batch_size").and_then(|h| h.get("p50")).and_then(|v| v.as_f64()),
            Some(4.0)
        );
    }
}

//! The engine's continuous-batching scheduler: fair-share round-robin
//! across model lanes, oldest-deadline-first admission within a lane,
//! bucket-aware chunking, per-request decode sessions, and the greedy
//! response cache.
//!
//! The scheduler is deliberately thread-agnostic: it borrows its models as
//! plain `&dyn LanguageModel` and runs wherever it is built.  The owned
//! [`super::Engine`] builds models from `Send` factories inside its own
//! scheduler thread; the deprecated `serve::serve_loop` shim drives the same
//! core on the caller's thread (the XLA-backed runners are not `Send`, so
//! they can never cross a thread boundary themselves).
//!
//! # Continuous batching
//!
//! Generation is no longer dispatch-whole-batch-and-wait: a lane owns up to
//! `max_batch` *slots*, each holding one request's [`DecodeSession`] (its
//! token history, pending logits, and — on runners with exported decode
//! graphs — its per-layer KV cache).  The loop interleaves three moves:
//!
//! 1. **Admit**: queued requests are drained as one *admission group* and
//!    split into bucket-sized prefill chunks, which are *staged* on the
//!    lane (`Lane::pending`) rather than executed inline.  An idle lane
//!    keeps the classic readiness rules — full batch, closed batch window,
//!    or a deadline's dispatch-due point — but a lane that is already
//!    streaming admits immediately between steps: newcomers ride the
//!    running batch instead of waiting out a window.
//! 2. **Work**: each scheduler turn gives one busy lane (live sessions
//!    *or* staged chunks; round-robin, so a backlogged model cannot
//!    starve its neighbours) exactly one unit of graph work: either one
//!    staged chunk's batched prefill or one `decode_step` over all live
//!    sessions.  When a lane has both, prefill and decode turns
//!    *interleave* (`Lane::last_turn_was_prefill` alternates them), so a
//!    long admission backlog cannot stall running streams and a long
//!    stream cannot stall admissions.
//! 3. **Retire**: a session that reaches its target (or is cancelled, or
//!    expires) leaves its slot *immediately* — the freed slot (and its KV
//!    arena slot, on decode-graph runners) is available to the next
//!    admission, not at end-of-batch.
//!
//! Each request samples from its own seed's stream, so any mix of sample
//! configs rides one step batch and results are reproducible regardless of
//! who shares the batch.  Queue time is measured from submit to the
//! admission group's dispatch instant with saturating math (riders of
//! later prefill chunks are not charged earlier chunks' generation time).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::calib::rng::SplitMix64;
use crate::error::{Error, Result};
use crate::eval::decode::lock_arena;
use crate::eval::generate::{sample_next, SampleConfig};
use crate::eval::{DecodeSession, LanguageModel};
use crate::obs::trace::TraceCollector;
use crate::util::json;

use super::cache::ResponseCache;
use super::stats::{EngineStats, LaneGauges, ModelStats};
use super::{EngineResponse, ModelTuning};

/// Where a finished request is answered.
pub(crate) enum ReplyTo {
    /// engine ticket: successes and failures both travel the channel
    Engine(mpsc::Sender<Result<EngineResponse>>),
    /// legacy `serve::Request` reply: the old protocol has no error
    /// channel, so failures drop the sender and the caller's `recv` fails
    /// (the historical "server dropped request" surface)
    Legacy(mpsc::Sender<crate::serve::Response>),
}

impl ReplyTo {
    pub(crate) fn ok(self, r: EngineResponse) {
        match self {
            ReplyTo::Engine(tx) => {
                let _ = tx.send(Ok(r));
            }
            ReplyTo::Legacy(tx) => {
                let _ = tx.send(crate::serve::Response {
                    tokens: r.tokens,
                    prompt_len: r.prompt_len,
                    queue_micros: r.queue_micros,
                    gen_micros: r.gen_micros,
                    batch_size: r.batch_size,
                });
            }
        }
    }

    pub(crate) fn err(self, e: Error) {
        match self {
            ReplyTo::Engine(tx) => {
                let _ = tx.send(Err(e));
            }
            ReplyTo::Legacy(_) => {}
        }
    }
}

/// One queued generation request.
pub(crate) struct Pending {
    /// index into the scheduler's lane table
    pub(crate) lane: usize,
    pub(crate) prompt: Vec<i32>,
    pub(crate) max_new: usize,
    pub(crate) sample: SampleConfig,
    pub(crate) enqueued: Instant,
    /// absolute expiry; `None` = no deadline
    pub(crate) deadline: Option<Instant>,
    pub(crate) reply: ReplyTo,
    pub(crate) cancel: Arc<AtomicBool>,
    /// admission number, assigned by the scheduler (FIFO tie-break)
    pub(crate) seq: u64,
}

/// Messages into the scheduler.
pub(crate) enum Msg {
    Submit(Pending),
    /// graceful shutdown: serve everything queued, then stop
    Shutdown,
}

/// Queue ordering key: oldest-effective-deadline first, FIFO tie-break.
///
/// A no-deadline request is ranked as if it carried an *aging* deadline of
/// 100 batch windows (clamped to [1s, 1h]) from submission, so a sustained
/// stream of deadline'd SLO traffic cannot starve FIFO riders forever:
/// once a FIFO rider has aged past the horizon it outranks every
/// longer-dated deadline.  Among pure FIFO traffic the aging constant
/// cancels out and ordering stays submission order.
fn sort_key(p: &Pending, window: Duration) -> (Instant, u64) {
    let effective = match p.deadline {
        Some(d) => d,
        None => {
            let aging = window
                .saturating_mul(100)
                .clamp(Duration::from_secs(1), Duration::from_secs(3600));
            p.enqueued.checked_add(aging).unwrap_or(p.enqueued)
        }
    };
    (effective, p.seq)
}

/// Latest comfortable dispatch instant for a deadline'd request: half its
/// budget is spent gathering batch mates, the other half is reserved for
/// generation.  Dispatching the moment a deadline is sighted would
/// collapse SLO traffic to batch-of-1; waiting for the full batch window
/// would expire deadlines shorter than it.  The window close still
/// applies — whichever due instant comes first wins.
fn dispatch_due(p: &Pending) -> Option<Instant> {
    p.deadline.map(|d| {
        let budget = d.saturating_duration_since(p.enqueued);
        p.enqueued.checked_add(budget / 2).unwrap_or(d)
    })
}

/// Clamp a `u128` microsecond reading into the histogram's `u64` domain.
fn micros_u64(us: u128) -> u64 {
    us.min(u128::from(u64::MAX)) as u64
}

/// Outcome of checking a rider's cancel flag and deadline.
enum Triage {
    Live,
    Cancelled,
    Expired,
}

/// Shared rider triage — every place a request can leave the system early
/// (routing, sweeps, dispatch, per-chunk prefill) runs the same check.
fn triage(cancel: &AtomicBool, deadline: Option<Instant>, now: Instant) -> Triage {
    if cancel.load(Ordering::Relaxed) {
        return Triage::Cancelled;
    }
    if matches!(deadline, Some(d) if now > d) {
        return Triage::Expired;
    }
    Triage::Live
}

/// Count and answer one expired rider; `stage` names where the expiry was
/// caught so the error is diagnosable.
fn answer_expired(
    stats: &mut ModelStats,
    lane_name: &str,
    stage: &str,
    now: Instant,
    enqueued: Instant,
    reply: ReplyTo,
) {
    stats.deadline_missed += 1;
    reply.err(Error::Serve(format!(
        "deadline exceeded {stage} on model `{lane_name}` (queued {:?})",
        now.saturating_duration_since(enqueued)
    )));
}

/// One occupied cache slot: a live request mid-generation.
struct Slot {
    session: DecodeSession,
    prompt_len: usize,
    max_new: usize,
    /// final sequence length: (prompt + max_new) clamped to the context
    target: usize,
    sample: SampleConfig,
    /// per-request stream seeded from the request's own seed — sessions
    /// sample independently, so batch composition never changes a result
    rng: SplitMix64,
    enqueued: Instant,
    deadline: Option<Instant>,
    reply: ReplyTo,
    cancel: Arc<AtomicBool>,
    /// fixed at admission (submit → group dispatch instant)
    queue_micros: u128,
    /// accumulated wall time of every prefill/decode call this request rode
    gen_micros: u128,
    /// largest batch this request shared (prefill chunk or decode step)
    batch_seen: usize,
    /// a generation call this slot rode failed; answered at retirement
    failed: Option<String>,
    /// admission number (trace span pairing id)
    seq: u64,
}

impl Slot {
    /// Sample the next token from the pending logits and append it.
    fn advance(&mut self) {
        let tok = sample_next(&self.session, self.prompt_len, &self.sample, &mut self.rng);
        self.session.tokens.push(tok);
    }

    fn done(&self) -> bool {
        self.session.tokens.len() >= self.target
    }
}

/// One staged prefill chunk: riders drained from the queue, cut to the
/// model's bucket, waiting for their prefill turn.  All chunks of one
/// admission group share the group's dispatch instant, so queue time is
/// charged up to the drain, not up to the (possibly later) prefill call.
struct PrefillChunk {
    riders: Vec<Pending>,
    t_drain: Instant,
}

/// One registered model, its waiting queue, its staged prefill chunks,
/// and its occupied slots.
pub(crate) struct Lane<'m> {
    pub(crate) name: String,
    pub(crate) model: &'m dyn LanguageModel,
    pub(crate) tuning: ModelTuning,
    queue: Vec<Pending>,
    /// admitted-but-not-yet-prefilled chunks; each costs one work turn
    pending: VecDeque<PrefillChunk>,
    /// alternation flag: when the lane has both staged chunks and live
    /// sessions, prefill and decode turns take strict turns
    last_turn_was_prefill: bool,
    active: Vec<Slot>,
    pub(crate) stats: ModelStats,
    /// live gauges (queue depth, slot occupancy, served) published for
    /// `Client::stats_snapshot`; the engine swaps in its shared set via
    /// [`Scheduler::set_gauges`], the `serve_loop` shim keeps this default
    pub(crate) gauges: Arc<LaneGauges>,
}

impl<'m> Lane<'m> {
    pub(crate) fn new(name: String, model: &'m dyn LanguageModel, tuning: ModelTuning) -> Self {
        let gauges = Arc::new(LaneGauges::new(name.clone(), tuning.max_batch));
        Lane {
            name,
            model,
            tuning,
            queue: Vec::new(),
            pending: VecDeque::new(),
            last_turn_was_prefill: false,
            active: Vec::new(),
            stats: ModelStats::default(),
            gauges,
        }
    }

    /// Largest chunk one graph call may carry (the model's biggest
    /// exported bucket; unbounded models take everything at once).
    fn chunk_cap(&self) -> usize {
        self.model.max_batch().unwrap_or(usize::MAX).max(1)
    }

    /// Riders staged in pending prefill chunks.  They already won their
    /// admission slots, so the free-slot calculation counts them alongside
    /// `active` — otherwise a second drain could over-admit past
    /// `max_batch` before the first drain's chunks ever run.
    fn staged(&self) -> usize {
        self.pending.iter().map(|c| c.riders.len()).sum()
    }

    /// A lane with staged chunks or live sessions has graph work to do.
    fn busy(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }
}

/// Trace track ids, resolved once at [`Scheduler::set_trace`]: the shared
/// scheduler lifecycle track plus one (prefill, decode) pair per lane.
struct SchedTracks {
    sched: u64,
    lanes: Vec<(u64, u64)>,
}

/// The multi-lane continuous-batching scheduler.
pub(crate) struct Scheduler<'m> {
    lanes: Vec<Lane<'m>>,
    rx: mpsc::Receiver<Msg>,
    cache: ResponseCache,
    /// round-robin cursor over lanes with live sessions
    rr: usize,
    /// shutdown requested (or every sender dropped): serve what is queued
    /// without waiting for batch windows, then exit
    draining: bool,
    seq: u64,
    /// trace collector (`None` = tracing disabled, zero overhead)
    trace: Option<Arc<TraceCollector>>,
    tracks: Option<SchedTracks>,
}

impl<'m> Scheduler<'m> {
    pub(crate) fn new(lanes: Vec<Lane<'m>>, rx: mpsc::Receiver<Msg>, cache_cap: usize) -> Self {
        Scheduler {
            lanes,
            rx,
            cache: ResponseCache::new(cache_cap),
            rr: 0,
            draining: false,
            seq: 0,
            trace: None,
            tracks: None,
        }
    }

    /// Attach a trace collector: request lifecycle instants land on the
    /// `scheduler` track, dispatch spans on `lane:<name>/prefill` and
    /// `lane:<name>/decode`.  Call before [`Scheduler::warm_up`] so
    /// warm-up batches are traced too.
    pub(crate) fn set_trace(&mut self, trace: Arc<TraceCollector>) {
        let sched = trace.track("scheduler");
        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                (
                    trace.track(&format!("lane:{}/prefill", l.name)),
                    trace.track(&format!("lane:{}/decode", l.name)),
                )
            })
            .collect();
        self.tracks = Some(SchedTracks { sched, lanes });
        self.trace = Some(trace);
    }

    /// Swap in the engine's shared per-lane gauges (one per lane, in lane
    /// order) so `Client::stats_snapshot` observes this scheduler.
    pub(crate) fn set_gauges(&mut self, gauges: Vec<Arc<LaneGauges>>) {
        for (lane, g) in self.lanes.iter_mut().zip(gauges) {
            lane.gauges = g;
        }
    }

    /// Publish queue depth / slot occupancy / served / KV-arena occupancy
    /// onto the lane gauges.  Staged riders still count as queued: they
    /// have not been prefilled yet.
    fn publish_gauges(&self) {
        for lane in &self.lanes {
            lane.gauges.queue_depth.store(lane.queue.len() + lane.staged(), Ordering::Relaxed);
            lane.gauges.active_slots.store(lane.active.len(), Ordering::Relaxed);
            lane.gauges.served.store(lane.stats.served, Ordering::Relaxed);
            if let Some(arena) = lane.model.kv_arena() {
                let (slots, occ) = {
                    let g = lock_arena(&arena);
                    (g.slots(), g.occupancy())
                };
                lane.gauges.arena_slots.store(slots, Ordering::Relaxed);
                lane.gauges.arena_occupancy.store(occ, Ordering::Relaxed);
            }
        }
    }

    /// Run one priming batch per model/bucket so the first real riders do
    /// not pay graph compile + dispatch latency.  Decode-capable models
    /// generate one extra token so the `embed_dec`/`block_dec`/`head_dec`
    /// step graphs compile during warm-up too, not under the first rider.
    pub(crate) fn warm_up(&mut self) -> Result<()> {
        let sample = SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 };
        for (li, lane) in self.lanes.iter_mut().enumerate() {
            let mut buckets: Vec<usize> =
                lane.model.warm_buckets().into_iter().filter(|&b| b > 0).collect();
            buckets.sort_unstable();
            buckets.dedup();
            let cfg = lane.model.config();
            let tok = if cfg.vocab > 1 { 1 } else { 0 };
            let depth = if lane.model.supports_decode() { 3 } else { 2 };
            let target = depth.min(cfg.seq);
            for b in buckets {
                let prompts = vec![vec![tok]; b];
                let ts = self.trace.as_ref().map(|t| t.now());
                crate::eval::generate::generate(lane.model, &prompts, target, &sample)
                    .map_err(|e| {
                        Error::Serve(format!(
                            "warm-up of model `{}` (bucket {b}) failed: {e}",
                            lane.name
                        ))
                    })?;
                if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
                    tr.complete(
                        tk.lanes[li].0,
                        "warmup",
                        ts.unwrap_or(0),
                        vec![("bucket", json::n(b as f64))],
                    );
                }
                lane.stats.warmup_batches += 1;
            }
        }
        Ok(())
    }

    /// Serve until shutdown (a [`Msg::Shutdown`] or every sender dropping),
    /// then drain the queues and live sessions and return the final stats.
    pub(crate) fn run(mut self) -> EngineStats {
        loop {
            // ingest everything already waiting in the channel
            loop {
                match self.rx.try_recv() {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            // drop cancellations, expire deadlines (queued *and* live)
            self.sweep();

            // stage ready admission groups as prefill chunks on every
            // lane, then give one busy lane one unit of graph work (one
            // staged chunk's prefill, or one decode step — interleaved)
            let mut worked = false;
            for li in 0..self.lanes.len() {
                worked |= self.admit_ready(li);
            }
            if let Some(li) = self.next_busy_lane() {
                self.turn(li);
                worked = true;
            }
            self.publish_gauges();
            if worked {
                continue;
            }

            if self.draining
                && self
                    .lanes
                    .iter()
                    .all(|l| l.queue.is_empty() && l.pending.is_empty() && l.active.is_empty())
            {
                // answer any last-gasp submissions still in the channel
                loop {
                    match self.rx.try_recv() {
                        Ok(Msg::Submit(p)) => {
                            p.reply.err(Error::Serve("engine is shutting down".into()));
                        }
                        Ok(Msg::Shutdown) => {}
                        Err(_) => break,
                    }
                }
                self.publish_gauges();
                return self.finish();
            }

            // idle: sleep until the next window/deadline or a new message
            match self.next_wakeup() {
                Some(d) => match self.rx.recv_timeout(d) {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => self.draining = true,
                },
                None => match self.rx.recv() {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(_) => self.draining = true,
                },
            }
        }
    }

    /// Accept a submission unless the engine is draining: requests sent
    /// after shutdown began are refused immediately, so a client that
    /// keeps submitting cannot hold the drain open forever (channel FIFO
    /// guarantees everything sent *before* the shutdown message is still
    /// routed and served).
    fn admit(&mut self, p: Pending) {
        if self.draining {
            p.reply.err(Error::Serve("engine is shutting down".into()));
        } else {
            self.route(p);
        }
    }

    /// Admit one request: validate, try the cache, or queue it in deadline
    /// order.
    fn route(&mut self, mut p: Pending) {
        self.seq += 1;
        p.seq = self.seq;
        if p.lane >= self.lanes.len() {
            p.reply.err(Error::Serve("request routed to an unknown model lane".into()));
            return;
        }
        let seq_len = self.lanes[p.lane].model.config().seq;
        let now = Instant::now();
        match triage(&p.cancel, p.deadline, now) {
            Triage::Cancelled => {
                self.lanes[p.lane].stats.cancelled += 1;
                return;
            }
            Triage::Expired => {
                let lane = &mut self.lanes[p.lane];
                answer_expired(
                    &mut lane.stats, &lane.name, "before scheduling",
                    now, p.enqueued, p.reply,
                );
                return;
            }
            Triage::Live => {}
        }
        if p.prompt.is_empty() || p.prompt.len() > seq_len {
            self.lanes[p.lane].stats.rejected += 1;
            p.reply.err(Error::Serve(format!(
                "prompt length {} outside [1, {seq_len}] for model `{}`",
                p.prompt.len(),
                self.lanes[p.lane].name
            )));
            return;
        }
        if self.cache.enabled() && p.sample.temperature == 0.0 {
            let key = (p.lane, p.prompt.clone(), p.max_new);
            if let Some(tokens) = self.cache.get(&key) {
                let lane = &mut self.lanes[p.lane];
                let queue_micros = now.saturating_duration_since(p.enqueued).as_micros();
                lane.stats.cache_hits += 1;
                lane.stats.served += 1;
                lane.stats.total_queue_micros += queue_micros;
                lane.stats.queue_us.record(micros_u64(queue_micros));
                lane.stats.e2e_us.record(micros_u64(queue_micros));
                let name = lane.name.clone();
                p.reply.ok(EngineResponse {
                    model: name.clone(),
                    prompt_len: p.prompt.len(),
                    tokens,
                    queue_micros,
                    gen_micros: 0,
                    batch_size: 0,
                    cached: true,
                });
                if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
                    tr.instant(
                        tk.sched,
                        "cache_hit",
                        vec![("model", json::s(name)), ("seq", json::n(p.seq as f64))],
                    );
                }
                return;
            }
            // the miss is counted at retirement, so a request that is
            // later cancelled or expires doesn't skew the hit rate of
            // answered traffic
        }
        let seq = p.seq;
        let lane_idx = p.lane;
        let lane = &mut self.lanes[p.lane];
        let window = lane.tuning.batch_window;
        let key = sort_key(&p, window);
        let pos = lane.queue.partition_point(|q| sort_key(q, window) <= key);
        lane.queue.insert(pos, p);
        if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
            let name = &self.lanes[lane_idx].name;
            let args = vec![("model", json::s(name.clone())), ("seq", json::n(seq as f64))];
            tr.instant(tk.sched, "submit", args.clone());
            tr.async_begin(tk.sched, "request", seq, args);
        }
    }

    /// Drop cancelled requests and answer expired deadlines with an error.
    /// Live sessions are swept too: a dropped ticket or mid-generation
    /// expiry frees its cache slot *now*, not at end of generation.
    fn sweep(&mut self) {
        let now = Instant::now();
        for lane in &mut self.lanes {
            // cancellations/expiries are rare: don't rebuild the queue on
            // every scheduler iteration unless one actually exists
            let dirty = lane.queue.iter().any(|p| {
                p.cancel.load(Ordering::Relaxed)
                    || matches!(p.deadline, Some(d) if now > d)
            });
            if dirty {
                let queue = std::mem::take(&mut lane.queue);
                for p in queue {
                    match triage(&p.cancel, p.deadline, now) {
                        Triage::Cancelled => lane.stats.cancelled += 1,
                        Triage::Expired => answer_expired(
                            &mut lane.stats, &lane.name, "while queued",
                            now, p.enqueued, p.reply,
                        ),
                        Triage::Live => lane.queue.push(p),
                    }
                }
            }

            // staged chunks are swept too — a cancelled rider should not
            // hold its admission slot (nor ride the chunk's prefill);
            // chunks emptied by the sweep vanish without costing a turn
            let dirty = lane.pending.iter().flat_map(|c| c.riders.iter()).any(|p| {
                p.cancel.load(Ordering::Relaxed)
                    || matches!(p.deadline, Some(d) if now > d)
            });
            if dirty {
                let pending = std::mem::take(&mut lane.pending);
                for mut chunk in pending {
                    let riders = std::mem::take(&mut chunk.riders);
                    for p in riders {
                        match triage(&p.cancel, p.deadline, now) {
                            Triage::Cancelled => lane.stats.cancelled += 1,
                            Triage::Expired => answer_expired(
                                &mut lane.stats, &lane.name, "while staged",
                                now, p.enqueued, p.reply,
                            ),
                            Triage::Live => chunk.riders.push(p),
                        }
                    }
                    if !chunk.riders.is_empty() {
                        lane.pending.push_back(chunk);
                    }
                }
            }

            let dirty = lane.active.iter().any(|s| {
                s.cancel.load(Ordering::Relaxed)
                    || matches!(s.deadline, Some(d) if now > d)
            });
            if dirty {
                let active = std::mem::take(&mut lane.active);
                for slot in active {
                    match triage(&slot.cancel, slot.deadline, now) {
                        Triage::Cancelled => lane.stats.cancelled += 1,
                        Triage::Expired => answer_expired(
                            &mut lane.stats, &lane.name, "mid-generation",
                            now, slot.enqueued, slot.reply,
                        ),
                        Triage::Live => lane.active.push(slot),
                    }
                }
            }
        }
    }

    /// Admit queued requests into this lane's free slots, staging them as
    /// prefill chunks.  An idle lane honours the classic readiness rules;
    /// a streaming lane admits immediately between steps (continuous
    /// batching).  Returns whether a drain happened.
    fn admit_ready(&mut self, li: usize) -> bool {
        let draining = self.draining;
        let now = Instant::now();
        let take = {
            let lane = &self.lanes[li];
            if lane.queue.is_empty() {
                return false;
            }
            // staged riders already hold admission slots: counting them
            // keeps a lane from over-admitting while its chunks wait
            let free = lane
                .tuning
                .max_batch
                .saturating_sub(lane.active.len() + lane.staged());
            if free == 0 {
                return false;
            }
            let ready = if draining || lane.busy() {
                true
            } else {
                // emptiness was rejected above, so `min()` always yields;
                // treat a broken invariant as not-ready rather than panic
                let Some(oldest) = lane.queue.iter().map(|p| p.enqueued).min() else {
                    return false;
                };
                let window_due = oldest.checked_add(lane.tuning.batch_window);
                // a queued deadline pulls the lane's due instant forward
                // to that request's dispatch-due point (half its budget),
                // so a deadline shorter than the batch window is served in
                // time without collapsing SLO traffic to batch-of-1
                let earliest_due = lane.queue.iter().filter_map(dispatch_due).min();
                let due = match (window_due, earliest_due) {
                    (Some(w), Some(u)) => Some(w.min(u)),
                    (w, u) => w.or(u),
                };
                lane.queue.len() >= lane.tuning.max_batch
                    || matches!(due, Some(t) if now >= t)
            };
            if !ready {
                return false;
            }
            free.min(lane.queue.len())
        };
        let group: Vec<Pending> = self.lanes[li].queue.drain(..take).collect();
        self.admit_group(li, group);
        true
    }

    /// Admit one dispatch group: answer degenerate requests, then cut the
    /// rest into bucket-sized prefill chunks and stage them on the lane
    /// (each chunk is executed by a later work turn, interleaved with
    /// decode steps).  All riders share the group's dispatch instant for
    /// queue-time accounting.
    fn admit_group(&mut self, li: usize, group: Vec<Pending>) {
        let t_drain = Instant::now();
        let seq = self.lanes[li].model.config().seq;
        let chunk_cap = self.lanes[li].chunk_cap();
        let mut pend: Vec<Pending> = Vec::with_capacity(group.len());
        for p in group {
            // re-checked at dispatch: a rider may have been cancelled or
            // expired after the queue sweep of this iteration
            match triage(&p.cancel, p.deadline, t_drain) {
                Triage::Cancelled => {
                    self.lanes[li].stats.cancelled += 1;
                    continue;
                }
                Triage::Expired => {
                    let lane = &mut self.lanes[li];
                    answer_expired(
                        &mut lane.stats, &lane.name, "at dispatch",
                        t_drain, p.enqueued, p.reply,
                    );
                    continue;
                }
                Triage::Live => {}
            }
            let target = (p.prompt.len() + p.max_new).min(seq);
            if target <= p.prompt.len() {
                // nothing to generate: answer with the (possibly clamped)
                // prompt without burning a prefill slot
                let queue_micros = t_drain.saturating_duration_since(p.enqueued).as_micros();
                let lane = &mut self.lanes[li];
                lane.stats.served += 1;
                lane.stats.total_queue_micros += queue_micros;
                lane.stats.queue_us.record(micros_u64(queue_micros));
                lane.stats.e2e_us.record(micros_u64(queue_micros));
                let prompt_len = p.prompt.len();
                p.reply.ok(EngineResponse {
                    model: lane.name.clone(),
                    prompt_len,
                    tokens: p.prompt[..target].to_vec(),
                    queue_micros,
                    gen_micros: 0,
                    batch_size: 0,
                    cached: false,
                });
                continue;
            }
            pend.push(p);
        }
        if pend.is_empty() {
            return;
        }
        self.lanes[li].stats.admission_batch.record(pend.len() as u64);
        crate::obs::global()
            .histogram("admission.batch_size")
            .record(pend.len() as u64);
        while !pend.is_empty() {
            let rest = if pend.len() > chunk_cap {
                pend.split_off(chunk_cap)
            } else {
                Vec::new()
            };
            let riders = std::mem::replace(&mut pend, rest);
            self.lanes[li].pending.push_back(PrefillChunk { riders, t_drain });
        }
    }

    /// Prefill one chunk of admitted requests into live slots: one batched
    /// prefill call, first token sampled from its logits; requests already
    /// satisfied retire immediately, the rest occupy slots for stepping.
    fn prefill_chunk(&mut self, li: usize, chunk: Vec<Pending>, t_drain: Instant) {
        // deadlines and cancellations are re-checked per chunk: a rider of
        // a late chunk may have expired while earlier chunks of the same
        // dispatch group were prefilling, and must get the deadline error,
        // not a late Ok (nor consume prefill compute after cancelling)
        let now = Instant::now();
        let mut live = Vec::with_capacity(chunk.len());
        {
            let lane = &mut self.lanes[li];
            for p in chunk {
                match triage(&p.cancel, p.deadline, now) {
                    Triage::Cancelled => lane.stats.cancelled += 1,
                    Triage::Expired => answer_expired(
                        &mut lane.stats, &lane.name, "before generation",
                        now, p.enqueued, p.reply,
                    ),
                    Triage::Live => live.push(p),
                }
            }
        }
        if live.is_empty() {
            return;
        }
        let chunk = live;
        let bs = chunk.len();
        let prompts: Vec<Vec<i32>> = chunk.iter().map(|p| p.prompt.clone()).collect();
        if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
            for p in &chunk {
                tr.instant(tk.sched, "admit", vec![("seq", json::n(p.seq as f64))]);
            }
        }
        let model = self.lanes[li].model;
        let seq = model.config().seq;
        let trace_start = self.trace.as_ref().map(|t| t.now());
        let t0 = Instant::now();
        let result = model.prefill(&prompts);
        let gen = t0.elapsed().as_micros();
        if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
            tr.complete(
                tk.lanes[li].0,
                "prefill",
                trace_start.unwrap_or(0),
                vec![
                    ("batch", json::n(bs as f64)),
                    (
                        "tokens",
                        json::n(prompts.iter().map(|p| p.len()).sum::<usize>() as f64),
                    ),
                ],
            );
        }
        match result {
            Ok(sessions) => {
                {
                    let stats = &mut self.lanes[li].stats;
                    stats.batches += 1;
                    stats.total_gen_micros += gen;
                    stats.total_prefill_micros += gen;
                    stats.prefill_us.record(micros_u64(gen));
                    stats.prefill_tokens +=
                        prompts.iter().map(|p| p.len() as u128).sum::<u128>();
                    stats.max_batch_seen = stats.max_batch_seen.max(bs);
                }
                for (p, session) in chunk.into_iter().zip(sessions) {
                    let queue_micros =
                        t_drain.saturating_duration_since(p.enqueued).as_micros();
                    self.lanes[li].stats.queue_us.record(micros_u64(queue_micros));
                    let mut slot = Slot {
                        prompt_len: p.prompt.len(),
                        max_new: p.max_new,
                        target: (p.prompt.len() + p.max_new).min(seq),
                        sample: p.sample,
                        rng: SplitMix64::new(p.sample.seed),
                        enqueued: p.enqueued,
                        deadline: p.deadline,
                        reply: p.reply,
                        cancel: p.cancel,
                        queue_micros,
                        gen_micros: gen,
                        batch_seen: bs,
                        failed: None,
                        seq: p.seq,
                        session,
                    };
                    slot.advance();
                    if slot.done() {
                        self.finish_slot(li, slot);
                    } else {
                        self.lanes[li].active.push(slot);
                    }
                }
            }
            Err(e) => {
                let lane = &mut self.lanes[li];
                let msg = format!("generation failed on model `{}`: {e}", lane.name);
                if lane.stats.first_error.is_none() {
                    lane.stats.first_error = Some(msg.clone());
                }
                for p in chunk {
                    lane.stats.failed += 1;
                    p.reply.err(Error::Serve(msg.clone()));
                }
            }
        }
    }

    /// Next lane with graph work (staged chunks or live sessions),
    /// fair-share round-robin.
    fn next_busy_lane(&mut self) -> Option<usize> {
        let n = self.lanes.len();
        for off in 0..n {
            let li = (self.rr + off) % n;
            if self.lanes[li].busy() {
                self.rr = (li + 1) % n;
                return Some(li);
            }
        }
        None
    }

    /// One unit of graph work for a busy lane: prefill the oldest staged
    /// chunk, or decode-step the live sessions.  A lane holding both
    /// strictly alternates, so chunked admissions *interleave* with
    /// decode turns — newcomers start streaming without stalling running
    /// sessions, and a deep admission backlog cannot monopolise the lane.
    fn turn(&mut self, li: usize) {
        let lane = &self.lanes[li];
        let do_prefill =
            !lane.pending.is_empty() && (lane.active.is_empty() || !lane.last_turn_was_prefill);
        if do_prefill {
            let Some(chunk) = self.lanes[li].pending.pop_front() else {
                return; // unreachable: emptiness was rejected above
            };
            self.lanes[li].last_turn_was_prefill = true;
            self.prefill_chunk(li, chunk.riders, chunk.t_drain);
        } else {
            self.lanes[li].last_turn_was_prefill = false;
            self.step(li);
        }
    }

    /// Advance every live session of a lane by one token (one decode step,
    /// chunked to the model bucket), then retire finished rows.
    fn step(&mut self, li: usize) {
        let model = self.lanes[li].model;
        // sample KV-arena occupancy once per decode turn (how many slots
        // back the sessions about to step) — the distribution lands in
        // `fast_path_json` so benches can show arena utilisation
        if let Some(arena) = model.kv_arena() {
            let occ = lock_arena(&arena).occupancy();
            self.lanes[li].stats.arena_occupancy.record(occ as u64);
            crate::obs::global().gauge("arena.occupancy").set(occ as i64);
        }
        let cap = self.lanes[li].chunk_cap();
        let n = self.lanes[li].active.len();
        let mut start = 0;
        while start < n {
            let end = start.saturating_add(cap).min(n);
            let bs = end - start;
            let trace_start = self.trace.as_ref().map(|t| t.now());
            let t0 = Instant::now();
            let result = {
                let chunk = &mut self.lanes[li].active[start..end];
                let mut refs: Vec<&mut DecodeSession> =
                    chunk.iter_mut().map(|s| &mut s.session).collect();
                model.decode_step(&mut refs)
            };
            let dt = t0.elapsed().as_micros();
            if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
                tr.complete(
                    tk.lanes[li].1,
                    "decode_step",
                    trace_start.unwrap_or(0),
                    vec![("batch", json::n(bs as f64))],
                );
            }
            let lane = &mut self.lanes[li];
            match result {
                Ok(()) => {
                    lane.stats.decode_steps += 1;
                    lane.stats.total_gen_micros += dt;
                    lane.stats.total_decode_micros += dt;
                    lane.stats.decode_step_us.record(micros_u64(dt));
                    lane.stats.decode_tokens += bs as u128;
                    lane.stats.max_batch_seen = lane.stats.max_batch_seen.max(bs);
                    for slot in &mut lane.active[start..end] {
                        slot.gen_micros += dt;
                        slot.batch_seen = slot.batch_seen.max(bs);
                        slot.advance();
                    }
                }
                Err(e) => {
                    let msg = format!("decode step failed on model `{}`: {e}", lane.name);
                    if lane.stats.first_error.is_none() {
                        lane.stats.first_error = Some(msg.clone());
                    }
                    for slot in &mut lane.active[start..end] {
                        slot.failed = Some(msg.clone());
                    }
                }
            }
            start = end;
        }
        self.retire(li);
    }

    /// Move finished/failed sessions out of their slots and answer them.
    fn retire(&mut self, li: usize) {
        let slots = std::mem::take(&mut self.lanes[li].active);
        for mut slot in slots {
            if let Some(msg) = slot.failed.take() {
                self.lanes[li].stats.failed += 1;
                slot.reply.err(Error::Serve(msg));
                continue;
            }
            if slot.done() {
                self.finish_slot(li, slot);
            } else {
                self.lanes[li].active.push(slot);
            }
        }
    }

    /// Answer one completed session and (for greedy traffic) feed the
    /// response cache.
    fn finish_slot(&mut self, li: usize, slot: Slot) {
        let Slot {
            session,
            prompt_len,
            max_new,
            sample,
            reply,
            enqueued,
            queue_micros,
            gen_micros,
            batch_seen,
            seq,
            ..
        } = slot;
        let tokens = session.tokens;
        if self.cache.enabled() && sample.temperature == 0.0 {
            self.lanes[li].stats.cache_misses += 1;
            self.cache
                .insert((li, tokens[..prompt_len].to_vec(), max_new), tokens.clone());
        }
        let e2e = Instant::now().saturating_duration_since(enqueued).as_micros();
        let lane = &mut self.lanes[li];
        lane.stats.served += 1;
        lane.stats.total_queue_micros += queue_micros;
        lane.stats.e2e_us.record(micros_u64(e2e));
        reply.ok(EngineResponse {
            model: lane.name.clone(),
            prompt_len,
            tokens,
            queue_micros,
            gen_micros,
            batch_size: batch_seen,
            cached: false,
        });
        if let (Some(tr), Some(tk)) = (&self.trace, &self.tracks) {
            tr.instant(tk.sched, "retire", vec![("seq", json::n(seq as f64))]);
            tr.async_end(tk.sched, "request", seq);
        }
    }

    /// How long the scheduler may sleep before a window closes or a
    /// deadline expires; `None` when every queue is empty.  (Only
    /// consulted when no lane is busy — staged chunks and live sessions
    /// both count as work, so a busy lane never sleeps.)
    fn next_wakeup(&self) -> Option<Duration> {
        let now = Instant::now();
        let mut earliest: Option<Instant> = None;
        for lane in &self.lanes {
            if lane.queue.is_empty() {
                continue;
            }
            let Some(oldest) = lane.queue.iter().map(|p| p.enqueued).min() else {
                continue; // unreachable: emptiness was rejected above
            };
            let window_due = oldest.checked_add(lane.tuning.batch_window);
            // wake for dispatch-due instants (so deadline'd requests ride
            // out in time) and for raw deadlines (so a blocked queue still
            // answers expiries promptly)
            for t in window_due
                .into_iter()
                .chain(lane.queue.iter().filter_map(dispatch_due))
                .chain(lane.queue.iter().filter_map(|p| p.deadline))
            {
                let sooner = match earliest {
                    Some(e) => t < e,
                    None => true,
                };
                if sooner {
                    earliest = Some(t);
                }
            }
        }
        earliest.map(|t| t.saturating_duration_since(now))
    }

    fn finish(self) -> EngineStats {
        let mut stats = EngineStats::default();
        for lane in self.lanes {
            stats.models.insert(lane.name, lane.stats);
        }
        stats
    }
}

//! The engine's batching scheduler: fair-share round-robin across model
//! lanes, oldest-deadline-first within a lane, bucket-aware chunking, and
//! the greedy response cache.
//!
//! The scheduler is deliberately thread-agnostic: it borrows its models as
//! plain `&dyn LanguageModel` and runs wherever it is built.  The owned
//! [`super::Engine`] builds models from `Send` factories inside its own
//! scheduler thread; the deprecated `serve::serve_loop` shim drives the same
//! core on the caller's thread (the XLA-backed runners are not `Send`, so
//! they can never cross a thread boundary themselves).
//!
//! Scheduling policy, in order:
//! 1. a lane is *ready* when its queue holds a full batch, when its oldest
//!    rider has waited at least `batch_window`, when a queued deadline'd
//!    request reaches its dispatch-due point (half its deadline budget —
//!    the other half is reserved for generation, so tight deadlines are
//!    served in time without collapsing SLO traffic to batch-of-1), or
//!    unconditionally while draining for shutdown;
//! 2. ready lanes are served round-robin (one dispatch per turn) so a
//!    backlogged model cannot starve its neighbours;
//! 3. within a lane, requests are ordered oldest-deadline-first; a
//!    no-deadline request ages into an effective deadline of 100 batch
//!    windows (clamped to [1s, 1h]) so sustained SLO traffic cannot
//!    starve FIFO riders, and pure FIFO traffic keeps submission order;
//! 4. a dispatch group is capped at the lane's `max_batch` and split into
//!    [`LanguageModel::max_batch`]-sized chunks (the largest exported AOT
//!    bucket), so an over-eager tuning degrades to more batches instead of
//!    failing riders;
//! 5. queue time is measured from submit to the *group's* dispatch instant
//!    (`t_drain`), so riders of later chunks are not charged earlier
//!    chunks' generation time, with saturating math throughout.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::eval::generate::{generate, SampleConfig};
use crate::eval::LanguageModel;

use super::cache::ResponseCache;
use super::stats::{EngineStats, ModelStats};
use super::{EngineResponse, ModelTuning};

/// Where a finished request is answered.
pub(crate) enum ReplyTo {
    /// engine ticket: successes and failures both travel the channel
    Engine(mpsc::Sender<Result<EngineResponse>>),
    /// legacy `serve::Request` reply: the old protocol has no error
    /// channel, so failures drop the sender and the caller's `recv` fails
    /// (the historical "server dropped request" surface)
    Legacy(mpsc::Sender<crate::serve::Response>),
}

impl ReplyTo {
    pub(crate) fn ok(self, r: EngineResponse) {
        match self {
            ReplyTo::Engine(tx) => {
                let _ = tx.send(Ok(r));
            }
            ReplyTo::Legacy(tx) => {
                let _ = tx.send(crate::serve::Response {
                    tokens: r.tokens,
                    prompt_len: r.prompt_len,
                    queue_micros: r.queue_micros,
                    gen_micros: r.gen_micros,
                    batch_size: r.batch_size,
                });
            }
        }
    }

    pub(crate) fn err(self, e: Error) {
        match self {
            ReplyTo::Engine(tx) => {
                let _ = tx.send(Err(e));
            }
            ReplyTo::Legacy(_) => {}
        }
    }
}

/// One queued generation request.
pub(crate) struct Pending {
    /// index into the scheduler's lane table
    pub(crate) lane: usize,
    pub(crate) prompt: Vec<i32>,
    pub(crate) max_new: usize,
    pub(crate) sample: SampleConfig,
    pub(crate) enqueued: Instant,
    /// absolute expiry; `None` = no deadline
    pub(crate) deadline: Option<Instant>,
    pub(crate) reply: ReplyTo,
    pub(crate) cancel: Arc<AtomicBool>,
    /// admission number, assigned by the scheduler (FIFO tie-break)
    pub(crate) seq: u64,
}

/// Messages into the scheduler.
pub(crate) enum Msg {
    Submit(Pending),
    /// graceful shutdown: serve everything queued, then stop
    Shutdown,
}

/// Queue ordering key: oldest-effective-deadline first, FIFO tie-break.
///
/// A no-deadline request is ranked as if it carried an *aging* deadline of
/// 100 batch windows (clamped to [1s, 1h]) from submission, so a sustained
/// stream of deadline'd SLO traffic cannot starve FIFO riders forever:
/// once a FIFO rider has aged past the horizon it outranks every
/// longer-dated deadline.  Among pure FIFO traffic the aging constant
/// cancels out and ordering stays submission order.
fn sort_key(p: &Pending, window: Duration) -> (Instant, u64) {
    let effective = match p.deadline {
        Some(d) => d,
        None => {
            let aging = window
                .saturating_mul(100)
                .clamp(Duration::from_secs(1), Duration::from_secs(3600));
            p.enqueued.checked_add(aging).unwrap_or(p.enqueued)
        }
    };
    (effective, p.seq)
}

/// Latest comfortable dispatch instant for a deadline'd request: half its
/// budget is spent gathering batch mates, the other half is reserved for
/// generation.  Dispatching the moment a deadline is sighted would
/// collapse SLO traffic to batch-of-1; waiting for the full batch window
/// would expire deadlines shorter than it.  The window close still
/// applies — whichever due instant comes first wins.
fn dispatch_due(p: &Pending) -> Option<Instant> {
    p.deadline.map(|d| {
        let budget = d.saturating_duration_since(p.enqueued);
        p.enqueued.checked_add(budget / 2).unwrap_or(d)
    })
}

/// One registered model and its private queue.
pub(crate) struct Lane<'m> {
    pub(crate) name: String,
    pub(crate) model: &'m dyn LanguageModel,
    pub(crate) tuning: ModelTuning,
    queue: Vec<Pending>,
    pub(crate) stats: ModelStats,
}

impl<'m> Lane<'m> {
    pub(crate) fn new(name: String, model: &'m dyn LanguageModel, tuning: ModelTuning) -> Self {
        Lane { name, model, tuning, queue: Vec::new(), stats: ModelStats::default() }
    }
}

/// The multi-lane batching scheduler.
pub(crate) struct Scheduler<'m> {
    lanes: Vec<Lane<'m>>,
    rx: mpsc::Receiver<Msg>,
    cache: ResponseCache,
    /// round-robin cursor over lanes
    rr: usize,
    /// shutdown requested (or every sender dropped): serve what is queued
    /// without waiting for batch windows, then exit
    draining: bool,
    seq: u64,
}

impl<'m> Scheduler<'m> {
    pub(crate) fn new(lanes: Vec<Lane<'m>>, rx: mpsc::Receiver<Msg>, cache_cap: usize) -> Self {
        Scheduler { lanes, rx, cache: ResponseCache::new(cache_cap), rr: 0, draining: false, seq: 0 }
    }

    /// Run one priming batch per model/bucket so the first real riders do
    /// not pay graph compile + dispatch latency.
    pub(crate) fn warm_up(&mut self) -> Result<()> {
        let sample = SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 };
        for lane in &mut self.lanes {
            let mut buckets: Vec<usize> =
                lane.model.warm_buckets().into_iter().filter(|&b| b > 0).collect();
            buckets.sort_unstable();
            buckets.dedup();
            let cfg = lane.model.config();
            let tok = if cfg.vocab > 1 { 1 } else { 0 };
            let target = 2.min(cfg.seq);
            for b in buckets {
                let prompts = vec![vec![tok]; b];
                generate(lane.model, &prompts, target, &sample).map_err(|e| {
                    Error::Serve(format!("warm-up of model `{}` (bucket {b}) failed: {e}", lane.name))
                })?;
                lane.stats.warmup_batches += 1;
            }
        }
        Ok(())
    }

    /// Serve until shutdown (a [`Msg::Shutdown`] or every sender dropping),
    /// then drain the queues and return the final stats.
    pub(crate) fn run(mut self) -> EngineStats {
        loop {
            // ingest everything already waiting in the channel
            loop {
                match self.rx.try_recv() {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            // drop cancellations, expire deadlines
            self.sweep();

            if let Some(li) = self.next_ready_lane() {
                self.dispatch(li);
                continue;
            }
            if self.draining && self.lanes.iter().all(|l| l.queue.is_empty()) {
                // answer any last-gasp submissions still in the channel
                loop {
                    match self.rx.try_recv() {
                        Ok(Msg::Submit(p)) => {
                            p.reply.err(Error::Serve("engine is shutting down".into()));
                        }
                        Ok(Msg::Shutdown) => {}
                        Err(_) => break,
                    }
                }
                return self.finish();
            }

            // idle: sleep until the next window/deadline or a new message
            match self.next_wakeup() {
                Some(d) => match self.rx.recv_timeout(d) {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => self.draining = true,
                },
                None => match self.rx.recv() {
                    Ok(Msg::Submit(p)) => self.admit(p),
                    Ok(Msg::Shutdown) => self.draining = true,
                    Err(_) => self.draining = true,
                },
            }
        }
    }

    /// Accept a submission unless the engine is draining: requests sent
    /// after shutdown began are refused immediately, so a client that
    /// keeps submitting cannot hold the drain open forever (channel FIFO
    /// guarantees everything sent *before* the shutdown message is still
    /// routed and served).
    fn admit(&mut self, p: Pending) {
        if self.draining {
            p.reply.err(Error::Serve("engine is shutting down".into()));
        } else {
            self.route(p);
        }
    }

    /// Admit one request: validate, try the cache, or queue it in deadline
    /// order.
    fn route(&mut self, mut p: Pending) {
        self.seq += 1;
        p.seq = self.seq;
        if p.lane >= self.lanes.len() {
            p.reply.err(Error::Serve("request routed to an unknown model lane".into()));
            return;
        }
        if p.cancel.load(Ordering::Relaxed) {
            self.lanes[p.lane].stats.cancelled += 1;
            return;
        }
        let seq_len = self.lanes[p.lane].model.config().seq;
        if p.prompt.is_empty() || p.prompt.len() > seq_len {
            self.lanes[p.lane].stats.rejected += 1;
            p.reply.err(Error::Serve(format!(
                "prompt length {} outside [1, {seq_len}] for model `{}`",
                p.prompt.len(),
                self.lanes[p.lane].name
            )));
            return;
        }
        let now = Instant::now();
        if let Some(d) = p.deadline {
            if now > d {
                self.lanes[p.lane].stats.deadline_missed += 1;
                p.reply.err(Error::Serve(format!(
                    "deadline exceeded before scheduling on model `{}` (queued {:?})",
                    self.lanes[p.lane].name,
                    now.saturating_duration_since(p.enqueued)
                )));
                return;
            }
        }
        if self.cache.enabled() && p.sample.temperature == 0.0 {
            let key = (p.lane, p.prompt.clone(), p.max_new);
            if let Some(tokens) = self.cache.get(&key) {
                let lane = &mut self.lanes[p.lane];
                let queue_micros = now.saturating_duration_since(p.enqueued).as_micros();
                lane.stats.cache_hits += 1;
                lane.stats.served += 1;
                lane.stats.total_queue_micros += queue_micros;
                p.reply.ok(EngineResponse {
                    model: lane.name.clone(),
                    prompt_len: p.prompt.len(),
                    tokens,
                    queue_micros,
                    gen_micros: 0,
                    batch_size: 0,
                    cached: true,
                });
                return;
            }
            // the miss is counted at generation time (run_batch), so a
            // request that is later cancelled or expires doesn't skew the
            // hit rate of answered traffic
        }
        let lane = &mut self.lanes[p.lane];
        let window = lane.tuning.batch_window;
        let key = sort_key(&p, window);
        let pos = lane.queue.partition_point(|q| sort_key(q, window) <= key);
        lane.queue.insert(pos, p);
    }

    /// Drop cancelled requests and answer expired deadlines with an error —
    /// a cancelled ticket never consumes a batch slot, and a deadline miss
    /// is reported, not silently dropped.
    fn sweep(&mut self) {
        let now = Instant::now();
        for lane in &mut self.lanes {
            // cancellations/expiries are rare: don't rebuild the queue on
            // every scheduler iteration unless one actually exists
            let dirty = lane.queue.iter().any(|p| {
                p.cancel.load(Ordering::Relaxed)
                    || matches!(p.deadline, Some(d) if now > d)
            });
            if !dirty {
                continue;
            }
            let queue = std::mem::take(&mut lane.queue);
            for p in queue {
                if p.cancel.load(Ordering::Relaxed) {
                    lane.stats.cancelled += 1;
                    continue;
                }
                if let Some(d) = p.deadline {
                    if now > d {
                        lane.stats.deadline_missed += 1;
                        p.reply.err(Error::Serve(format!(
                            "deadline exceeded after {:?} in `{}` queue",
                            now.saturating_duration_since(p.enqueued),
                            lane.name
                        )));
                        continue;
                    }
                }
                lane.queue.push(p);
            }
        }
    }

    /// Next lane with a dispatchable queue, fair-share round-robin.
    fn next_ready_lane(&mut self) -> Option<usize> {
        let now = Instant::now();
        let n = self.lanes.len();
        for off in 0..n {
            let li = (self.rr + off) % n;
            let lane = &self.lanes[li];
            if lane.queue.is_empty() {
                continue;
            }
            let oldest = lane.queue.iter().map(|p| p.enqueued).min().unwrap();
            let window_due = oldest.checked_add(lane.tuning.batch_window);
            // a queued deadline pulls the lane's due instant forward to
            // that request's dispatch-due point (half its budget), so a
            // deadline shorter than the batch window is served in time
            // without collapsing SLO traffic to batch-of-1
            let earliest_due = lane.queue.iter().filter_map(dispatch_due).min();
            let due = match (window_due, earliest_due) {
                (Some(w), Some(u)) => Some(w.min(u)),
                (w, u) => w.or(u),
            };
            let ready = self.draining
                || lane.queue.len() >= lane.tuning.max_batch
                || matches!(due, Some(t) if now >= t);
            if ready {
                self.rr = (li + 1) % n;
                return Some(li);
            }
        }
        None
    }

    /// How long the scheduler may sleep before a window closes or a
    /// deadline expires; `None` when every queue is empty.
    fn next_wakeup(&self) -> Option<Duration> {
        let now = Instant::now();
        let mut earliest: Option<Instant> = None;
        for lane in &self.lanes {
            if lane.queue.is_empty() {
                continue;
            }
            let oldest = lane.queue.iter().map(|p| p.enqueued).min().unwrap();
            let window_due = oldest.checked_add(lane.tuning.batch_window);
            // wake for dispatch-due instants (so deadline'd requests ride
            // out in time) and for raw deadlines (so a blocked queue still
            // answers expiries promptly)
            for t in window_due
                .into_iter()
                .chain(lane.queue.iter().filter_map(dispatch_due))
                .chain(lane.queue.iter().filter_map(|p| p.deadline))
            {
                let sooner = match earliest {
                    Some(e) => t < e,
                    None => true,
                };
                if sooner {
                    earliest = Some(t);
                }
            }
        }
        earliest.map(|t| t.saturating_duration_since(now))
    }

    /// Dispatch one batch group from a lane: up to `max_batch` front-of-
    /// queue requests sharing the head's sample config (`generate` takes a
    /// single [`SampleConfig`] per batch), chunked to the model's largest
    /// exported bucket.
    fn dispatch(&mut self, li: usize) {
        let (group, chunk_cap) = {
            let lane = &mut self.lanes[li];
            let cap = lane.tuning.max_batch;
            // the head always rides — guaranteed progress even for sample
            // configs that don't equal themselves (NaN temperature); the
            // rest of the group must share its config
            let head = lane.queue.remove(0);
            let head_sample = head.sample;
            let mut group = vec![head];
            let mut i = 0;
            while i < lane.queue.len() && group.len() < cap {
                if lane.queue[i].sample == head_sample {
                    group.push(lane.queue.remove(i));
                } else {
                    i += 1;
                }
            }
            (group, lane.model.max_batch().unwrap_or(usize::MAX).max(1))
        };
        let t_drain = Instant::now();
        let mut rest = group;
        while !rest.is_empty() {
            let tail = if rest.len() > chunk_cap {
                rest.split_off(chunk_cap)
            } else {
                Vec::new()
            };
            let batch = std::mem::replace(&mut rest, tail);
            self.run_batch(li, batch, t_drain);
        }
    }

    /// Generate one chunk and answer its riders.  A generation failure is
    /// answered per-rider and recorded; the scheduler keeps serving.
    fn run_batch(&mut self, li: usize, batch: Vec<Pending>, t_drain: Instant) {
        // deadlines and cancellations are re-checked per chunk: a rider of
        // a late chunk may have expired while earlier chunks of the same
        // dispatch group were generating, and must get the deadline error,
        // not a late Ok
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        {
            let lane = &mut self.lanes[li];
            for p in batch {
                if p.cancel.load(Ordering::Relaxed) {
                    lane.stats.cancelled += 1;
                    continue;
                }
                if matches!(p.deadline, Some(d) if now > d) {
                    lane.stats.deadline_missed += 1;
                    p.reply.err(Error::Serve(format!(
                        "deadline exceeded before generation on model `{}` (queued {:?})",
                        lane.name,
                        now.saturating_duration_since(p.enqueued)
                    )));
                    continue;
                }
                live.push(p);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = live;
        let lane = &mut self.lanes[li];
        let seq = lane.model.config().seq;
        let sample = batch[0].sample;
        let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
        let target = batch
            .iter()
            .map(|r| (r.prompt.len() + r.max_new).min(seq))
            .max()
            .unwrap();
        let bs = batch.len();
        let t0 = Instant::now();
        match generate(lane.model, &prompts, target, &sample) {
            Ok(outs) => {
                let gen_micros = t0.elapsed().as_micros();
                lane.stats.batches += 1;
                lane.stats.total_gen_micros += gen_micros;
                lane.stats.max_batch_seen = lane.stats.max_batch_seen.max(bs);
                for (req, tokens) in batch.into_iter().zip(outs) {
                    let want = (req.prompt.len() + req.max_new).min(seq);
                    let queue_micros =
                        t_drain.saturating_duration_since(req.enqueued).as_micros();
                    let toks = tokens[..want].to_vec();
                    if self.cache.enabled() && req.sample.temperature == 0.0 {
                        lane.stats.cache_misses += 1;
                        self.cache.insert((li, req.prompt.clone(), req.max_new), toks.clone());
                    }
                    lane.stats.served += 1;
                    lane.stats.total_queue_micros += queue_micros;
                    req.reply.ok(EngineResponse {
                        model: lane.name.clone(),
                        prompt_len: req.prompt.len(),
                        tokens: toks,
                        queue_micros,
                        gen_micros,
                        batch_size: bs,
                        cached: false,
                    });
                }
            }
            Err(e) => {
                let msg = format!("generation failed on model `{}`: {e}", lane.name);
                if lane.stats.first_error.is_none() {
                    lane.stats.first_error = Some(msg.clone());
                }
                for req in batch {
                    lane.stats.failed += 1;
                    req.reply.err(Error::Serve(msg.clone()));
                }
            }
        }
    }

    fn finish(self) -> EngineStats {
        let mut stats = EngineStats::default();
        for lane in self.lanes {
            stats.models.insert(lane.name, lane.stats);
        }
        stats
    }
}

//! LRU response cache for deterministic greedy decoding.
//!
//! Keyed on (lane, prompt, max_new): greedy decoding (`temperature == 0.0`)
//! is a pure function of the prompt and the model, so a repeat prompt can be
//! answered without riding a batch.  Sampled requests are never cached —
//! `eval::generate` draws from one RNG shared across batch rows, so sampled
//! output depends on batch composition and is not replayable.
//!
//! Capacity 0 disables the cache.  Eviction scans for the least-recently
//! used entry on insert — O(capacity), which is fine at the few-hundred
//! entry capacities the engine runs with.

use std::collections::HashMap;

/// (lane index, prompt tokens, max_new) — the full identity of a greedy
/// generation.  The lane index stands in for the model name: it is stable
/// for the lifetime of the scheduler that owns the cache.
pub(crate) type CacheKey = (usize, Vec<i32>, usize);

pub(crate) struct ResponseCache {
    cap: usize,
    tick: u64,
    map: HashMap<CacheKey, (Vec<i32>, u64)>,
}

impl ResponseCache {
    pub(crate) fn new(cap: usize) -> Self {
        ResponseCache { cap, tick: 0, map: HashMap::new() }
    }

    pub(crate) fn enabled(&self) -> bool {
        self.cap > 0
    }

    pub(crate) fn len(&self) -> usize {
        self.map.len()
    }

    /// Look up a cached response, refreshing its recency on hit.
    pub(crate) fn get(&mut self, key: &CacheKey) -> Option<Vec<i32>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|(tokens, used)| {
            *used = tick;
            tokens.clone()
        })
    }

    /// Insert a response, evicting the least-recently-used entry when full.
    pub(crate) fn insert(&mut self, key: CacheKey, tokens: Vec<i32>) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (tokens, self.tick));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(prompt: i32) -> CacheKey {
        (0, vec![prompt], 4)
    }

    #[test]
    fn hit_returns_inserted_tokens() {
        let mut c = ResponseCache::new(4);
        assert!(c.enabled());
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), vec![1, 2, 3]);
        assert_eq!(c.get(&key(1)), Some(vec![1, 2, 3]));
        // distinct max_new is a distinct entry
        assert!(c.get(&(0, vec![1], 8)).is_none());
        // distinct lane is a distinct entry
        assert!(c.get(&(1, vec![1], 4)).is_none());
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = ResponseCache::new(2);
        c.insert(key(1), vec![1]);
        c.insert(key(2), vec![2]);
        // touch 1 so 2 becomes the LRU entry
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), vec![3]);
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn reinsert_updates_in_place_without_eviction() {
        let mut c = ResponseCache::new(2);
        c.insert(key(1), vec![1]);
        c.insert(key(2), vec![2]);
        c.insert(key(1), vec![9]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key(1)), Some(vec![9]));
        assert!(c.get(&key(2)).is_some(), "re-insert must not evict");
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = ResponseCache::new(0);
        assert!(!c.enabled());
        c.insert(key(1), vec![1]);
        assert_eq!(c.len(), 0);
        assert!(c.get(&key(1)).is_none());
    }
}

//! Multi-model serving engine — the deployment story of the paper as a
//! first-class API.
//!
//! One [`Engine`] hosts any number of named quantized (or float) models —
//! e.g. a `w2` fleet with a `w4` fallback, the natural companion to the
//! mixed-precision planner — behind a single deadline-aware
//! **continuous-batching** scheduler with per-request cancellation,
//! graceful shutdown, executable warm-up, and an LRU response cache for
//! deterministic greedy decoding.
//!
//! # Continuous batching
//!
//! Requests occupy per-lane *slots* as [`crate::eval::DecodeSession`]s:
//! the scheduler batch-prefills each admission round into free slots,
//! advances all live sessions of a lane by one token per turn
//! (`decode_step`), and retires each session the moment it reaches its
//! target — so a short request never waits for a long batch-mate, and a
//! newly arrived request joins the running batch between steps instead of
//! waiting for the next dispatch window.  An admission round larger than
//! the model's batch bucket is split into bucket-sized prefill chunks
//! whose execution *interleaves* with the lane's decode turns, so a large
//! backlog never stalls running sessions.  On models whose artifacts
//! carry the manifest's `decode` record the step is O(1) over
//! arena-resident KV caches; on anything else it falls back to
//! full-context recompute (same tokens, just O(S) per step).  Each
//! request samples from its own seeded stream, so any mix of
//! [`SampleConfig`]s shares a batch and results never depend on batch
//! composition.  [`EngineStats`] splits prefill vs decode token counts
//! and wall time (`prefill_tokens` / `decode_tokens`).
//!
//! # Slot lifecycle (the KV arena)
//!
//! Models backed by AOT decode graphs own a
//! [`crate::eval::KvArena`]: per layer, one `(K, V)` tensor pair of shape
//! `[slots, H, S, Dh]`, allocated once when the runner is built (`slots`
//! = the manifest's `decode.slots`).  A request's cache lives in one
//! arena row for its whole life:
//!
//! ```text
//!   admit     try_reserve(n) hands the prefill n free slot indices
//!   prefill   one batched block_fwd*_kv pass; each newcomer's K/V rows
//!             are written into its slot (the only copy it ever pays)
//!   decode    every step runs at the fixed `slots` bucket with the arena
//!             tensors carried through the step graph in place — zero
//!             per-step stacking, scattering, or row copies
//!   retire    dropping the session drops its ArenaSlot, which frees the
//!             slot for the next admission round
//! ```
//!
//! Admission rounds that find the arena full (or degraded by a failed
//! step graph) still succeed: those sessions carry
//! [`crate::eval::KvCache::Recompute`] and ride the full-context fallback
//! until they retire.  The scheduler surfaces arena pressure as the
//! `arena.occupancy` gauge and per-turn occupancy histogram in
//! [`ModelStats`].
//!
//! # Lifecycle
//!
//! ```text
//!   Engine::builder()                       EngineBuilder
//!     .model("w4", factory)                   register named models
//!     .model_with("w2", tuning, factory)      (per-model batching tuning)
//!     .cache(256)                             greedy response cache
//!     .build()?                             Engine        (validated)
//!          │
//!          ▼ start()                        spawns the scheduler thread:
//!          │                                  factories build the models,
//!          │                                  warm-up primes every exported
//!          │                                  batch bucket, then serving
//!          │                                  begins; returns a Client
//!          ▼
//!   Client::submit(model, GenRequest)      Ticket  (wait / try_wait /
//!     · cloneable across threads                    cancel-on-drop)
//!     · per-request deadline
//!          │
//!          ▼ shutdown()                    drains the queues gracefully,
//!                                          returns per-model EngineStats
//! ```
//!
//! # Threading model
//!
//! The XLA-backed runners ([`crate::coordinator::QuantModel`] /
//! [`crate::coordinator::FloatModel`]) borrow a PJRT client and are not
//! `Send`, so models can never migrate between threads.  The engine
//! therefore registers model *factories* (`FnOnce() -> Result<Box<dyn
//! LanguageModel>> + Send`): `start()` runs every factory **inside** the
//! scheduler thread, which then owns its models for the engine's lifetime.
//! [`ServableModel`] is the ready-made factory payload for serving a saved
//! quantized checkpoint.  Mock models in tests are ordinary owned values.
//!
//! Requests may be submitted from any number of threads via cloned
//! [`Client`]s; a [`Ticket`] supports blocking wait, polling, and
//! cancellation (dropping a ticket cancels a not-yet-scheduled request).
//! [`Client`]s obtained before `start()` buffer their submissions until the
//! scheduler comes up.
//!
//! # Observability
//!
//! Three layers, all rooted in [`crate::obs`]:
//!
//! * **Shutdown stats** — [`EngineStats`] / [`ModelStats`] counters plus
//!   per-phase latency histograms (`queue_us`, `prefill_us`,
//!   `decode_step_us`, `e2e_us`): engine-measured p50/p90/p99 per lane,
//!   which `bench_serve` reports instead of client-side timings.
//! * **Live gauges** — [`Client::stats_snapshot`] polls per-lane queue
//!   depth, slot occupancy, and served count ([`LaneSnapshot`]) at any
//!   moment, without pausing or shutting the engine down.
//! * **Traces** — [`EngineBuilder::trace`] attaches a
//!   [`crate::obs::TraceCollector`]; the scheduler then emits the request
//!   lifecycle (`submit` → `admit` → prefill span → per-step decode spans
//!   → `retire`, plus an async span per request keyed by its admission
//!   seq) onto a `scheduler` track and per-lane `lane:<name>/prefill` /
//!   `lane:<name>/decode` tracks.  Export with
//!   [`crate::obs::TraceCollector::write_chrome`] and load the file in
//!   `chrome://tracing` or Perfetto (`normtweak serve --trace out.json`).
//!
//! Progress narration goes through the leveled logger (`NORMTWEAK_LOG`,
//! see [`crate::obs::log`]); the engine itself never prints.
//!
//! # Migration from `serve::serve_loop`
//!
//! The old free-function loop survives as a deprecated single-model shim on
//! top of this scheduler; see `serve/mod.rs` for the migration note.

pub(crate) mod cache;
pub(crate) mod scheduler;
mod stats;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::eval::LanguageModel;
use crate::model::{ModelConfig, QuantizedModel};
use crate::obs::trace::TraceCollector;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub use crate::eval::generate::SampleConfig;
pub use stats::{EngineStats, LaneSnapshot, ModelStats};

use scheduler::{Lane, Msg, Pending, ReplyTo, Scheduler};
use stats::LaneGauges;

/// Per-model batching knobs (the engine-side analog of
/// [`crate::serve::ServeConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct ModelTuning {
    /// number of continuous-batching slots (live sessions) the lane may
    /// hold; graph calls are additionally chunked to the model's
    /// [`LanguageModel::max_batch`] bucket
    pub max_batch: usize,
    /// how long the oldest rider may wait for stragglers before an *idle*
    /// lane dispatches; a streaming lane admits newcomers immediately
    /// between decode steps
    pub batch_window: Duration,
}

impl Default for ModelTuning {
    fn default() -> Self {
        ModelTuning { max_batch: 8, batch_window: Duration::from_millis(2) }
    }
}

impl ModelTuning {
    /// Reject degenerate tunings at build time instead of silently serving
    /// one-request batches.  Lint-backed: the checks (and message text)
    /// live in `crate::analysis::serve_rules::tuning_diags` (NT0401 /
    /// NT0402), shared with `normtweak check`; the first finding aborts.
    pub fn validate(&self, name: &str) -> Result<()> {
        match crate::analysis::serve_rules::tuning_diags(name, self.max_batch, self.batch_window)
            .into_iter()
            .next()
        {
            None => Ok(()),
            Some(d) => Err(Error::Config(d.message)),
        }
    }
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sample: SampleConfig,
    /// answer-by budget measured from submit; expiry is answered with
    /// [`Error::Serve`], never silently dropped
    pub deadline: Option<Duration>,
}

impl GenRequest {
    /// Deterministic greedy request — the only kind the response cache
    /// may answer.
    pub fn greedy(prompt: Vec<i32>, max_new: usize) -> Self {
        GenRequest {
            prompt,
            max_new,
            sample: SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 },
            deadline: None,
        }
    }

    pub fn with_sample(mut self, sample: SampleConfig) -> Self {
        self.sample = sample;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// The engine's answer to one request.
#[derive(Debug, Clone)]
pub struct EngineResponse {
    /// registered name of the model that served this request
    pub model: String,
    /// prompt + generated tokens (prompt prefix included, as generated)
    pub tokens: Vec<i32>,
    /// length of the prompt prefix inside `tokens`
    pub prompt_len: usize,
    /// submit-to-dispatch wait
    pub queue_micros: u128,
    /// summed wall time of every prefill/decode call this request rode
    /// (0 for cache hits)
    pub gen_micros: u128,
    /// largest batch this request shared — prefill chunk or decode step
    /// (0 for cache hits)
    pub batch_size: usize,
    /// answered from the greedy response cache
    pub cached: bool,
}

impl EngineResponse {
    /// Only the newly generated tokens (everything after the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len.min(self.tokens.len())..]
    }
}

/// A pending request: wait, poll, or cancel (dropping cancels a
/// not-yet-scheduled request — it will never consume a batch slot).
pub struct Ticket {
    rx: mpsc::Receiver<Result<EngineResponse>>,
    cancel: Arc<AtomicBool>,
    done: bool,
}

impl Ticket {
    /// Block until the engine answers.
    pub fn wait(self) -> Result<EngineResponse> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Serve("engine stopped before answering".into())),
        }
    }

    /// Non-blocking poll: `None` while pending (and after a result has
    /// already been delivered), `Some(result)` exactly once.
    pub fn try_wait(&mut self) -> Option<Result<EngineResponse>> {
        if self.done {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = true;
                Some(r)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(Error::Serve("engine stopped before answering".into())))
            }
        }
    }

    /// Explicit cancellation (equivalent to dropping the ticket).
    pub fn cancel(self) {}
}

impl Drop for Ticket {
    fn drop(&mut self) {
        // flag checked by the scheduler before every dispatch; harmless
        // after the request was answered
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// Cloneable submission handle (channels only — freely `Send`).
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
    names: Arc<Vec<String>>,
    gauges: Arc<Vec<Arc<LaneGauges>>>,
}

impl Client {
    /// Submit a request to a registered model; returns immediately with a
    /// [`Ticket`].
    pub fn submit(&self, model: &str, req: GenRequest) -> Result<Ticket> {
        let lane = self.names.iter().position(|n| n == model).ok_or_else(|| {
            Error::Serve(format!(
                "unknown model `{model}`; registered: {}",
                self.names.join(", ")
            ))
        })?;
        if req.prompt.is_empty() {
            return Err(Error::Serve("empty prompt".into()));
        }
        let enqueued = Instant::now();
        let (reply, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let pending = Pending {
            lane,
            prompt: req.prompt,
            max_new: req.max_new,
            sample: req.sample,
            enqueued,
            // a deadline too large to represent simply never expires
            deadline: req.deadline.and_then(|d| enqueued.checked_add(d)),
            reply: ReplyTo::Engine(reply),
            cancel: cancel.clone(),
            seq: 0,
        };
        self.tx
            .send(Msg::Submit(pending))
            .map_err(|_| Error::Serve("engine stopped".into()))?;
        Ok(Ticket { rx, cancel, done: false })
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, model: &str, req: GenRequest) -> Result<EngineResponse> {
        self.submit(model, req)?.wait()
    }

    /// Names of the registered models, in registration order.
    pub fn models(&self) -> &[String] {
        &self.names
    }

    /// Live per-lane stats — queue depth, slot occupancy, served count —
    /// readable at any moment without pausing or shutting the engine down
    /// (one [`LaneSnapshot`] per registered model, in registration order).
    ///
    /// The scheduler publishes after each work cycle with relaxed atomics,
    /// so the snapshot is loosely consistent: each field is a real recent
    /// value, but the set may straddle a cycle.  All-zero until `start()`.
    pub fn stats_snapshot(&self) -> Vec<LaneSnapshot> {
        self.gauges.iter().map(|g| g.snapshot()).collect()
    }
}

/// A model factory: runs inside the scheduler thread at `start()`, so the
/// produced model never has to be `Send`.
pub type ModelFactory = Box<dyn FnOnce() -> Result<Box<dyn LanguageModel>> + Send>;

/// Builder for [`Engine`]: register models, tune batching, size the cache.
pub struct EngineBuilder {
    models: Vec<(String, ModelTuning, ModelFactory)>,
    cache: usize,
    warmup: bool,
    trace: Option<Arc<TraceCollector>>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder { models: Vec::new(), cache: 0, warmup: true, trace: None }
    }
}

impl EngineBuilder {
    /// Register a named model with default tuning.
    pub fn model<F>(self, name: impl Into<String>, factory: F) -> Self
    where
        F: FnOnce() -> Result<Box<dyn LanguageModel>> + Send + 'static,
    {
        self.model_with(name, ModelTuning::default(), factory)
    }

    /// Register a named model with explicit batching tuning.
    pub fn model_with<F>(mut self, name: impl Into<String>, tuning: ModelTuning, factory: F) -> Self
    where
        F: FnOnce() -> Result<Box<dyn LanguageModel>> + Send + 'static,
    {
        self.models.push((name.into(), tuning, Box::new(factory)));
        self
    }

    /// Capacity of the greedy response cache (entries); 0 disables it.
    pub fn cache(mut self, capacity: usize) -> Self {
        self.cache = capacity;
        self
    }

    /// Toggle executable warm-up at `start()` (on by default; tests with
    /// call-counting mocks turn it off).
    pub fn warmup(mut self, warmup: bool) -> Self {
        self.warmup = warmup;
        self
    }

    /// Attach a trace collector: the scheduler records the request
    /// lifecycle (submit/admit/prefill/decode/retire spans, one track per
    /// lane) into it while serving.  Share the same `Arc` with
    /// [`ServableModel::with_trace`] to land per-graph XLA spans on the
    /// same timeline, and export it after shutdown with
    /// [`TraceCollector::write_chrome`].
    pub fn trace(mut self, trace: Arc<TraceCollector>) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Validate and assemble the engine.
    pub fn build(self) -> Result<Engine> {
        if self.models.is_empty() {
            return Err(Error::Config("engine needs at least one registered model".into()));
        }
        let mut seen = std::collections::BTreeSet::new();
        for (name, tuning, _) in &self.models {
            if !seen.insert(name.clone()) {
                return Err(Error::Config(format!(
                    "model `{name}` registered twice; engine keys must be unique"
                )));
            }
            tuning.validate(name)?;
        }
        let names = Arc::new(self.models.iter().map(|(n, _, _)| n.clone()).collect::<Vec<_>>());
        let gauges: Arc<Vec<Arc<LaneGauges>>> = Arc::new(
            self.models
                .iter()
                .map(|(n, t, _)| Arc::new(LaneGauges::new(n.clone(), t.max_batch)))
                .collect(),
        );
        let (tx, rx) = mpsc::channel();
        Ok(Engine {
            tx,
            names,
            gauges: gauges.clone(),
            boot: Some(Boot {
                rx,
                models: self.models,
                cache: self.cache,
                warmup: self.warmup,
                trace: self.trace,
                gauges,
            }),
            handle: None,
        })
    }
}

/// Deferred scheduler-thread state, consumed by `start()`.
struct Boot {
    rx: mpsc::Receiver<Msg>,
    models: Vec<(String, ModelTuning, ModelFactory)>,
    cache: usize,
    warmup: bool,
    trace: Option<Arc<TraceCollector>>,
    gauges: Arc<Vec<Arc<LaneGauges>>>,
}

/// An owned multi-model serving engine.  See the module docs for the
/// lifecycle diagram.
pub struct Engine {
    tx: mpsc::Sender<Msg>,
    names: Arc<Vec<String>>,
    gauges: Arc<Vec<Arc<LaneGauges>>>,
    boot: Option<Boot>,
    handle: Option<std::thread::JoinHandle<EngineStats>>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// A submission handle.  Valid before `start()` too — submissions
    /// buffer until the scheduler comes up (warm-up always precedes them).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone(), names: self.names.clone(), gauges: self.gauges.clone() }
    }

    /// Spawn the scheduler thread: build every registered model from its
    /// factory, run warm-up, then begin serving.  Blocks until the engine
    /// is ready (or a factory/warm-up failed) and returns a [`Client`].
    pub fn start(&mut self) -> Result<Client> {
        let boot = self
            .boot
            .take()
            .ok_or_else(|| Error::Serve("engine already started".into()))?;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("nt-engine".into())
            .spawn(move || {
                let Boot { rx, models, cache, warmup, trace, gauges } = boot;
                let mut built: Vec<(String, ModelTuning, Box<dyn LanguageModel>)> = Vec::new();
                for (name, tuning, factory) in models {
                    match factory() {
                        Ok(m) => built.push((name, tuning, m)),
                        Err(e) => {
                            let _ = ready_tx.send(Err(Error::Serve(format!(
                                "building model `{name}` failed: {e}"
                            ))));
                            return EngineStats::default();
                        }
                    }
                }
                let lanes: Vec<Lane> = built
                    .iter()
                    .map(|(n, t, m)| Lane::new(n.clone(), m.as_ref(), *t))
                    .collect();
                let mut sched = Scheduler::new(lanes, rx, cache);
                // gauges + trace attach before warm-up so warm-up batches
                // are traced and the client's snapshot handles are the
                // cells the scheduler actually writes
                sched.set_gauges(gauges.iter().cloned().collect());
                if let Some(tr) = trace {
                    sched.set_trace(tr);
                }
                if warmup {
                    if let Err(e) = sched.warm_up() {
                        let _ = ready_tx.send(Err(e));
                        return EngineStats::default();
                    }
                }
                let _ = ready_tx.send(Ok(()));
                sched.run()
            })
            .map_err(Error::Io)?;
        self.handle = Some(handle);
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(self.client()),
            Ok(Err(e)) => {
                if let Some(h) = self.handle.take() {
                    let _ = h.join();
                }
                Err(e)
            }
            Err(_) => {
                if let Some(h) = self.handle.take() {
                    let _ = h.join();
                }
                Err(Error::Serve("engine thread died during startup".into()))
            }
        }
    }

    /// Graceful shutdown: serve everything already queued, then stop and
    /// return the per-model statistics.  Outstanding [`Client`]s keep
    /// working until the drain finishes; their later submits fail cleanly.
    pub fn shutdown(mut self) -> Result<EngineStats> {
        let handle = self.handle.take().ok_or_else(|| {
            Error::Serve("engine was never started (call start() before shutdown())".into())
        })?;
        let _ = self.tx.send(Msg::Shutdown);
        handle
            .join()
            .map_err(|_| Error::Serve("engine thread panicked".into()))
    }
}

/// An owned, self-contained runner for a saved quantized checkpoint —
/// the ready-made [`ModelFactory`] payload.
///
/// Owns its own [`Runtime`] (PJRT client + executable cache) plus the
/// checkpoint, so a `Send` factory can capture plain strings and build the
/// whole stack inside the engine thread.  Each `ServableModel` carries its
/// own PJRT client; at demo scale that is fine, and models sharing one
/// engine share one scheduler thread regardless.
pub struct ServableModel {
    runtime: Runtime,
    model: QuantizedModel,
    act_bits: Option<u8>,
    /// One arena for the model's lifetime, shared by every runner view —
    /// slot reservations made through one `runner()` call survive into
    /// the next (sessions hold `ArenaSlot` handles into this object).
    arena: Option<crate::eval::SharedKvArena>,
}

impl ServableModel {
    /// Load `checkpoint` for built-in architecture `model_name`, compiling
    /// against the AOT artifacts in `artifacts`.
    pub fn load(
        artifacts: impl AsRef<std::path::Path>,
        model_name: &str,
        checkpoint: impl AsRef<std::path::Path>,
    ) -> Result<Self> {
        let runtime = Runtime::new(artifacts)?;
        let mcfg = ModelConfig::builtin(model_name)?;
        let model = QuantizedModel::load(mcfg, checkpoint)?;
        // surface artifact/grain/decode mismatches now, not inside the
        // first batch
        runtime.manifest.verify_model(&model.config)?;
        runtime.validate_grain(&model.scheme.group_tag())?;
        runtime.manifest.verify_decode(&model.config)?;
        let arena = crate::coordinator::arena_for(&runtime, &model.config.name);
        Ok(ServableModel { runtime, model, act_bits: None, arena })
    }

    /// Serve with dynamic activation fake-quant (the W+A modes).
    pub fn with_act_bits(mut self, bits: Option<u8>) -> Self {
        self.act_bits = bits;
        self
    }

    /// Record per-graph XLA execution spans into `trace` (the `xla`
    /// track, one span per runtime call named by graph family).  Pass the
    /// same `Arc` given to [`EngineBuilder::trace`] to interleave graph
    /// timings with the scheduler lifecycle on one timeline.
    pub fn with_trace(mut self, trace: Arc<TraceCollector>) -> Self {
        self.runtime.set_trace(trace);
        self
    }

    fn runner(&self) -> crate::coordinator::QuantModel<'_, '_> {
        crate::coordinator::QuantModel {
            runtime: &self.runtime,
            model: &self.model,
            act_bits: self.act_bits,
            arena: self.arena.clone(),
        }
    }
}

impl LanguageModel for ServableModel {
    fn config(&self) -> &ModelConfig {
        &self.model.config
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        self.runner().logits(tokens)
    }

    fn max_batch(&self) -> Option<usize> {
        self.runtime.manifest.max_bucket()
    }

    fn warm_buckets(&self) -> Vec<usize> {
        self.runtime.manifest.buckets.clone()
    }

    fn supports_decode(&self) -> bool {
        self.runner().supports_decode()
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<crate::eval::DecodeSession>> {
        self.runner().prefill(prompts)
    }

    fn decode_step(&self, sessions: &mut [&mut crate::eval::DecodeSession]) -> Result<()> {
        self.runner().decode_step(sessions)
    }

    fn kv_arena(&self) -> Option<crate::eval::SharedKvArena> {
        self.arena.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuning_validation_rejects_degenerate() {
        let t = ModelTuning { max_batch: 0, ..Default::default() };
        let err = t.validate("w4").unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(format!("{err}").contains("max_batch"), "{err}");

        let t = ModelTuning { batch_window: Duration::ZERO, ..Default::default() };
        let err = t.validate("w4").unwrap_err();
        assert!(format!("{err}").contains("batch_window"), "{err}");

        ModelTuning::default().validate("w4").unwrap();
    }

    #[test]
    fn builder_rejects_empty_and_duplicates() {
        let err = Engine::builder().build().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");

        let err = Engine::builder()
            .model("a", || Err(Error::Serve("unused".into())))
            .model("a", || Err(Error::Serve("unused".into())))
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("registered twice"), "{err}");
    }

    #[test]
    fn builder_rejects_bad_tuning_at_build() {
        let err = Engine::builder()
            .model_with(
                "a",
                ModelTuning { max_batch: 0, ..Default::default() },
                || Err(Error::Serve("unused".into())),
            )
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn greedy_request_is_cacheable_shape() {
        let r = GenRequest::greedy(vec![1, 2], 4);
        assert_eq!(r.sample.temperature, 0.0);
        assert!(r.deadline.is_none());
        let r = r.with_deadline(Duration::from_millis(5));
        assert!(r.deadline.is_some());
    }

    #[test]
    fn response_new_tokens_slices_after_prompt() {
        let r = EngineResponse {
            model: "m".into(),
            tokens: vec![1, 2, 3, 4, 5],
            prompt_len: 2,
            queue_micros: 0,
            gen_micros: 0,
            batch_size: 1,
            cached: false,
        };
        assert_eq!(r.new_tokens(), &[3, 4, 5]);
        // degenerate prompt_len never panics
        let r = EngineResponse { prompt_len: 9, ..r };
        assert!(r.new_tokens().is_empty());
    }
}

//! Synthetic multilingual corpus — bit-for-bit mirror of
//! `python/compile/corpus.py` (cross-checked against goldens in
//! `rust/tests/corpus_crosscheck.rs`).

use super::rng::{mix64, SplitMix64, MIX_K};
use super::vocab::{Lang, BOS, EOS, LANGS, PERIOD, QUERY};
use crate::calib::vocab::BIND;

/// Deterministic grammar successor of `word` inside `lang`'s bucket.
pub fn successor(word: u32, lang: &Lang) -> u32 {
    let b = (lang.hi - lang.lo) as u64;
    lang.lo + (mix64((word as u64).wrapping_mul(MIX_K).wrapping_add(lang.salt)) % b) as u32
}

/// One grammar sentence: 4..11 words, 85% successor / 15% random, PERIOD.
pub fn sentence(rng: &mut SplitMix64, lang: &Lang) -> Vec<i32> {
    let b = (lang.hi - lang.lo) as u64;
    let n = 4 + rng.below(8);
    let mut w = lang.lo + rng.below(b) as u32;
    let mut out = vec![w as i32];
    for _ in 0..n - 1 {
        if rng.chance(85, 100) {
            w = successor(w, lang);
        } else {
            w = lang.lo + rng.below(b) as u32;
        }
        out.push(w as i32);
    }
    out.push(PERIOD);
    out
}

/// Binding-recall sequence (present in the corpus; see DESIGN.md §2 on why
/// the headline metric uses successor-cloze instead).
pub fn recall_sequence(rng: &mut SplitMix64, lang: &Lang) -> Vec<i32> {
    let n_bind = 2usize;
    let filler_sents = 1usize;
    let b = (lang.hi - lang.lo) as u64;
    let mut keys: Vec<u32> = Vec::new();
    let mut vals: Vec<u32> = Vec::new();
    while keys.len() < n_bind {
        let k = lang.lo + rng.below(b) as u32;
        if !keys.contains(&k) {
            keys.push(k);
            vals.push(lang.lo + rng.below(b) as u32);
        }
    }
    let mut out = vec![BOS];
    for (k, v) in keys.iter().zip(&vals) {
        out.extend([*k as i32, *v as i32, BIND]);
    }
    for _ in 0..filler_sents {
        out.extend(sentence(rng, lang));
    }
    let r = rng.below(n_bind as u64) as usize;
    out.extend([QUERY, keys[r] as i32, vals[r] as i32, EOS]);
    out
}

/// A corpus spec: language mix + document shape + recall share
/// (mirror of `corpus.MixSpec`).
#[derive(Debug, Clone)]
pub struct MixSpec {
    pub name: &'static str,
    pub seed: u64,
    pub weights: Option<Vec<f64>>,
    pub recall_permille: u64,
    pub doc_min: u64,
    pub doc_max: u64,
}

impl MixSpec {
    fn mix_weights(&self) -> Vec<f64> {
        match &self.weights {
            Some(w) => w.clone(),
            None => LANGS.iter().map(|l| l.corpus_share).collect(),
        }
    }
}

/// Weighted language choice via integer per-mille thresholds
/// (mirror of `corpus.pick_lang` — integer arithmetic keeps the two
/// implementations identical).
pub fn pick_lang<'a>(rng: &mut SplitMix64, weights: &[f64]) -> &'a Lang {
    let permille: Vec<u64> = weights.iter().map(|w| (w * 1000.0) as u64).collect();
    let total: u64 = permille.iter().sum();
    let r = rng.below(total);
    let mut acc = 0u64;
    for (lang, p) in LANGS.iter().zip(&permille) {
        acc += p;
        if r < acc {
            return lang;
        }
    }
    // per-mille rounding can leave `r == total`; the static language
    // table is never empty
    LANGS.last().expect("LANGS is a non-empty static table")
}

/// One document: BOS, sentences (or a recall block), EOS.
pub fn document(rng: &mut SplitMix64, lang: &Lang, spec: &MixSpec) -> Vec<i32> {
    if rng.below(1000) < spec.recall_permille {
        return recall_sequence(rng, lang);
    }
    let target = (spec.doc_min + rng.below(spec.doc_max - spec.doc_min)) as usize;
    let mut out = vec![BOS];
    while out.len() < target {
        out.extend(sentence(rng, lang));
    }
    out.push(EOS);
    out
}

/// Concatenate documents until at least `n_tokens`; truncate exactly.
pub fn token_stream(spec: &MixSpec, n_tokens: usize) -> Vec<i32> {
    let mut rng = SplitMix64::new(spec.seed);
    let weights = spec.mix_weights();
    let mut out: Vec<i32> = Vec::with_capacity(n_tokens + 512);
    while out.len() < n_tokens {
        let lang = pick_lang(&mut rng, &weights);
        out.extend(document(&mut rng, lang, spec));
    }
    out.truncate(n_tokens);
    out
}

/// Build a full weight vector from sparse (name, weight) pairs
/// (mirror of `corpus._w` — leftover spread evenly over the rest).
fn w(pairs: &[(&str, f64)]) -> Vec<f64> {
    let named: f64 = pairs.iter().map(|(_, v)| v).sum();
    let rest_count = LANGS.iter().filter(|l| !pairs.iter().any(|(n, _)| *n == l.name)).count();
    let per = if rest_count > 0 { (1.0 - named).max(0.0) / rest_count as f64 } else { 0.0 };
    LANGS
        .iter()
        .map(|l| {
            pairs
                .iter()
                .find(|(n, _)| *n == l.name)
                .map(|(_, v)| *v)
                .unwrap_or(per)
        })
        .collect()
}

/// The named corpora (mirrors of TRAIN_SPEC / WIKI_SYN / PTB_SYN / C4_SYN).
pub fn train_spec() -> MixSpec {
    MixSpec { name: "train", seed: 0xC0FFEE, weights: None,
              recall_permille: 150, doc_min: 64, doc_max: 256 }
}

pub fn wiki_syn() -> MixSpec {
    MixSpec { name: "wiki-syn", seed: 0x71C1,
              weights: Some(w(&[("en", 0.70), ("fr", 0.15)])),
              recall_permille: 150, doc_min: 96, doc_max: 256 }
}

pub fn ptb_syn() -> MixSpec {
    MixSpec { name: "ptb-syn", seed: 0x97B2,
              weights: Some(w(&[("en", 0.45), ("zhs", 0.30), ("es", 0.15)])),
              recall_permille: 100, doc_min: 48, doc_max: 128 }
}

pub fn c4_syn() -> MixSpec {
    MixSpec { name: "c4-syn", seed: 0xC4C4,
              weights: Some(w(&[("en", 0.25), ("zhs", 0.15), ("fr", 0.15),
                                ("es", 0.12), ("pt", 0.10)])),
              recall_permille: 250, doc_min: 64, doc_max: 224 }
}

/// Look up a named eval corpus spec.
pub fn spec_by_name(name: &str) -> Option<MixSpec> {
    match name {
        "train" => Some(train_spec()),
        "wiki-syn" => Some(wiki_syn()),
        "ptb-syn" => Some(ptb_syn()),
        "c4-syn" => Some(c4_syn()),
        _ => None,
    }
}

/// Successor-cloze items (the LAMBADA-syn set) — mirror of
/// `corpus.lambada_syn`. Returns (tokens [n, seq] row-major, answer_pos).
pub fn lambada_syn(seed: u64, n_items: usize, seq: usize) -> (Vec<i32>, Vec<usize>) {
    let mut rng = SplitMix64::new(seed);
    let mut items: Vec<i32> = Vec::with_capacity(n_items * seq);
    let mut pos = Vec::with_capacity(n_items);
    while pos.len() < n_items {
        let lang = &LANGS[rng.below(5) as usize];
        let mut sent = sentence(&mut rng, lang);
        sent.pop(); // drop PERIOD
        let mut seqt = vec![BOS];
        seqt.extend(sent);
        if seqt.len() > seq {
            continue;
        }
        let n = seqt.len();
        seqt[n - 1] = successor(seqt[n - 2] as u32, lang) as i32;
        pos.push(n - 1);
        items.extend(&seqt);
        items.extend(std::iter::repeat(0).take(seq - n));
    }
    (items, pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_deterministic() {
        let a = token_stream(&train_spec(), 1000);
        let b = token_stream(&train_spec(), 1000);
        assert_eq!(a, b);
        let c = token_stream(&wiki_syn(), 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn tokens_in_range() {
        for spec in [train_spec(), wiki_syn(), ptb_syn(), c4_syn()] {
            for &t in token_stream(&spec, 2000).iter() {
                assert!((0..2048).contains(&t), "token {t} out of range");
            }
        }
    }

    #[test]
    fn successor_stays_in_bucket() {
        let lang = &LANGS[0];
        for w_ in lang.lo..lang.lo + 20 {
            let s = successor(w_, lang);
            assert!(s >= lang.lo && s < lang.hi);
        }
    }

    #[test]
    fn wiki_is_en_heavy() {
        let toks = token_stream(&wiki_syn(), 20_000);
        let en = toks
            .iter()
            .filter(|&&t| (8..168).contains(&t))
            .count() as f64;
        let content = toks.iter().filter(|&&t| t >= 8).count() as f64;
        assert!(en / content > 0.5, "en share {}", en / content);
    }

    #[test]
    fn lambada_syn_answers_are_successors() {
        let (items, pos) = lambada_syn(7, 16, 128);
        for (i, &p) in pos.iter().enumerate() {
            let row = &items[i * 128..(i + 1) * 128];
            let prev = row[p - 1] as u32;
            let ans = row[p] as u32;
            let lang = crate::calib::vocab::lang_of_token(prev as i32).unwrap();
            assert_eq!(ans, successor(prev, lang));
        }
    }

    #[test]
    fn sentence_shape() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let s = sentence(&mut rng, &LANGS[2]);
            assert!(s.len() >= 5 && s.len() <= 12);
            assert_eq!(*s.last().unwrap(), PERIOD);
        }
    }
}

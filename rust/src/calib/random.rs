//! Random-token calibration baseline (Table 8's "Random" row).
//!
//! The paper samples Gaussian data matching the real data's mean/variance;
//! for a token-level pipeline the analog is tokens drawn from the corpus's
//! *unigram marginal* without any sequential structure — same first-order
//! statistics, zero semantics.

use crate::calib::corpus::{pick_lang, MixSpec};
use crate::calib::rng::SplitMix64;
use crate::tensor::Tensor;

use super::CalibSet;

/// Build a structureless calibration set: each token drawn independently
/// from the language-weighted unigram distribution of `spec`.
pub fn random_calib(spec: &MixSpec, n: usize, seq: usize, seed: u64) -> CalibSet {
    let mut rng = SplitMix64::new(seed);
    let weights: Vec<f64> = match &spec.weights {
        Some(w) => w.clone(),
        None => crate::calib::vocab::LANGS.iter().map(|l| l.corpus_share).collect(),
    };
    let mut flat = Vec::with_capacity(n * seq);
    for _ in 0..n * seq {
        let lang = pick_lang(&mut rng, &weights);
        flat.push((lang.lo + rng.below((lang.hi - lang.lo) as u64) as u32) as i32);
    }
    CalibSet {
        tokens: Tensor::i32(&[n, seq], flat),
        source: "random".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::corpus::train_spec;

    #[test]
    fn shape_and_range() {
        let c = random_calib(&train_spec(), 4, 32, 1);
        assert_eq!(c.tokens.shape, vec![4, 32]);
        assert!(c.tokens.as_i32().unwrap().iter().all(|&t| (8..2048).contains(&t)));
        assert_eq!(c.source, "random");
    }

    #[test]
    fn no_sequential_structure() {
        // successor-rate of random tokens must be near zero
        let c = random_calib(&train_spec(), 1, 512, 2);
        let r = crate::eval::subjective::grammar_report(c.tokens.as_i32().unwrap());
        assert!(r.successor_rate < 0.05);
    }
}

//! Calibration data: the synthetic multilingual corpus (bit-for-bit mirror
//! of the Python generator), the paper's self-generation scheme (GenData
//! V1/V2 with the language-scope restriction), and the random-Gaussian
//! baseline of Table 8.

pub mod corpus;
pub mod gen;
pub mod random;
pub mod rng;
pub mod vocab;

use crate::error::Result;
use crate::tensor::Tensor;

/// A calibration set: `n` token sequences of fixed length.
#[derive(Debug, Clone)]
pub struct CalibSet {
    /// i32 [n, seq]
    pub tokens: Tensor,
    /// provenance tag used in reports ("gen-v2", "wiki-syn", ...)
    pub source: String,
}

impl CalibSet {
    pub fn n_samples(&self) -> usize {
        self.tokens.shape[0]
    }

    pub fn seq(&self) -> usize {
        self.tokens.shape[1]
    }

    /// Build from a flat token stream, chunked into consecutive windows —
    /// how the paper samples calibration text from a real dataset.
    pub fn from_stream(stream: &[i32], n: usize, seq: usize, source: &str) -> Result<Self> {
        let need = n * seq;
        if stream.len() < need {
            return Err(crate::error::Error::msg(format!(
                "stream too short: {} < {need}",
                stream.len()
            )));
        }
        let tokens = Tensor::i32(&[n, seq], stream[..need].to_vec());
        Ok(CalibSet { tokens, source: source.to_string() })
    }
}

//! GenData: the paper's self-generated calibration scheme (LLM-QAT two-stage
//! generation), with the V2 language-scope restriction on the first token.
//!
//! * **V1** — first token uniform over the whole content vocabulary (the
//!   official LLM-QAT recipe).
//! * **V2** — first token restricted to the top-language buckets, weighted
//!   by corpus share (the paper's improvement, motivated by the Table-1
//!   corpus-vs-vocab mismatch: uniform vocab sampling lands in the
//!   low-resource tail ~76% of the time).

use crate::calib::rng::SplitMix64;
use crate::calib::vocab::{BOS, LANGS, N_SPECIAL, N_TOP_LANGS, VOCAB_SIZE};
use crate::error::Result;
use crate::eval::generate::{generate, SampleConfig};
use crate::eval::LanguageModel;
use crate::tensor::Tensor;

use super::CalibSet;

/// Which first-token scheme to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenVariant {
    V1,
    V2,
}

impl GenVariant {
    pub fn tag(&self) -> &'static str {
        match self {
            GenVariant::V1 => "gen-v1",
            GenVariant::V2 => "gen-v2",
        }
    }
}

/// Draw the first content token per the variant's restriction.
pub fn first_token(variant: GenVariant, rng: &mut SplitMix64) -> i32 {
    match variant {
        GenVariant::V1 => (N_SPECIAL + rng.below((VOCAB_SIZE - N_SPECIAL) as u64) as u32) as i32,
        GenVariant::V2 => {
            // weighted by corpus share over the top languages
            let top = &LANGS[..N_TOP_LANGS];
            let permille: Vec<u64> = top.iter().map(|l| (l.corpus_share * 1000.0) as u64).collect();
            let total: u64 = permille.iter().sum();
            let r = rng.below(total);
            let mut acc = 0;
            for (lang, p) in top.iter().zip(&permille) {
                acc += p;
                if r < acc {
                    return (lang.lo + rng.below((lang.hi - lang.lo) as u64) as u32) as i32;
                }
            }
            (top[0].lo) as i32
        }
    }
}

/// Generate an `n × seq` calibration set from the model itself.
pub fn generate_calib(
    model: &dyn LanguageModel,
    variant: GenVariant,
    n: usize,
    seq: usize,
    seed: u64,
) -> Result<CalibSet> {
    let mut rng = SplitMix64::new(seed);
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|_| vec![BOS, first_token(variant, &mut rng)])
        .collect();
    let cfg = SampleConfig { temperature: 1.0, stochastic_prefix: 5, seed };
    let seqs = generate(model, &prompts, seq, &cfg)?;
    let mut flat = Vec::with_capacity(n * seq);
    for s in &seqs {
        flat.extend(s);
    }
    Ok(CalibSet {
        tokens: Tensor::i32(&[n, seq], flat),
        source: variant.tag().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v2_stays_in_top_buckets() {
        let mut rng = SplitMix64::new(1);
        let top_hi = LANGS[N_TOP_LANGS - 1].hi;
        for _ in 0..500 {
            let t = first_token(GenVariant::V2, &mut rng) as u32;
            assert!(t >= N_SPECIAL && t < top_hi, "token {t} outside top langs");
        }
    }

    #[test]
    fn v1_covers_tail() {
        let mut rng = SplitMix64::new(2);
        let top_hi = LANGS[N_TOP_LANGS - 1].hi;
        let tail = (0..500)
            .filter(|_| (first_token(GenVariant::V1, &mut rng) as u32) >= top_hi)
            .count();
        // tail owns ~76% of the vocab, so uniform sampling should land there often
        assert!(tail > 300, "only {tail}/500 in tail");
    }

    #[test]
    fn v2_weighted_toward_en() {
        let mut rng = SplitMix64::new(3);
        let en = (0..1000)
            .filter(|_| {
                let t = first_token(GenVariant::V2, &mut rng) as u32;
                (8..168).contains(&t)
            })
            .count();
        // en has 40/78 of the top-language mass
        assert!(en > 350 && en < 700, "en count {en}");
    }
}

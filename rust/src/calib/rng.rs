//! splitmix64 PRNG — bit-for-bit mirror of `python/compile/corpus.py`.
//!
//! The corpus cross-check test (`rust/tests/corpus_crosscheck.rs`) compares
//! token streams generated here against goldens written by the Python side,
//! so any change to these constants must be made in both places.

/// splitmix64 stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

pub const MIX_K: u64 = 0x2545F4914F6CDD1D;

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` via modulo (bias negligible for n << 2^64).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// True with probability `num/den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }
}

/// Stateless avalanche hash (splitmix64 finalizer) — `corpus.mix64`.
pub fn mix64(x: u64) -> u64 {
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_sequence() {
        // golden values computed with the Python implementation
        let mut r = SplitMix64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        let mut r2 = SplitMix64::new(0);
        assert_eq!(r2.next_u64(), a);
        assert_eq!(r2.next_u64(), b);
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = SplitMix64::new(3);
        for _ in 0..100 {
            assert!(r.chance(100, 100));
            assert!(!r.chance(0, 100));
        }
    }

    #[test]
    fn mix64_avalanche() {
        // flipping one input bit should flip ~half the output bits
        let a = mix64(0x1234);
        let b = mix64(0x1235);
        let flipped = (a ^ b).count_ones();
        assert!(flipped > 16, "weak avalanche: {flipped}");
    }
}

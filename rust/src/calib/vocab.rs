//! Vocabulary layout — mirror of `python/compile/configs.py`.
//!
//! Reproduces the paper's Table-1 mismatch: top-5 languages dominate the
//! corpus (~78%) but own ~24% of the vocabulary.

pub const VOCAB_SIZE: u32 = 2048;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const PERIOD: i32 = 4;
pub const BIND: i32 = 5;
pub const QUERY: i32 = 6;
pub const UNK: i32 = 7;
pub const N_SPECIAL: u32 = 8;

/// One synthetic language: vocab bucket + corpus share + grammar salt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lang {
    pub name: &'static str,
    pub lo: u32,
    pub hi: u32,
    pub corpus_share: f64,
    pub salt: u64,
}

/// The 17-language registry (5 dominant + 12 tail).
pub const LANGS: &[Lang] = &[
    Lang { name: "en", lo: 8, hi: 168, corpus_share: 0.40, salt: 0x9E3779B97F4A7C15 },
    Lang { name: "zhs", lo: 168, hi: 200, corpus_share: 0.18, salt: 0xBF58476D1CE4E5B9 },
    Lang { name: "fr", lo: 200, hi: 328, corpus_share: 0.10, salt: 0x94D049BB133111EB },
    Lang { name: "es", lo: 328, hi: 424, corpus_share: 0.06, salt: 0xD6E8FEB86659FD93 },
    Lang { name: "pt", lo: 424, hi: 488, corpus_share: 0.04, salt: 0xA5A5A5A5A5A5A5A5 },
    Lang { name: "t0", lo: 488, hi: 618, corpus_share: 0.03, salt: 0x0123456789ABCDEF },
    Lang { name: "t1", lo: 618, hi: 748, corpus_share: 0.03, salt: 0xFEDCBA9876543210 },
    Lang { name: "t2", lo: 748, hi: 878, corpus_share: 0.02, salt: 0x1111111111111111 },
    Lang { name: "t3", lo: 878, hi: 1008, corpus_share: 0.02, salt: 0x2222222222222222 },
    Lang { name: "t4", lo: 1008, hi: 1138, corpus_share: 0.02, salt: 0x3333333333333333 },
    Lang { name: "t5", lo: 1138, hi: 1268, corpus_share: 0.02, salt: 0x4444444444444444 },
    Lang { name: "t6", lo: 1268, hi: 1398, corpus_share: 0.02, salt: 0x5555555555555555 },
    Lang { name: "t7", lo: 1398, hi: 1528, corpus_share: 0.01, salt: 0x6666666666666666 },
    Lang { name: "t8", lo: 1528, hi: 1658, corpus_share: 0.01, salt: 0x7777777777777777 },
    Lang { name: "t9", lo: 1658, hi: 1788, corpus_share: 0.01, salt: 0x8888888888888888 },
    Lang { name: "t10", lo: 1788, hi: 1918, corpus_share: 0.01, salt: 0x9999999999999999 },
    Lang { name: "t11", lo: 1918, hi: 2048, corpus_share: 0.02, salt: 0xAAAAAAAAAAAAAAAA },
];

pub const N_TOP_LANGS: usize = 5;

/// Map a token id to the language bucket owning it (None for specials).
pub fn lang_of_token(tok: i32) -> Option<&'static Lang> {
    let t = tok as u32;
    LANGS.iter().find(|l| t >= l.lo && t < l.hi)
}

/// Render a token as a readable pseudo-word (subjective-eval display).
pub fn token_to_word(tok: i32) -> String {
    match tok {
        PAD => "<pad>".into(),
        BOS => "<s>".into(),
        EOS => "</s>".into(),
        SEP => "<sep>".into(),
        PERIOD => ".".into(),
        BIND => ":=".into(),
        QUERY => "?".into(),
        UNK => "<unk>".into(),
        t => match lang_of_token(t) {
            Some(l) => {
                // stable consonant-vowel pseudo-word; the two trailing
                // syllables encode the token id in base 75 (15 consonants x
                // 5 vowels), which is injective for vocab < 5625 — adjacent
                // tokens can never render identically
                let consonants = b"bcdfgklmnprstvz";
                let vowels = b"aeiou";
                let x = crate::calib::rng::mix64(t as u64);
                let mut w = String::new();
                w.push(consonants[(x % 15) as usize] as char);
                w.push(vowels[((x / 15) % 5) as usize] as char);
                let tid = t as usize;
                for digit in [tid % 75, (tid / 75) % 75] {
                    w.push(consonants[digit % 15] as char);
                    w.push(vowels[(digit / 15) % 5] as char);
                }
                format!("{}_{w}", l.name)
            }
            None => format!("<tok{t}>"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let s: f64 = LANGS.iter().map(|l| l.corpus_share).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn buckets_are_contiguous_and_cover_vocab() {
        assert_eq!(LANGS[0].lo, N_SPECIAL);
        for w in LANGS.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
        assert_eq!(LANGS.last().unwrap().hi, VOCAB_SIZE);
    }

    #[test]
    fn table1_mismatch_holds() {
        // top-5 corpus share ~78%, vocab share < 30% — the paper's Table 1
        let corpus: f64 = LANGS[..5].iter().map(|l| l.corpus_share).sum();
        let vocab: f64 = LANGS[..5]
            .iter()
            .map(|l| (l.hi - l.lo) as f64)
            .sum::<f64>()
            / VOCAB_SIZE as f64;
        assert!(corpus > 0.7, "corpus share {corpus}");
        assert!(vocab < 0.3, "vocab share {vocab}");
    }

    #[test]
    fn lang_lookup() {
        assert_eq!(lang_of_token(10).unwrap().name, "en");
        assert_eq!(lang_of_token(170).unwrap().name, "zhs");
        assert!(lang_of_token(3).is_none());
    }

    #[test]
    fn words_are_stable_and_distinct() {
        assert_eq!(token_to_word(42), token_to_word(42));
        assert_ne!(token_to_word(42), token_to_word(43));
        assert!(token_to_word(42).starts_with("en_"));
    }
}

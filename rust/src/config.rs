//! Run configuration (TOML-subset; parsed by `util::tomlmini`).
//!
//! ```toml
//! [run]
//! model = "nt-small"
//! artifacts = "artifacts"
//!
//! [quant]
//! method = "gptq"          # any registered quantizer plugin, or a
//!                          # composition: "smoothquant+gptq" (see
//!                          # `normtweak help` for the registry table)
//! bits = 4
//! group = 0                # 0 = per-channel
//! act_bits = 0             # 0 = float activations
//! layer_bits = ["0:8"]     # per-layer bit overrides, "layer:bits"
//!
//! [tweak]
//! enabled = true
//! iters = 4
//! lr0 = 1e-3
//! lr_scale = 1.0
//! loss = "dist"            # dist | mse | kl
//!
//! [calib]
//! source = "gen-v2"        # gen-v1 | gen-v2 | random | wiki-syn | ptb-syn | c4-syn | train
//! n_samples = 32
//!
//! [eval]
//! lambada = true
//! ppl = ["wiki-syn", "c4-syn"]
//! tasks = []
//! ```

use crate::error::{Error, Result};
use crate::quant::quantizer::validate_spec;
use crate::quant::QuantScheme;
use crate::tweak::tweaker::LossKind;
use crate::tweak::TweakConfig;
use crate::util::tomlmini::TomlDoc;

#[derive(Debug, Clone)]
pub struct RunSection {
    pub model: String,
    pub artifacts: String,
}

#[derive(Debug, Clone)]
pub struct QuantSection {
    pub method: String,
    pub bits: u8,
    pub group: usize,
    pub act_bits: u8,
    /// Per-layer bit-width overrides as `"layer:bits"` entries.
    pub layer_bits: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct TweakSection {
    pub enabled: bool,
    pub iters: usize,
    pub lr0: f32,
    pub lr_scale: f32,
    pub loss: String,
}

#[derive(Debug, Clone)]
pub struct CalibSection {
    pub source: String,
    pub n_samples: usize,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct EvalSection {
    pub lambada: bool,
    pub ppl: Vec<String>,
    pub tasks: Vec<String>,
    pub ppl_tokens: usize,
}

/// The full parsed configuration (every field has a default).
#[derive(Debug, Clone)]
pub struct Config {
    pub run: RunSection,
    pub quant: QuantSection,
    pub tweak: TweakSection,
    pub calib: CalibSection,
    pub eval: EvalSection,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            run: RunSection { model: "nt-small".into(), artifacts: "artifacts".into() },
            quant: QuantSection {
                method: "gptq".into(),
                bits: 4,
                group: 0,
                act_bits: 0,
                layer_bits: vec![],
            },
            tweak: TweakSection {
                enabled: true,
                iters: 4,
                lr0: 1e-3,
                lr_scale: 1.0,
                loss: "dist".into(),
            },
            calib: CalibSection { source: "gen-v2".into(), n_samples: 32, seed: 0xCA11B },
            eval: EvalSection { lambada: true, ppl: vec![], tasks: vec![], ppl_tokens: 8192 },
        }
    }
}

impl Config {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut c = Config::default();
        let gs = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_str().map(String::from));
        let gu = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_usize());
        let gf = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_f32());
        let gb = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_bool());
        let ga = |sec: &str, key: &str| {
            doc.get(sec, key).and_then(|v| v.as_str_arr().map(|a| a.to_vec()))
        };

        if let Some(v) = gs("run", "model") { c.run.model = v; }
        if let Some(v) = gs("run", "artifacts") { c.run.artifacts = v; }
        if let Some(v) = gs("quant", "method") { c.quant.method = v; }
        if let Some(v) = gu("quant", "bits") { c.quant.bits = v as u8; }
        if let Some(v) = gu("quant", "group") { c.quant.group = v; }
        if let Some(v) = gu("quant", "act_bits") { c.quant.act_bits = v as u8; }
        if let Some(v) = ga("quant", "layer_bits") { c.quant.layer_bits = v; }
        if let Some(v) = gb("tweak", "enabled") { c.tweak.enabled = v; }
        if let Some(v) = gu("tweak", "iters") { c.tweak.iters = v; }
        if let Some(v) = gf("tweak", "lr0") { c.tweak.lr0 = v; }
        if let Some(v) = gf("tweak", "lr_scale") { c.tweak.lr_scale = v; }
        if let Some(v) = gs("tweak", "loss") { c.tweak.loss = v; }
        if let Some(v) = gs("calib", "source") { c.calib.source = v; }
        if let Some(v) = gu("calib", "n_samples") { c.calib.n_samples = v; }
        if let Some(v) = doc.get("calib", "seed").and_then(|v| v.as_u64()) { c.calib.seed = v; }
        if let Some(v) = gb("eval", "lambada") { c.eval.lambada = v; }
        if let Some(v) = ga("eval", "ppl") { c.eval.ppl = v; }
        if let Some(v) = ga("eval", "tasks") { c.eval.tasks = v; }
        if let Some(v) = gu("eval", "ppl_tokens") { c.eval.ppl_tokens = v; }
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    /// Validate the method spec against the quantizer registry and return
    /// its canonical name (compositions like `"smoothquant+gptq"` included).
    pub fn method(&self) -> Result<String> {
        validate_spec(&self.quant.method)
    }

    /// Parse `layer_bits` overrides into per-layer schemes sharing the base
    /// scheme's group grain. A layer index may appear at most once —
    /// letting the last entry win silently hid typos in hand-typed lists.
    pub fn layer_schemes(&self) -> Result<Vec<(usize, QuantScheme)>> {
        let base = self.scheme();
        let mut out = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for spec in &self.quant.layer_bits {
            let (l, b) = spec.split_once(':').ok_or_else(|| {
                Error::Config(format!(
                    "layer_bits entry `{spec}` must be `layer:bits`, e.g. \"0:8\""
                ))
            })?;
            let layer: usize = l.trim().parse().map_err(|_| {
                Error::Config(format!("bad layer index in layer_bits entry `{spec}`"))
            })?;
            let bits: u8 = b.trim().parse().map_err(|_| {
                Error::Config(format!("bad bit width in layer_bits entry `{spec}`"))
            })?;
            if !seen.insert(layer) {
                return Err(Error::Config(format!(
                    "duplicate layer index {layer} in layer_bits (entry `{spec}`); \
                     each layer may be overridden once"
                )));
            }
            out.push((layer, QuantScheme { bits, group_size: base.group_size }));
        }
        Ok(out)
    }

    pub fn scheme(&self) -> QuantScheme {
        QuantScheme {
            bits: self.quant.bits,
            group_size: if self.quant.group == 0 { None } else { Some(self.quant.group) },
        }
    }

    pub fn tweak_config(&self) -> Result<Option<TweakConfig>> {
        if !self.tweak.enabled {
            return Ok(None);
        }
        let loss = LossKind::from_str(&self.tweak.loss)?;
        Ok(Some(TweakConfig {
            iters: self.tweak.iters,
            lr0: self.tweak.lr0,
            lr_scale: self.tweak.lr_scale,
            loss,
        }))
    }

    pub fn act_bits(&self) -> Option<u8> {
        if self.quant.act_bits == 0 { None } else { Some(self.quant.act_bits) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse() {
        let c = Config::from_toml("").unwrap();
        assert_eq!(c.run.model, "nt-small");
        assert_eq!(c.method().unwrap(), "gptq");
        assert!(c.tweak_config().unwrap().is_some());
        assert_eq!(c.scheme().bits, 4);
        assert!(c.act_bits().is_none());
        assert!(c.layer_schemes().unwrap().is_empty());
    }

    #[test]
    fn full_toml_parses() {
        let c = Config::from_toml(
            r#"
            [run]
            model = "nt-tiny"
            [quant]
            method = "smoothquant"
            bits = 2
            group = 64
            act_bits = 8
            [tweak]
            enabled = false
            [calib]
            source = "wiki-syn"
            [eval]
            ppl = ["wiki-syn", "c4-syn"]
            "#,
        )
        .unwrap();
        assert_eq!(c.run.model, "nt-tiny");
        assert_eq!(c.method().unwrap(), "smoothquant");
        assert_eq!(c.scheme().group_size, Some(64));
        assert_eq!(c.act_bits(), Some(8));
        assert!(c.tweak_config().unwrap().is_none());
        assert_eq!(c.calib.source, "wiki-syn");
        assert_eq!(c.eval.ppl.len(), 2);
    }

    #[test]
    fn bad_values_rejected() {
        let c = Config::from_toml("[quant]\nmethod = \"zap\"").unwrap();
        assert!(c.method().is_err());
        let c = Config::from_toml("[tweak]\nloss = \"zap\"").unwrap();
        assert!(c.tweak_config().is_err());
    }

    #[test]
    fn composed_method_validates() {
        let c = Config::from_toml("[quant]\nmethod = \"smoothquant+gptq\"").unwrap();
        assert_eq!(c.method().unwrap(), "smoothquant+gptq");
        let c = Config::from_toml("[quant]\nmethod = \"smoothquant+zap\"").unwrap();
        assert!(c.method().is_err());
    }

    #[test]
    fn layer_bits_parse_and_reject() {
        let c = Config::from_toml(
            "[quant]\nbits = 2\ngroup = 64\nlayer_bits = [\"0:8\", \"3:4\"]",
        )
        .unwrap();
        let overrides = c.layer_schemes().unwrap();
        assert_eq!(overrides.len(), 2);
        assert_eq!(overrides[0], (0, QuantScheme { bits: 8, group_size: Some(64) }));
        assert_eq!(overrides[1], (3, QuantScheme { bits: 4, group_size: Some(64) }));
        let c = Config::from_toml("[quant]\nlayer_bits = [\"zap\"]").unwrap();
        assert!(c.layer_schemes().is_err());
    }

    #[test]
    fn duplicate_layer_bits_rejected() {
        // the last entry used to win silently, hiding typos like 0:8,0:2
        let c = Config::from_toml("[quant]\nlayer_bits = [\"0:8\", \"0:2\"]").unwrap();
        let err = c.layer_schemes().unwrap_err();
        assert!(format!("{err}").contains("duplicate layer index 0"), "{err}");
        // same layer, same bits is still a duplicate
        let c = Config::from_toml("[quant]\nlayer_bits = [\"3:4\", \"3:4\"]").unwrap();
        assert!(c.layer_schemes().is_err());
    }
}

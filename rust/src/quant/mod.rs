//! Quantization substrates behind the open [`Quantizer`] plugin API.
//!
//! Norm Tweaking treats its host PTQ method as a *plugin*: the pipeline
//! resolves a string spec through [`quantizer::registry`] and drives the
//! resulting trait object one transformer block at a time via a
//! [`quantizer::LayerContext`] that lazily provides float weights,
//! activation taps, per-linear Hessians, and the norm-fold hook.
//!
//! Built-in plugins (see each module for the algorithm):
//!
//! * [`rtn`] — round-to-nearest symmetric quantization (the paper's Table 4
//!   weakest baseline, and the primitive every other method builds on).
//! * [`gptq`] — Hessian-based OBS reconstruction (Frantar et al. 2022): the
//!   paper's main host algorithm. Pure-Rust Cholesky + blocked update.
//! * [`smoothquant`] — activation-outlier migration (Xiao et al. 2023) for
//!   joint W+A quantization (Table 4's W4A8 rows).
//! * [`awq`] — activation-aware per-channel weight scaling (AWQ-lite), the
//!   Table-10 comparison row.
//! * [`omniquant`] — grid-searched per-channel weight clipping
//!   (OmniQuant-lite, the learnable-weight-clipping reproduction), the
//!   Table-10 host.
//! * [`act`] — activation fake-quantization helpers (W4A8 / W4A4 modes).
//!
//! # Registering a new method
//!
//! Implement [`quantizer::Quantizer`] in a new file under `quant/` and add
//! one `Registration` row to [`quantizer::REGISTRY`] — the name is then
//! valid everywhere a method spec is accepted: `--method`, config files,
//! and `+`-compositions.
//!
//! # Startup validation (lint-backed)
//!
//! The pipeline's pre-flight checks are lint rules from [`crate::analysis`]
//! shared with `normtweak check`: method-spec resolution
//! ([`quantizer::validate_spec`], diagnostic NT0301), pack-width legality
//! ([`QuantScheme::pack_bits`], NT0303), and the exported-grain /
//! tweak-graph cross-checks (`coordinator::validate_scheme_artifacts`,
//! NT0308/NT0309). `quantize` still aborts on the first `Err`, but the
//! message carries every error-severity finding; run `normtweak check` for
//! the full diagnostic list including warnings.
//!
//! # Composed methods
//!
//! `a+b` chains preprocess stages left-to-right and quantizes with the last
//! stage: `smoothquant+gptq` migrates activation outliers into the norms,
//! then GPTQ reconstructs the smoothed weights against Hessians of the
//! smoothed inputs. See [`quantizer`] for the full contract.
//!
//! # Quantization grains
//!
//! [`QuantScheme::group_size`] picks the scale grain along K: `None` is
//! per-channel (tag `pc`), `Some(g)` is group-`g` (tag `g{g}`). The AOT
//! exporter compiles one `block_fwd_q`/`tweak_step` graph variant per grain
//! and records the set under the manifest's `groups` key; the default
//! export covers `pc`/`g32`/`g64`/`g128`. At pipeline startup the requested
//! scheme's grain is checked against that record
//! (`coordinator::validate_scheme_artifacts`), so an unexported grain fails
//! immediately with the list of what *is* exported.
//!
//! **Adding a new grain** is one `GROUPS` entry in `python/compile/aot.py`
//! (e.g. `"g16": 16` — the tag must be `g{size}` and the size must divide
//! every model's `d_model` and `d_ff`) followed by a re-export
//! (`make artifacts`, or `python -m compile.aot --groups pc,g16,...`). No
//! Rust change is needed: [`QuantScheme::group_tag`] derives the tag from
//! `group_size`, and the runtime learns the exported set from the manifest.
//!
//! # Incremental decode graphs and the KV slot arena
//!
//! Serving no longer re-runs the full fixed-shape forward per generated
//! token.  Alongside `block_fwd_q.{grain}.b{B}` the exporter emits, per
//! grain and bucket, a *prefill* variant `block_fwd_q_kv.{grain}.b{B}`
//! (block forward + per-head K/V `[B, H, S, Dh]`) and a one-token *step*
//! variant `block_dec_q.{grain}.b{B}` (new-token activation + per-row
//! position + KV caches → updated activation + caches), plus the shared
//! `embed_dec` / `head_dec` graphs.  The manifest records the contract
//! under its `decode` key: step buckets, the per-model cache shape, and
//! `slots` — the capacity of the *KV slot arena*.  The runtime parses the
//! record strictly when present (`slots` must be an exported step bucket
//! no smaller than the largest one; `normtweak check` lints the same
//! invariant as NT0110), and a manifest exported with `--no-decode`
//! simply has none — generation then falls back to full-context
//! recompute (`eval::decode`), a feature-gated degradation rather than
//! an error.
//!
//! **Cache layout.**  Session caches are not per-session tensors that get
//! stacked into a batch each step and scattered back after.  Each layer
//! owns one arena tensor pair `K,V: [slots, H, S, Dh]` allocated once at
//! model load ([`crate::eval::KvArena`]); admission reserves a slot index
//! per session, prefill writes the new rows in place, and every decode
//! turn runs the `slots`-batch step graph directly over the arena via the
//! runtime's carry calls — zero per-token stacking, scattering, or row
//! copies on the hot path (the CI trace gate rejects `stack_layer` /
//! `scatter_layer` / `cache_row` spans on decode tracks).  Retirement
//! just frees the slot.  Rows that carry no live session feed their slot's
//! shadow token/position, an *idempotent rewrite*: the step recomputes and
//! rewrites exactly the cache row it wrote last turn, so vacant and
//! retired rows stay byte-stable while costing no extra dispatch.
//!
//! Greedy output is token-identical between the arena session loop and
//! the recompute path whenever both run the same kernels (the offline
//! contract pinned by `rust/tests/decode_parity.rs`, which also pins
//! arena-vs-stacked parity and slot-reuse stability); on real artifacts
//! the step graphs use the jnp oracle kernels while the full-context
//! graphs use Pallas, so the two paths may differ only at argmax
//! near-ties inside the ~2e-4 kernel tolerance (`integration_eval.rs`
//! gates on exactly that).
//!
//! # Graph contract
//!
//! Every manifest graph entry records its full signature: the declared
//! `inputs` and — since the signature-recording exporter — the intended
//! `outputs`, both as `{name, shape, dtype}` specs that parse into the
//! shared [`crate::analysis::hlo::TensorSig`] type.  Those recorded specs
//! are what the runtime validates call arguments against
//! (`runtime::literal::check_spec`), and what the deep static pass
//! (`normtweak check --graphs`, or `quantize`/`serve --deep-check`)
//! cross-checks three ways: recorded intent vs the HLO text's actual
//! `entry_computation_layout` (NT0502), and both vs the pipeline dataflow
//! reconstructed from the model record — quantized arg/scale geometry per
//! grain (NT0503), activation-stream and bucket consistency (NT0504), KV
//! cache shapes vs the `decode` record (NT0505), decode-step `pos`/carried
//! -cache conventions (NT0506), and the scalar tweak loss (NT0507).  See
//! the diagnostic table in [`crate::analysis`].
//!
//! # Observability
//!
//! The quantization pipeline is instrumented through [`crate::obs`]: with
//! `quantize --trace out.json`, every layer records nested phase spans
//! (`float_ref` / `quantize` / `pack` / `tweak` / `advance`) on a
//! `pipeline` track, each norm-tweak Adam iteration emits its loss as a
//! Chrome counter sample, and per-graph execution timing lands on the
//! `xla` track keyed by graph family.  Per-layer phase latencies also
//! feed the global metrics registry (`pipeline.quant_us` /
//! `pipeline.tweak_us` histograms, `tweak.iters` counter), embedded in
//! the trace export.  Progress prints route through the leveled logger
//! (`NORMTWEAK_LOG`), never raw stdout — see [`crate::obs`] for the
//! naming convention and track schema.
//!
//! # Automatic mixed precision
//!
//! Per-layer scheme overrides (`PipelineConfig::layer_schemes`,
//! `--layer-bits`) no longer have to be hand-typed: the policy subsystem
//! ([`crate::policy`]) measures them. The flow is **profile → plan →
//! quantize**:
//!
//! 1. *Profile* — `normtweak plan` runs the calibration set through the
//!    float model, trial-quantizes every block at each candidate bit width
//!    through this registry, and scores the channel-wise output divergence
//!    with the tweak-loss distance kernels. The result is persisted as
//!    `sensitivity.json` with full provenance (model, method, grain,
//!    calibration source, loss).
//! 2. *Plan* — a greedy bit-budget knapsack upgrades the most fragile
//!    layers first until the mean width reaches `--target-bits`, emitting
//!    per-layer [`QuantScheme`]s at the base scheme's grain (so every
//!    override passes the same grain/pack-width validation as hand-typed
//!    ones).
//! 3. *Quantize* — `normtweak quantize --auto-bits <budget>` feeds that
//!    plan straight into the pipeline, reusing `sensitivity.json` when
//!    present; the plan's provenance is echoed into the pipeline metrics
//!    and experiment records.
//!
//! [`Quantizer`]: quantizer::Quantizer

pub mod act;
pub mod awq;
pub mod gptq;
pub mod omniquant;
pub mod quantizer;
pub mod rtn;
pub mod smoothquant;

pub use quantizer::{
    registry, resolve, BlockQuant, LayerContext, Linear, NormState, Quantizer, QuantizerParams,
    Requirements,
};

use crate::error::{Error, Result};

/// Weight quantization scheme: bit width + optional group size along K.
/// `group_size = None` means per-channel (one scale per output column over
/// the whole K dim) — the FasterTransformer-deployable scheme; the paper's
/// 2-bit results use fine-grained groups of 64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantScheme {
    pub bits: u8,
    pub group_size: Option<usize>,
}

impl QuantScheme {
    pub fn w4_perchannel() -> Self {
        QuantScheme { bits: 4, group_size: None }
    }

    pub fn w2_g64() -> Self {
        QuantScheme { bits: 2, group_size: Some(64) }
    }

    pub fn w3_g64() -> Self {
        QuantScheme { bits: 3, group_size: Some(64) }
    }

    /// Finest exported grain (the FPTQ-style fine-grained end of the sweep).
    pub fn w2_g32() -> Self {
        QuantScheme { bits: 2, group_size: Some(32) }
    }

    /// Coarsest exported grouped grain (GPTQ's deployment default).
    pub fn w4_g128() -> Self {
        QuantScheme { bits: 4, group_size: Some(128) }
    }

    /// Symmetric integer ceiling: 2^(bits-1) - 1.
    pub fn qmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1) as f32
    }

    /// Storage width for bit-packing (3-bit stores in 4-bit slots).
    /// Unsupported widths fail loudly instead of silently widening to 8.
    pub fn pack_bits(&self) -> Result<u8> {
        match self.bits {
            2 => Ok(2),
            3 | 4 => Ok(4),
            8 => Ok(8),
            other => Err(Error::Quant(format!(
                "no packed storage width for {other}-bit codes (supported: 2, 3, 4, 8)"
            ))),
        }
    }

    /// Effective group length for a K dimension.
    pub fn group_for(&self, k: usize) -> usize {
        self.group_size.unwrap_or(k).min(k)
    }

    pub fn validate(&self, k: usize) -> Result<()> {
        if ![2, 3, 4, 8].contains(&self.bits) {
            return Err(Error::Quant(format!("unsupported bit width {}", self.bits)));
        }
        let g = self.group_for(k);
        if k % g != 0 {
            return Err(Error::Quant(format!("K={k} not divisible by group {g}")));
        }
        Ok(())
    }

    /// Manifest group tag for artifact lookup: `"pc"` or the real grain
    /// (`"g64"`, `"g128"`, ...). The tag is checked against the manifest's
    /// exported-grain record at pipeline startup
    /// (`coordinator::validate_scheme_artifacts`), so a grain without
    /// exported graphs fails fast with the exported list instead of dying
    /// at graph lookup mid-run.
    pub fn group_tag(&self) -> String {
        match self.group_size {
            None => "pc".to_string(),
            Some(g) => format!("g{g}"),
        }
    }
}

/// Result of quantizing one weight matrix.
#[derive(Debug, Clone)]
pub struct QuantizedWeight {
    /// i8 codes, logical shape [K, N], row-major
    pub codes: Vec<i8>,
    pub k: usize,
    pub n: usize,
    /// f32 [G, N]
    pub scales: Vec<f32>,
    pub g: usize,
}

impl QuantizedWeight {
    /// Dequantize back to f32 (row-major [K, N]).
    pub fn dequantize(&self) -> Vec<f32> {
        let group = self.k / self.g;
        let mut w = vec![0.0f32; self.k * self.n];
        for kk in 0..self.k {
            let gi = kk / group;
            for nn in 0..self.n {
                w[kk * self.n + nn] =
                    self.codes[kk * self.n + nn] as f32 * self.scales[gi * self.n + nn];
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_helpers() {
        let s = QuantScheme::w4_perchannel();
        assert_eq!(s.qmax(), 7.0);
        assert_eq!(s.group_for(256), 256);
        assert_eq!(s.group_tag(), "pc");
        let s2 = QuantScheme::w2_g64();
        assert_eq!(s2.qmax(), 1.0);
        assert_eq!(s2.group_for(256), 64);
        assert_eq!(s2.group_tag(), "g64");
        assert_eq!(QuantScheme::w2_g32().group_tag(), "g32");
        assert_eq!(QuantScheme::w4_g128().group_tag(), "g128");
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(QuantScheme { bits: 5, group_size: None }.validate(64).is_err());
        assert!(QuantScheme { bits: 4, group_size: Some(48) }.validate(64).is_err());
        assert!(QuantScheme { bits: 4, group_size: Some(32) }.validate(64).is_ok());
    }

    #[test]
    fn pack_bits_mapping() {
        assert_eq!(QuantScheme { bits: 3, group_size: None }.pack_bits().unwrap(), 4);
        assert_eq!(QuantScheme::w2_g64().pack_bits().unwrap(), 2);
        assert_eq!(QuantScheme { bits: 8, group_size: None }.pack_bits().unwrap(), 8);
    }

    #[test]
    fn pack_bits_rejects_unsupported_width() {
        // 5-bit silently widening to 8 used to corrupt compression accounting
        assert!(QuantScheme { bits: 5, group_size: None }.pack_bits().is_err());
        assert!(QuantScheme { bits: 16, group_size: None }.pack_bits().is_err());
    }

    #[test]
    fn group_tag_emits_real_grain() {
        // Some(128) used to collapse to "g64" and load mismatched artifacts
        assert_eq!(QuantScheme { bits: 4, group_size: Some(128) }.group_tag(), "g128");
        assert_eq!(QuantScheme { bits: 4, group_size: Some(32) }.group_tag(), "g32");
    }
}

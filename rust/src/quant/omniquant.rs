//! OmniQuant-lite: learnable-weight-clipping reproduced as per-channel grid
//! search (the Table-10 host PTQ).
//!
//! OmniQuant's LWC learns a clipping strength per output channel via
//! gradient descent on block reconstruction; at our scale an exhaustive grid
//! over the clip ratio with the same objective (per-group amax shrink that
//! minimizes weight MSE) recovers its effect: at 2-3 bits the optimal scale
//! is smaller than the abs-max (clipping outliers costs less than the
//! rounding precision they steal).

use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::parallel::par_chunks_mut;

use super::quantizer::{BlockQuant, LayerContext, Linear, Quantizer, Requirements};
use super::{QuantScheme, QuantizedWeight};

/// OmniQuant-lite as a registry plugin: weight-only clipping, no side inputs.
pub struct OmniQuantizer;

impl Quantizer for OmniQuantizer {
    fn name(&self) -> &str {
        "omniquant"
    }

    fn requirements(&self) -> Requirements {
        Requirements::none()
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        Ok(BlockQuant {
            qkv: quantize(ctx.weight(Linear::Qkv), &ctx.scheme)?,
            proj: quantize(ctx.weight(Linear::Proj), &ctx.scheme)?,
            fc1: quantize(ctx.weight(Linear::Fc1), &ctx.scheme)?,
            fc2: quantize(ctx.weight(Linear::Fc2), &ctx.scheme)?,
        })
    }
}

/// Clip-ratio grid (1.0 == plain RTN). The low end matters at 2-3 bits,
/// where OmniQuant's learned clipping converges to aggressive values.
pub const CLIP_GRID: &[f32] =
    &[1.0, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1];

/// Quantize with per-(group, out-channel) optimal clipping.
pub fn quantize(w: &Tensor, scheme: &QuantScheme) -> Result<QuantizedWeight> {
    let k = w.shape[0];
    let n = w.shape[1];
    scheme.validate(k)?;
    let group = scheme.group_for(k);
    let g = k / group;
    let qmax = scheme.qmax();
    let wv = w.as_f32()?;

    let mut scales = vec![1.0f32; g * n];
    par_chunks_mut(&mut scales, n, |gi, srow| {
            for (col, s) in srow.iter_mut().enumerate() {
                let mut amax = 0.0f32;
                for kk in gi * group..(gi + 1) * group {
                    amax = amax.max(wv[kk * n + col].abs());
                }
                if amax == 0.0 {
                    *s = 1.0;
                    continue;
                }
                // grid-search the clip ratio minimizing group MSE
                let mut best_s = amax / qmax;
                let mut best_mse = f32::INFINITY;
                for &ratio in CLIP_GRID {
                    let sc = amax * ratio / qmax;
                    let mut mse = 0.0f32;
                    for kk in gi * group..(gi + 1) * group {
                        let x = wv[kk * n + col];
                        let q = (x / sc).round().clamp(-qmax, qmax);
                        let e = x - q * sc;
                        mse += e * e;
                    }
                    if mse < best_mse {
                        best_mse = mse;
                        best_s = sc;
                    }
                }
                *s = best_s;
            }
    });

    let mut codes = vec![0i8; k * n];
    {
        let scales_ref = &scales;
        par_chunks_mut(&mut codes, n, |kk, crow| {
            let gi = kk / group;
            for (col, c) in crow.iter_mut().enumerate() {
                let q = (wv[kk * n + col] / scales_ref[gi * n + col])
                    .round()
                    .clamp(-qmax, qmax);
                *c = q as i8;
            }
        });
    }

    Ok(QuantizedWeight { codes, k, n, scales, g })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn;

    fn weight_mse(w: &Tensor, q: &QuantizedWeight) -> f64 {
        let deq = q.dequantize();
        w.as_f32()
            .unwrap()
            .iter()
            .zip(&deq)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }

    #[test]
    fn never_worse_than_rtn_in_mse() {
        // clipping grid includes ratio 1.0, so MSE(omni) <= MSE(rtn)
        for seed in 0..4 {
            let w = Tensor::randn(&[64, 16], seed, 1.0);
            for scheme in [QuantScheme::w2_g64(), QuantScheme::w4_perchannel()] {
                let qo = quantize(&w, &scheme).unwrap();
                let qr = rtn::quantize(&w, &scheme).unwrap();
                assert!(weight_mse(&w, &qo) <= weight_mse(&w, &qr) + 1e-9);
            }
        }
    }

    #[test]
    fn clips_heavy_tailed_weights_at_2bit() {
        // a moderate outlier (3x the bulk) per column: at 2 bits the optimal
        // scale sacrifices the outlier to keep the bulk representable
        let mut v = Tensor::randn(&[64, 4], 9, 1.0).as_f32().unwrap().to_vec();
        for col in 0..4 {
            v[col] = 3.0;
        }
        let w = Tensor::f32(&[64, 4], v);
        let scheme = QuantScheme { bits: 2, group_size: Some(64) };
        let qo = quantize(&w, &scheme).unwrap();
        let qr = rtn::quantize(&w, &scheme).unwrap();
        // rtn scale = 3.0; omni should clip substantially
        assert!(qo.scales[0] < qr.scales[0] * 0.7,
                "omni {} vs rtn {}", qo.scales[0], qr.scales[0]);
        assert!(weight_mse(&w, &qo) < weight_mse(&w, &qr));
    }

    #[test]
    fn codes_in_range() {
        let w = Tensor::randn(&[32, 8], 1, 2.0);
        let q = quantize(&w, &QuantScheme { bits: 3, group_size: Some(32) }).unwrap();
        assert!(q.codes.iter().all(|&c| (-3..=3).contains(&c)));
    }
}

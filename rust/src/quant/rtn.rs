//! Round-to-nearest symmetric quantization — the primitive of every PTQ
//! method here, and the Table-4 "RTN" baseline on its own.
//!
//! Mirrors the Pallas `rtn_quantize` kernel / `ref.rtn_quantize` oracle
//! exactly (same qmax, same zero-amax convention), which the cross-layer
//! integration test verifies through the runtime.

use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::parallel::par_chunks_mut;

use super::quantizer::{rtn_block, BlockQuant, LayerContext, Quantizer, Requirements};
use super::{QuantScheme, QuantizedWeight};

/// RTN as a registry plugin: no side inputs, straight rounding.
pub struct RtnQuantizer;

impl Quantizer for RtnQuantizer {
    fn name(&self) -> &str {
        "rtn"
    }

    fn requirements(&self) -> Requirements {
        Requirements::none()
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        rtn_block(ctx)
    }
}

/// Quantize `w` (f32 [K, N], row-major) per `scheme`.
pub fn quantize(w: &Tensor, scheme: &QuantScheme) -> Result<QuantizedWeight> {
    let k = w.shape[0];
    let n = w.shape[1];
    scheme.validate(k)?;
    let group = scheme.group_for(k);
    let g = k / group;
    let qmax = scheme.qmax();
    let wv = w.as_f32()?;

    let mut scales = vec![0.0f32; g * n];
    // per group: amax over the group rows, per column
    par_chunks_mut(&mut scales, n, |gi, srow| {
        for (j, s) in srow.iter_mut().enumerate() {
            let mut amax = 0.0f32;
            for kk in gi * group..(gi + 1) * group {
                amax = amax.max(wv[kk * n + j].abs());
            }
            *s = if amax > 0.0 { amax / qmax } else { 1.0 };
        }
    });

    let mut codes = vec![0i8; k * n];
    {
        let scales_ref = &scales;
        par_chunks_mut(&mut codes, n, |kk, crow| {
            let gi = kk / group;
            for (j, c) in crow.iter_mut().enumerate() {
                let q = (wv[kk * n + j] / scales_ref[gi * n + j]).round();
                *c = q.clamp(-qmax, qmax) as i8;
            }
        });
    }

    Ok(QuantizedWeight { codes, k, n, scales, g })
}

/// Quantize a single column group in isolation (used by GPTQ's inner loop).
pub fn quantize_value(x: f32, scale: f32, qmax: f32) -> (i8, f32) {
    let q = (x / scale).round().clamp(-qmax, qmax);
    (q as i8, q * scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perchannel_error_bound() {
        // |w - deq(w)| <= scale/2 for every element (RTN's defining property)
        let w = Tensor::randn(&[64, 32], 9, 1.0);
        let s = QuantScheme::w4_perchannel();
        let q = quantize(&w, &s).unwrap();
        let deq = q.dequantize();
        let wv = w.as_f32().unwrap();
        for j in 0..32 {
            let scale = q.scales[j];
            for kk in 0..64 {
                let err = (wv[kk * 32 + j] - deq[kk * 32 + j]).abs();
                assert!(err <= scale / 2.0 + 1e-6, "err {err} scale {scale}");
            }
        }
    }

    #[test]
    fn grouped_matches_manual() {
        let w = Tensor::f32(&[4, 1], vec![1.0, -2.0, 8.0, 0.5]);
        let s = QuantScheme { bits: 4, group_size: Some(2) };
        let q = quantize(&w, &s).unwrap();
        assert_eq!(q.g, 2);
        // group0 amax=2 -> scale 2/7; group1 amax=8 -> scale 8/7
        assert!((q.scales[0] - 2.0 / 7.0).abs() < 1e-6);
        assert!((q.scales[1] - 8.0 / 7.0).abs() < 1e-6);
        assert_eq!(q.codes[0], (1.0 / (2.0 / 7.0) as f32).round() as i8);
        assert_eq!(q.codes[2], 7);
    }

    #[test]
    fn zero_group_gets_unit_scale() {
        let w = Tensor::zeros(&[8, 4]);
        let q = quantize(&w, &QuantScheme::w4_perchannel()).unwrap();
        assert!(q.scales.iter().all(|&s| s == 1.0));
        assert!(q.codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn w2_codes_in_range() {
        let w = Tensor::randn(&[64, 16], 3, 2.0);
        let q = quantize(&w, &QuantScheme::w2_g64()).unwrap();
        assert!(q.codes.iter().all(|&c| (-1..=1).contains(&c)));
    }
}

//! GPTQ (Frantar et al. 2022): Hessian-based one-shot weight reconstruction.
//!
//! Pure-Rust implementation of the OBS-style column-by-column quantization
//! with error feedback, matching the reference PyTorch implementation's
//! structure: damped Hessian → upper Cholesky of H⁻¹ → per-column quantize,
//! divide by the Cholesky diagonal, propagate the error into not-yet-
//! quantized rows (lazy block updates for cache efficiency).
//!
//! Weight layout: `W [K, N]` with K the *input* dim (Hessian dim) and N the
//! output channels — the same layout the AOT graphs use.  All Hessian
//! algebra is f64 for stability (2-bit quantization amplifies roundoff).

// Justified unwraps: the four-linear iterator is built from a fixed-size array
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::parallel::{par_chunks_mut, par_map};

use super::quantizer::{BlockQuant, LayerContext, Quantizer, Requirements, LINEARS};
use super::{rtn, QuantScheme, QuantizedWeight};

/// GPTQ as a registry plugin: consumes a per-linear Hessian, no raw taps.
pub struct GptqQuantizer {
    pub params: GptqParams,
}

impl Quantizer for GptqQuantizer {
    fn name(&self) -> &str {
        "gptq"
    }

    fn requirements(&self) -> Requirements {
        Requirements { hessians: true, act_taps: false }
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        let mut out = Vec::with_capacity(4);
        for lin in LINEARS {
            let h = ctx.take_hessian(lin)?;
            out.push(quantize(ctx.weight(lin), &h, &ctx.scheme, &self.params)?);
        }
        let mut it = out.into_iter();
        Ok(BlockQuant {
            qkv: it.next().unwrap(),
            proj: it.next().unwrap(),
            fc1: it.next().unwrap(),
            fc2: it.next().unwrap(),
        })
    }
}

/// Accumulated Hessian for one linear layer: `H = 2 Σ XᵀX` over calibration
/// batches (X = the layer's input activations, rows = tokens).
#[derive(Debug, Clone)]
pub struct Hessian {
    pub k: usize,
    /// row-major [K, K], f64
    pub h: Vec<f64>,
    pub n_samples: usize,
}

impl Hessian {
    pub fn new(k: usize) -> Self {
        Hessian { k, h: vec![0.0; k * k], n_samples: 0 }
    }

    /// Add a batch: `H += 2 XᵀX`.  `xtx` is f32 [K, K] (from the AOT `xtx`
    /// graph or [`crate::tensor::matmul`]), `rows` the token count in X.
    pub fn accumulate(&mut self, xtx: &Tensor, rows: usize) -> Result<()> {
        if xtx.shape != [self.k, self.k] {
            return Err(Error::Shape(format!(
                "xtx {:?}, expected [{}, {}]",
                xtx.shape, self.k, self.k
            )));
        }
        let v = xtx.as_f32()?;
        for (acc, &x) in self.h.iter_mut().zip(v) {
            *acc += 2.0 * x as f64;
        }
        self.n_samples += rows;
        Ok(())
    }

    /// Identity Hessian (makes GPTQ degenerate to RTN — a proptest invariant).
    pub fn identity(k: usize) -> Self {
        let mut h = vec![0.0; k * k];
        for i in 0..k {
            h[i * k + i] = 1.0;
        }
        Hessian { k, h, n_samples: 1 }
    }
}

/// GPTQ hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct GptqParams {
    /// relative damping added to diag(H) (reference default 0.01)
    pub percdamp: f64,
    /// lazy-update block width
    pub block_size: usize,
    /// act-order: quantize input dims in decreasing diag(H) order (the
    /// reference `--actorder` flag; helps at 2-3 bits). Only valid with
    /// per-channel scales (groups would straddle the permutation).
    pub actorder: bool,
}

impl Default for GptqParams {
    fn default() -> Self {
        GptqParams { percdamp: 0.01, block_size: 128, actorder: false }
    }
}

/// Quantize one weight matrix with GPTQ against its Hessian.
pub fn quantize(
    w: &Tensor,
    hessian: &Hessian,
    scheme: &QuantScheme,
    params: &GptqParams,
) -> Result<QuantizedWeight> {
    let k = w.shape[0];
    let n = w.shape[1];
    scheme.validate(k)?;
    if hessian.k != k {
        return Err(Error::Shape(format!("hessian k={} vs w K={k}", hessian.k)));
    }
    let group = scheme.group_for(k);
    let qmax = scheme.qmax();

    // ---- act-order: permute input dims by decreasing Hessian diagonal -------
    let perm: Vec<usize> = if params.actorder {
        if group != k {
            return Err(Error::Quant(
                "actorder requires per-channel scales (group == K)".into(),
            ));
        }
        let mut idx: Vec<usize> = (0..k).collect();
        idx.sort_by(|&a, &b| {
            hessian.h[b * k + b]
                .partial_cmp(&hessian.h[a * k + a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx
    } else {
        (0..k).collect()
    };

    // working copy of W in f64 [K, N], rows permuted
    let wv = w.as_f32()?;
    let mut work: Vec<f64> = Vec::with_capacity(k * n);
    for &src in &perm {
        work.extend(wv[src * n..(src + 1) * n].iter().map(|&x| x as f64));
    }

    // ---- prepare H (permuted): dead columns, damping -------------------------
    let mut h = vec![0.0f64; k * k];
    for (i, &pi) in perm.iter().enumerate() {
        for (j, &pj) in perm.iter().enumerate() {
            h[i * k + j] = hessian.h[pi * k + pj];
        }
    }
    let mut dead = vec![false; k];
    for i in 0..k {
        if h[i * k + i] == 0.0 {
            dead[i] = true;
            h[i * k + i] = 1.0;
            for j in 0..n {
                work[i * n + j] = 0.0;
            }
        }
    }
    let mean_diag: f64 = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
    let damp = params.percdamp * mean_diag;
    for i in 0..k {
        h[i * k + i] += damp;
    }

    // ---- U = upper Cholesky of H⁻¹ ------------------------------------------
    let l = cholesky_lower(&h, k)
        .ok_or_else(|| Error::Numerical("Hessian not positive definite".into()))?;
    let linv = invert_lower(&l, k);
    let hinv = ata_from_lower_inv(&linv, k); // H⁻¹ = Linv^T Linv
    let u = {
        // chol_lower(Hinv) = M with Hinv = M Mᵀ ; U = Mᵀ (upper, Hinv = Uᵀ U)
        let m = cholesky_lower(&hinv, k)
            .ok_or_else(|| Error::Numerical("H⁻¹ not positive definite".into()))?;
        transpose(&m, k)
    };

    // ---- column-by-column quantization with lazy block updates --------------
    let g = k / group;
    let mut codes = vec![0i8; k * n];
    let mut scales = vec![1.0f32; g * n];
    let bs = params.block_size.max(1);

    let mut row = 0;
    while row < k {
        let row_end = (row + bs).min(k);
        let bw = row_end - row;
        // error rows of this block, [bw, N]
        let mut err = vec![0.0f64; bw * n];

        for j in row..row_end {
            let gi = j / group;
            if j % group == 0 {
                // (re)compute group scales from the *current* (error-
                // compensated) weights — the reference "static groups off"
                // behaviour
                let srow = &mut scales[gi * n..(gi + 1) * n];
                for (col, s) in srow.iter_mut().enumerate() {
                    let mut amax = 0.0f64;
                    for kk in j..(j + group).min(k) {
                        amax = amax.max(work[kk * n + col].abs());
                    }
                    *s = if amax > 0.0 { (amax / qmax as f64) as f32 } else { 1.0 };
                }
            }
            let d = u[j * k + j];
            let lj = j - row;
            for col in 0..n {
                let x = work[j * n + col];
                let s = scales[gi * n + col] as f64;
                let q = (x / s).round().clamp(-qmax as f64, qmax as f64);
                codes[j * n + col] = q as i8;
                let dq = q * s;
                err[lj * n + col] = (x - dq) / d;
            }
            // propagate into the remaining rows of this block
            let ucol = &u[j * k..(j + 1) * k];
            for jj in (j + 1)..row_end {
                let f = ucol[jj];
                if f == 0.0 {
                    continue;
                }
                for col in 0..n {
                    work[jj * n + col] -= f * err[lj * n + col];
                }
            }
        }

        // lazy update of all rows past the block: W[row_end..] -= U[row..row_end, row_end..]ᵀ @ Err
        if row_end < k {
            let u_ref = &u;
            let err_ref = &err;
            let tail = &mut work[row_end * n..];
            par_chunks_mut(tail, n, |off, wrow| {
                let jj = row_end + off;
                for (lj, j) in (row..row_end).enumerate() {
                    let f = u_ref[j * k + jj];
                    if f == 0.0 {
                        continue;
                    }
                    let erow = &err_ref[lj * n..(lj + 1) * n];
                    for col in 0..n {
                        wrow[col] -= f * erow[col];
                    }
                }
            });
        }
        row = row_end;
    }

    // ---- undo the act-order permutation --------------------------------------
    if params.actorder {
        let mut unperm_codes = vec![0i8; k * n];
        for (i, &src) in perm.iter().enumerate() {
            unperm_codes[src * n..(src + 1) * n].copy_from_slice(&codes[i * n..(i + 1) * n]);
        }
        // per-channel scales: one group independent of row order — but the
        // scales were computed from permuted rows at j=0 covering all K, so
        // they are already row-order-free
        return Ok(QuantizedWeight { codes: unperm_codes, k, n, scales, g });
    }

    Ok(QuantizedWeight { codes, k, n, scales, g })
}

/// Convenience: GPTQ with an identity Hessian equals RTN (used by tests).
pub fn quantize_rtn_equivalent(w: &Tensor, scheme: &QuantScheme) -> Result<QuantizedWeight> {
    rtn::quantize(w, scheme)
}

// ---- dense f64 linear algebra helpers ---------------------------------------

/// Lower Cholesky: A = L Lᵀ. Returns None if not positive definite.
pub fn cholesky_lower(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for p in 0..j {
                s -= l[i * n + p] * l[j * n + p];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Invert a lower-triangular matrix (forward substitution per column —
/// columns are independent, so they solve in parallel; §Perf: this stage
/// was serial O(K³/6) and dominated GPTQ at K=1536 together with ata).
pub fn invert_lower(l: &[f64], n: usize) -> Vec<f64> {
    let cols = par_map(n, |col| {
        let mut x = vec![0.0f64; n];
        x[col] = 1.0 / l[col * n + col];
        for i in (col + 1)..n {
            let mut s = 0.0;
            for p in col..i {
                s += l[i * n + p] * x[p];
            }
            x[i] = -s / l[i * n + i];
        }
        x
    });
    let mut inv = vec![0.0f64; n * n];
    for (col, x) in cols.into_iter().enumerate() {
        for i in col..n {
            inv[i * n + col] = x[i];
        }
    }
    inv
}

/// Given Linv (lower), compute Linvᵀ · Linv (= H⁻¹), exploiting symmetry.
fn ata_from_lower_inv(linv: &[f64], n: usize) -> Vec<f64> {
    let rows = par_map(n, |i| {
        let mut row = vec![0.0f64; n];
        for j in i..n {
            // (LinvT Linv)[i,j] = sum_p Linv[p,i] * Linv[p,j], p >= max(i,j)
            let mut s = 0.0;
            for p in j..n {
                s += linv[p * n + i] * linv[p * n + j];
            }
            row[j] = s;
        }
        row
    });
    let mut out = vec![0.0f64; n * n];
    for (i, row) in rows.into_iter().enumerate() {
        out[i * n..(i + 1) * n].copy_from_slice(&row);
    }
    // mirror
    for i in 0..n {
        for j in 0..i {
            out[i * n + j] = out[j * n + i];
        }
    }
    out
}

fn transpose(a: &[f64], n: usize) -> Vec<f64> {
    let mut t = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = a[i * n + j];
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    #[test]
    fn cholesky_known() {
        // A = [[4,2],[2,3]] -> L = [[2,0],[1,sqrt(2)]]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky_lower(&a, 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_lower(&a, 2).is_none());
    }

    #[test]
    fn invert_lower_identity() {
        let l = vec![2.0, 0.0, 3.0, 4.0];
        let inv = invert_lower(&l, 2);
        // L * Linv = I
        let p00 = l[0] * inv[0];
        let p10 = l[2] * inv[0] + l[3] * inv[2];
        let p11 = l[3] * inv[3];
        assert!((p00 - 1.0).abs() < 1e-12);
        assert!(p10.abs() < 1e-12);
        assert!((p11 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn identity_hessian_matches_rtn() {
        let w = Tensor::randn(&[32, 16], 11, 1.0);
        let scheme = QuantScheme::w4_perchannel();
        let q_gptq = quantize(&w, &Hessian::identity(32), &scheme,
                              &GptqParams::default()).unwrap();
        let q_rtn = rtn::quantize(&w, &scheme).unwrap();
        // with H = I there is no correlation to exploit; same codes modulo
        // error feedback which is zero at the first column of each group...
        // but feedback only flows through off-diagonal U entries, which are 0.
        assert_eq!(q_gptq.codes, q_rtn.codes);
        for (a, b) in q_gptq.scales.iter().zip(&q_rtn.scales) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        // build a correlated Hessian: H = 2 XtX with X having strong column
        // correlation; GPTQ should reconstruct with lower proxy loss
        // tr((W-Q)ᵀ H (W-Q)) than RTN.
        let k = 32;
        let n = 24;
        let x = {
            let base = Tensor::randn(&[256, 1], 5, 1.0);
            let noise = Tensor::randn(&[256, k], 6, 0.3);
            let mut v = vec![0.0f32; 256 * k];
            for r in 0..256 {
                for c in 0..k {
                    v[r * k + c] =
                        base.as_f32().unwrap()[r] + noise.as_f32().unwrap()[r * k + c];
                }
            }
            Tensor::f32(&[256, k], v)
        };
        let xtx = matmul(&crate::tensor::transpose2d(&x).unwrap(), &x).unwrap();
        let mut hess = Hessian::new(k);
        hess.accumulate(&xtx, 256).unwrap();

        let w = Tensor::randn(&[k, n], 7, 1.0);
        let scheme = QuantScheme { bits: 2, group_size: Some(16) };
        let qg = quantize(&w, &hess, &scheme, &GptqParams::default()).unwrap();
        let qr = rtn::quantize(&w, &scheme).unwrap();

        let proxy = |q: &QuantizedWeight| -> f64 {
            let dq = q.dequantize();
            let wv = w.as_f32().unwrap();
            // tr(E^T H E), E = W - Q
            let mut total = 0.0f64;
            for col in 0..n {
                for i in 0..k {
                    let ei = (wv[i * n + col] - dq[i * n + col]) as f64;
                    if ei == 0.0 {
                        continue;
                    }
                    for j in 0..k {
                        let ej = (wv[j * n + col] - dq[j * n + col]) as f64;
                        total += ei * hess.h[i * k + j] * ej;
                    }
                }
            }
            total
        };
        let pg = proxy(&qg);
        let pr = proxy(&qr);
        assert!(
            pg < pr,
            "GPTQ proxy loss {pg:.3} should beat RTN {pr:.3}"
        );
    }

    #[test]
    fn actorder_not_worse_on_skewed_hessian() {
        // a strongly skewed Hessian diagonal: act-order should match or beat
        // natural order on the proxy loss tr(Eᵀ H E)
        let k = 24;
        let n = 16;
        let w = Tensor::randn(&[k, n], 21, 1.0);
        let mut hess = Hessian::new(k);
        let mut xtx = vec![0.0f32; k * k];
        for i in 0..k {
            xtx[i * k + i] = 1.0 + (k - i) as f32 * 10.0; // decreasing importance
        }
        hess.accumulate(&Tensor::f32(&[k, k], xtx), 64).unwrap();
        let scheme = QuantScheme { bits: 2, group_size: None };
        let q_nat = quantize(&w, &hess, &scheme, &GptqParams::default()).unwrap();
        let q_act = quantize(&w, &hess, &scheme,
                             &GptqParams { actorder: true, ..Default::default() })
            .unwrap();
        let proxy = |q: &QuantizedWeight| -> f64 {
            let dq = q.dequantize();
            let wv = w.as_f32().unwrap();
            let mut t = 0.0;
            for col in 0..n {
                for i in 0..k {
                    let e = (wv[i * n + col] - dq[i * n + col]) as f64;
                    t += e * e * hess.h[i * k + i];
                }
            }
            t
        };
        assert!(proxy(&q_act) <= proxy(&q_nat) * 1.02,
                "actorder {} vs natural {}", proxy(&q_act), proxy(&q_nat));
    }

    #[test]
    fn actorder_rejects_groups() {
        let w = Tensor::randn(&[32, 8], 1, 1.0);
        let scheme = QuantScheme { bits: 2, group_size: Some(16) };
        let p = GptqParams { actorder: true, ..Default::default() };
        assert!(quantize(&w, &Hessian::identity(32), &scheme, &p).is_err());
    }

    #[test]
    fn actorder_identity_hessian_matches_rtn_dequant() {
        // with H = I the permutation is arbitrary but the dequantized result
        // must still be RTN-equivalent per element
        let w = Tensor::randn(&[16, 8], 31, 1.0);
        let scheme = QuantScheme::w4_perchannel();
        let q = quantize(&w, &Hessian::identity(16), &scheme,
                         &GptqParams { actorder: true, ..Default::default() })
            .unwrap();
        let qr = rtn::quantize(&w, &scheme).unwrap();
        for (a, b) in q.dequantize().iter().zip(qr.dequantize().iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn dead_columns_zeroed() {
        let k = 8;
        let mut hess = Hessian::new(k);
        // only first 4 input dims ever active
        let mut xtx = vec![0.0f32; k * k];
        for i in 0..4 {
            xtx[i * k + i] = 5.0;
        }
        hess.accumulate(&Tensor::f32(&[k, k], xtx), 16).unwrap();
        let w = Tensor::ones(&[k, 4]);
        let q = quantize(&w, &hess, &QuantScheme::w4_perchannel(),
                         &GptqParams::default()).unwrap();
        for dead_row in 4..8 {
            for col in 0..4 {
                assert_eq!(q.codes[dead_row * 4 + col], 0);
            }
        }
    }
}

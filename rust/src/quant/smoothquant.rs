//! SmoothQuant (Xiao et al. 2023): migrate activation outliers into weights.
//!
//! For a linear `y = x W`, pick per-input-channel factors
//! `s_j = max|x_j|^α / max|w_j|^(1-α)` and rewrite `y = (x / s)(s W)` — the
//! scaled activations are then quantizable to 8 bits while the weight picks
//! up the (weight-friendly) outliers.  The transform is numerically exact in
//! float; quantization then happens on the transformed pair.
//!
//! Our deployment folds `1/s` into the *preceding* LayerNorm's gamma/beta
//! exactly as the paper does, which is also why SmoothQuant composes so
//! naturally with Norm Tweaking — both treat the norm affine as the
//! distribution-control surface.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::quantizer::{rtn_block, BlockQuant, LayerContext, Linear, Quantizer, Requirements};

/// SmoothQuant as a registry plugin. The migration is pure preprocessing —
/// scale the norm-fed weights, fold `1/s` into the preceding norm through
/// the context — so it composes as a pre-stage for any terminal method
/// (`smoothquant+gptq`); standalone it finishes with RTN.
pub struct SmoothQuantizer {
    pub params: SmoothParams,
}

impl Quantizer for SmoothQuantizer {
    fn name(&self) -> &str {
        "smoothquant"
    }

    fn requirements(&self) -> Requirements {
        Requirements { hessians: false, act_taps: true }
    }

    fn preprocess(&self, ctx: &mut LayerContext) -> Result<()> {
        for lin in [Linear::Qkv, Linear::Fc1] {
            let stats = ctx.act_stats(lin)?;
            let s = smoothing_factors(ctx.weight(lin), &stats, &self.params)?;
            let scaled = scale_weight(ctx.weight(lin), &s)?;
            ctx.set_weight(lin, scaled);
            ctx.fold_input_scales(lin, &s)?;
        }
        Ok(())
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        rtn_block(ctx)
    }
}

/// Per-input-channel activation absolute maxima for one linear layer,
/// accumulated over calibration batches.
#[derive(Debug, Clone)]
pub struct ActStats {
    pub amax: Vec<f32>,
}

impl ActStats {
    pub fn new(k: usize) -> Self {
        ActStats { amax: vec![0.0; k] }
    }

    /// Fold in a batch of activations `x [rows, K]`.
    pub fn update(&mut self, x: &Tensor) -> Result<()> {
        let k = self.amax.len();
        if x.shape.last() != Some(&k) {
            return Err(Error::Shape(format!(
                "act stats: {:?} vs K={k}",
                x.shape
            )));
        }
        let v = x.as_f32()?;
        for row in v.chunks_exact(k) {
            for (a, &x) in self.amax.iter_mut().zip(row) {
                *a = a.max(x.abs());
            }
        }
        Ok(())
    }
}

/// SmoothQuant migration strength (paper default 0.5).
#[derive(Debug, Clone, Copy)]
pub struct SmoothParams {
    pub alpha: f32,
}

impl Default for SmoothParams {
    fn default() -> Self {
        SmoothParams { alpha: 0.5 }
    }
}

/// Compute the per-input-channel smoothing factors `s` for weight `w [K, N]`.
pub fn smoothing_factors(w: &Tensor, act: &ActStats, p: &SmoothParams) -> Result<Vec<f32>> {
    let k = w.shape[0];
    let n = w.shape[1];
    if act.amax.len() != k {
        return Err(Error::Shape("act stats K mismatch".into()));
    }
    let wv = w.as_f32()?;
    let mut s = vec![1.0f32; k];
    for j in 0..k {
        let mut wmax = 0.0f32;
        for col in 0..n {
            wmax = wmax.max(wv[j * n + col].abs());
        }
        let a = act.amax[j].max(1e-5);
        let wm = wmax.max(1e-5);
        s[j] = (a.powf(p.alpha) / wm.powf(1.0 - p.alpha)).max(1e-5);
    }
    Ok(s)
}

/// Apply the migration: returns `s W` (weight rows scaled **up** by s).
/// The caller must divide the *activations* by `s` — done by folding `1/s`
/// into the preceding norm's affine via [`fold_into_norm`].
pub fn scale_weight(w: &Tensor, s: &[f32]) -> Result<Tensor> {
    let k = w.shape[0];
    let n = w.shape[1];
    let wv = w.as_f32()?;
    let mut out = vec![0.0f32; k * n];
    for j in 0..k {
        for col in 0..n {
            out[j * n + col] = wv[j * n + col] * s[j];
        }
    }
    Ok(Tensor::f32(&[k, n], out))
}

/// Fold `1/s` into a norm affine: gamma' = gamma / s, beta' = beta / s.
/// (The norm's output feeds the linear, so dividing its affine by `s`
/// divides the activations by `s` exactly.)
pub fn fold_into_norm(
    gamma: &Tensor,
    beta: Option<&Tensor>,
    s: &[f32],
) -> Result<(Tensor, Option<Tensor>)> {
    let g = gamma.as_f32()?;
    if g.len() != s.len() {
        return Err(Error::Shape("fold: gamma/s length mismatch".into()));
    }
    let g2: Vec<f32> = g.iter().zip(s).map(|(x, f)| x / f).collect();
    let b2 = match beta {
        Some(b) => Some(Tensor::f32(
            &[s.len()],
            b.as_f32()?.iter().zip(s).map(|(x, f)| x / f).collect(),
        )),
        None => None,
    };
    Ok((Tensor::f32(&[s.len()], g2), b2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, max_abs_diff};

    #[test]
    fn transform_is_exact_in_float() {
        // (x / s) @ (s W) == x @ W
        let x = Tensor::randn(&[8, 16], 1, 2.0);
        let w = Tensor::randn(&[16, 12], 2, 1.0);
        let mut stats = ActStats::new(16);
        stats.update(&x).unwrap();
        let s = smoothing_factors(&w, &stats, &SmoothParams::default()).unwrap();
        let ws = scale_weight(&w, &s).unwrap();

        let xs = {
            let xv = x.as_f32().unwrap();
            let mut out = vec![0.0f32; 8 * 16];
            for r in 0..8 {
                for j in 0..16 {
                    out[r * 16 + j] = xv[r * 16 + j] / s[j];
                }
            }
            Tensor::f32(&[8, 16], out)
        };
        let y0 = matmul(&x, &w).unwrap();
        let y1 = matmul(&xs, &ws).unwrap();
        assert!(max_abs_diff(&y0, &y1).unwrap() < 1e-4);
    }

    #[test]
    fn factors_shrink_activation_range() {
        // an outlier activation channel should get s > 1 (activation shrunk)
        let mut stats = ActStats::new(4);
        let x = Tensor::f32(&[2, 4], vec![100.0, 1.0, 1.0, 1.0, -90.0, 0.5, 1.0, 0.2]);
        stats.update(&x).unwrap();
        let w = Tensor::ones(&[4, 3]);
        let s = smoothing_factors(&w, &stats, &SmoothParams::default()).unwrap();
        assert!(s[0] > 5.0, "outlier channel factor {}", s[0]);
        assert!(s[1] <= 1.5);
    }

    #[test]
    fn fold_into_norm_matches_division() {
        let gamma = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let beta = Tensor::f32(&[3], vec![0.5, -0.5, 0.0]);
        let s = vec![2.0, 4.0, 0.5];
        let (g2, b2) = fold_into_norm(&gamma, Some(&beta), &s).unwrap();
        assert_eq!(g2.as_f32().unwrap(), &[0.5, 0.5, 6.0]);
        assert_eq!(b2.unwrap().as_f32().unwrap(), &[0.25, -0.125, 0.0]);
    }

    #[test]
    fn act_stats_accumulate_max() {
        let mut st = ActStats::new(2);
        st.update(&Tensor::f32(&[1, 2], vec![1.0, -3.0])).unwrap();
        st.update(&Tensor::f32(&[1, 2], vec![-2.0, 1.0])).unwrap();
        assert_eq!(st.amax, vec![2.0, 3.0]);
        assert!(st.update(&Tensor::zeros(&[1, 3])).is_err());
    }
}

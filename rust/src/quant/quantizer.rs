//! The open `Quantizer` plugin API: every PTQ method is a composable plugin.
//!
//! The paper's central claim is that norm tweaking *layers onto* any host
//! PTQ method.  This module makes that architectural: a [`Quantizer`] is a
//! trait object resolved from a string spec (`"gptq"`, `"smoothquant+gptq"`,
//! ...) through the [`registry`], and the pipeline drives it through a
//! [`LayerContext`] that lazily provides everything a method may need —
//! the float weight view, per-linear Hessians, activation taps, and a
//! uniform [`LayerContext::fold_input_scales`] hook so outlier-migration
//! methods never touch `ln1_g`/`ln2_g` by hand.
//!
//! # Plugin contract
//!
//! A plugin runs in two phases per transformer block:
//!
//! 1. [`Quantizer::preprocess`] — optional float-domain rewriting: scale
//!    weights ([`LayerContext::set_weight`]) and migrate the inverse scales
//!    into the preceding norm ([`LayerContext::fold_input_scales`]).
//!    SmoothQuant and AWQ live entirely here, which is what makes them
//!    composable *pre-stages* for any reconstruction method.
//! 2. [`Quantizer::quantize_block`] — produce the four [`QuantizedWeight`]s
//!    from the context's current (possibly preprocessed) weights.
//!
//! Composition `a+b` chains every stage's `preprocess` in order and then
//! runs the *last* stage's `quantize_block`: `smoothquant+gptq` smooths the
//! activations and lets GPTQ reconstruct the smoothed weights against
//! Hessians of the smoothed inputs (the context rescales taps after a fold,
//! so lazily-built Hessians stay consistent).
//!
//! # Registering a new method
//!
//! ```text
//! 1. implement `Quantizer` for your type (one new file in `quant/`);
//! 2. add a `Registration { name, summary, build }` row to `REGISTRY`.
//! ```
//! The name is immediately valid in `--method`, in config files, and in any
//! `+`-composition.

use crate::coordinator::{hessian_from_tap, hessian_from_tap_cpu, FloatModel};
use crate::error::{Error, Result};
use crate::model::BlockWeights;
use crate::tensor::Tensor;

use super::gptq::Hessian;
use super::smoothquant::{fold_into_norm, ActStats};
use super::{awq, gptq, omniquant, rtn, smoothquant, QuantScheme, QuantizedWeight};

/// Identifies one of a block's four linears (also the tap/Hessian index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Linear {
    Qkv = 0,
    Proj = 1,
    Fc1 = 2,
    Fc2 = 3,
}

/// Block-quantization order: matches the AOT tap / Hessian layout.
pub const LINEARS: [Linear; 4] = [Linear::Qkv, Linear::Proj, Linear::Fc1, Linear::Fc2];

impl Linear {
    pub fn as_str(&self) -> &'static str {
        match self {
            Linear::Qkv => "qkv",
            Linear::Proj => "proj",
            Linear::Fc1 => "fc1",
            Linear::Fc2 => "fc2",
        }
    }
}

/// What side inputs a plugin consumes. Purely declarative — the context
/// collects lazily either way — but the registry parity suite asserts the
/// declaration matches actual consumption, so plugins cannot silently
/// trigger (or claim) expensive Hessian collection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Requirements {
    /// per-linear `2 XᵀX` Hessians of the calibration inputs
    pub hessians: bool,
    /// raw activation taps feeding each linear
    pub act_taps: bool,
}

impl Requirements {
    pub fn none() -> Self {
        Requirements::default()
    }

    pub fn union(self, other: Requirements) -> Requirements {
        Requirements {
            hessians: self.hessians || other.hessians,
            act_taps: self.act_taps || other.act_taps,
        }
    }
}

/// Result of quantizing one block: the four linears in AOT order.
#[derive(Debug, Clone)]
pub struct BlockQuant {
    pub qkv: QuantizedWeight,
    pub proj: QuantizedWeight,
    pub fc1: QuantizedWeight,
    pub fc2: QuantizedWeight,
}

/// The pending norm affine of a block. Plugins fold input scales into it
/// through the context; the pipeline turns it into the quantized block's
/// norm parameters (which norm tweaking then optimizes further).
#[derive(Debug, Clone)]
pub struct NormState {
    pub ln1_g: Tensor,
    pub ln1_b: Option<Tensor>,
    pub ln2_g: Tensor,
    pub ln2_b: Option<Tensor>,
}

enum TapSource<'a> {
    /// Production: taps via the float model's AOT `block_taps` graph,
    /// Hessians via the runtime `xtx` graph.
    Live {
        fm: &'a FloatModel<'a, 'a>,
        layer: usize,
        x_q: &'a Tensor,
    },
    /// Tests / offline: precomputed taps, CPU Gram matrices.
    Static { taps: Vec<Tensor> },
}

/// Per-layer view handed to a [`Quantizer`]: float weights (with preprocess
/// overrides), lazy activation taps and Hessians, and the norm-fold hook.
pub struct LayerContext<'a> {
    source: TapSource<'a>,
    pub scheme: QuantScheme,
    weights: BlockWeights<'a>,
    overrides: [Option<Tensor>; 4],
    in_scales: [Option<Vec<f32>>; 4],
    norms: NormState,
    taps: Option<Vec<Tensor>>,
    taps_used: bool,
    hessians_used: bool,
}

fn norm_state(bw: &BlockWeights) -> NormState {
    NormState {
        ln1_g: bw.ln1_g.clone(),
        ln1_b: bw.ln1_b.cloned(),
        ln2_g: bw.ln2_g.clone(),
        ln2_b: bw.ln2_b.cloned(),
    }
}

impl<'a> LayerContext<'a> {
    /// Production context: taps/Hessians computed through the runtime from
    /// the quantized-stream input `x_q` (Algorithm 1 keeps the error model
    /// honest by calibrating layer `l` on the *quantized* prefix).
    pub fn new(
        fm: &'a FloatModel<'a, 'a>,
        layer: usize,
        x_q: &'a Tensor,
        weights: BlockWeights<'a>,
        scheme: QuantScheme,
    ) -> Self {
        let norms = norm_state(&weights);
        LayerContext {
            source: TapSource::Live { fm, layer, x_q },
            scheme,
            weights,
            overrides: [None, None, None, None],
            in_scales: [None, None, None, None],
            norms,
            taps: None,
            taps_used: false,
            hessians_used: false,
        }
    }

    /// Offline context with precomputed taps (one `[rows, K]` activation
    /// tensor per linear, in [`LINEARS`] order). Hessians fall back to CPU
    /// Gram matrices — no runtime or AOT artifacts needed.
    pub fn with_static_taps(
        weights: BlockWeights<'a>,
        taps: Vec<Tensor>,
        scheme: QuantScheme,
    ) -> Self {
        let norms = norm_state(&weights);
        LayerContext {
            source: TapSource::Static { taps },
            scheme,
            weights,
            overrides: [None, None, None, None],
            in_scales: [None, None, None, None],
            norms,
            taps: None,
            taps_used: false,
            hessians_used: false,
        }
    }

    /// Current float weight of a linear: the preprocess override if one was
    /// installed, else the original checkpoint view.
    pub fn weight(&self, lin: Linear) -> &Tensor {
        if let Some(t) = &self.overrides[lin as usize] {
            return t;
        }
        match lin {
            Linear::Qkv => self.weights.wqkv,
            Linear::Proj => self.weights.wproj,
            Linear::Fc1 => self.weights.wfc1,
            Linear::Fc2 => self.weights.wfc2,
        }
    }

    /// Replace the effective float weight of a linear (preprocess stages:
    /// outlier migration, clipping, ...).
    pub fn set_weight(&mut self, lin: Linear, w: Tensor) {
        self.overrides[lin as usize] = Some(w);
    }

    fn ensure_taps(&mut self) -> Result<()> {
        if self.taps.is_none() {
            let taps = match &self.source {
                TapSource::Live { fm, layer, x_q } => fm.block_taps(*layer, x_q)?,
                TapSource::Static { taps } => taps.clone(),
            };
            if taps.len() != 4 {
                return Err(Error::Quant(format!(
                    "expected 4 activation taps, got {}",
                    taps.len()
                )));
            }
            self.taps = Some(taps);
        }
        Ok(())
    }

    /// Flattened `[rows, K]` activation feeding `lin`, with any folded input
    /// scales already applied (so taps stay consistent with the rewritten
    /// norm affine after a preprocess fold).
    fn tap_inner(&mut self, lin: Linear) -> Result<Tensor> {
        self.ensure_taps()?;
        let i = lin as usize;
        let t = self
            .taps
            .as_ref()
            .ok_or_else(|| Error::Quant("taps unavailable after ensure_taps".into()))?[i]
            .clone();
        let k = *t
            .shape
            .last()
            .ok_or_else(|| Error::Quant("tap has empty shape".into()))?;
        let rows = t.numel() / k;
        let mut flat = t.reshape(&[rows, k])?;
        if let Some(s) = &self.in_scales[i] {
            let v = flat.as_f32_mut()?;
            for r in 0..rows {
                for (j, &f) in s.iter().enumerate() {
                    v[r * k + j] /= f;
                }
            }
        }
        Ok(flat)
    }

    /// The activation tap feeding `lin` (flattened to `[rows, K]`).
    pub fn tap(&mut self, lin: Linear) -> Result<Tensor> {
        self.taps_used = true;
        self.tap_inner(lin)
    }

    /// Per-input-channel abs-max statistics of the tap feeding `lin`.
    pub fn act_stats(&mut self, lin: Linear) -> Result<ActStats> {
        let flat = self.tap(lin)?;
        let mut st = ActStats::new(flat.shape[1]);
        st.update(&flat)?;
        Ok(st)
    }

    /// Hessian `2 XᵀX` of the inputs feeding `lin`, built fresh from the
    /// (scale-corrected) tap. Owned so reconstruction methods can hold it
    /// while reading the weight view.
    pub fn take_hessian(&mut self, lin: Linear) -> Result<Hessian> {
        self.hessians_used = true;
        let flat = self.tap_inner(lin)?;
        match &self.source {
            TapSource::Live { fm, .. } => {
                hessian_from_tap(fm.runtime, &fm.weights.config.name, &flat)
            }
            TapSource::Static { .. } => hessian_from_tap_cpu(&flat),
        }
    }

    /// Migrate per-input-channel scales `s` out of the activations feeding
    /// `lin`: folds `1/s` into the preceding norm affine and records `s` so
    /// later tap/Hessian requests see the rescaled inputs. Only the two
    /// norm-fed linears (`qkv` via ln1, `fc1` via ln2) accept a fold.
    pub fn fold_input_scales(&mut self, lin: Linear, s: &[f32]) -> Result<()> {
        match lin {
            Linear::Qkv => {
                let (g, b) = fold_into_norm(&self.norms.ln1_g, self.norms.ln1_b.as_ref(), s)?;
                self.norms.ln1_g = g;
                self.norms.ln1_b = b;
            }
            Linear::Fc1 => {
                let (g, b) = fold_into_norm(&self.norms.ln2_g, self.norms.ln2_b.as_ref(), s)?;
                self.norms.ln2_g = g;
                self.norms.ln2_b = b;
            }
            Linear::Proj | Linear::Fc2 => {
                return Err(Error::Quant(format!(
                    "fold_input_scales: `{}` is not norm-fed (only qkv/fc1 can absorb \
                     input scales into a preceding norm)",
                    lin.as_str()
                )));
            }
        }
        let i = lin as usize;
        match &mut self.in_scales[i] {
            Some(acc) => {
                if acc.len() != s.len() {
                    return Err(Error::Quant(format!(
                        "fold_input_scales: scale length {} != earlier fold {}",
                        s.len(),
                        acc.len()
                    )));
                }
                for (a, &f) in acc.iter_mut().zip(s) {
                    *a *= f;
                }
            }
            None => self.in_scales[i] = Some(s.to_vec()),
        }
        Ok(())
    }

    /// Accumulated input scales folded out of `lin`'s activations, if any.
    pub fn input_scales(&self, lin: Linear) -> Option<&[f32]> {
        self.in_scales[lin as usize].as_deref()
    }

    /// The pending (possibly fold-rewritten) norm affine.
    pub fn norms(&self) -> &NormState {
        &self.norms
    }

    /// Consume the context, yielding the final norm affine for the block.
    pub fn into_norms(self) -> NormState {
        self.norms
    }

    /// Whether any tap was consumed through the public API (parity checks).
    pub fn taps_used(&self) -> bool {
        self.taps_used
    }

    /// Whether any Hessian was consumed (parity checks).
    pub fn hessians_used(&self) -> bool {
        self.hessians_used
    }
}

/// A PTQ method as a composable plugin. See the module docs for the
/// two-phase contract and the registration recipe.
pub trait Quantizer {
    /// Canonical registry name (composed plugins join with `+`).
    fn name(&self) -> &str;

    /// Side inputs this plugin consumes across both phases.
    fn requirements(&self) -> Requirements;

    /// Optional float-domain preprocessing (outlier migration, scaling).
    fn preprocess(&self, _ctx: &mut LayerContext) -> Result<()> {
        Ok(())
    }

    /// Quantize the four linears from the context's current weights.
    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant>;

    /// Convenience: run both phases.
    fn quantize_layer(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        self.preprocess(ctx)?;
        self.quantize_block(ctx)
    }
}

/// RTN over all four linears of the context — the shared terminal stage for
/// preprocess-only plugins and the baseline every method is measured against.
pub fn rtn_block(ctx: &LayerContext) -> Result<BlockQuant> {
    Ok(BlockQuant {
        qkv: rtn::quantize(ctx.weight(Linear::Qkv), &ctx.scheme)?,
        proj: rtn::quantize(ctx.weight(Linear::Proj), &ctx.scheme)?,
        fc1: rtn::quantize(ctx.weight(Linear::Fc1), &ctx.scheme)?,
        fc2: rtn::quantize(ctx.weight(Linear::Fc2), &ctx.scheme)?,
    })
}

/// `a+b+...`: chain every stage's preprocess, quantize with the last stage.
pub struct Composed {
    name: String,
    parts: Vec<Box<dyn Quantizer>>,
}

impl Composed {
    pub fn new(parts: Vec<Box<dyn Quantizer>>) -> Result<Self> {
        if parts.is_empty() {
            return Err(Error::Config("empty quantizer composition".into()));
        }
        let name = parts
            .iter()
            .map(|p| p.name().to_string())
            .collect::<Vec<_>>()
            .join("+");
        Ok(Composed { name, parts })
    }
}

impl Quantizer for Composed {
    fn name(&self) -> &str {
        &self.name
    }

    fn requirements(&self) -> Requirements {
        self.parts
            .iter()
            .fold(Requirements::none(), |acc, p| acc.union(p.requirements()))
    }

    fn preprocess(&self, ctx: &mut LayerContext) -> Result<()> {
        for p in &self.parts {
            p.preprocess(ctx)?;
        }
        Ok(())
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        self.parts
            .last()
            .expect("composition is non-empty")
            .quantize_block(ctx)
    }
}

/// Tunables threaded to plugin constructors at resolve time.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantizerParams {
    pub gptq: gptq::GptqParams,
    pub smooth: smoothquant::SmoothParams,
}

/// One registry row: a buildable, documented plugin.
pub struct Registration {
    pub name: &'static str,
    pub summary: &'static str,
    pub build: fn(&QuantizerParams) -> Box<dyn Quantizer>,
}

fn build_rtn(_p: &QuantizerParams) -> Box<dyn Quantizer> {
    Box::new(rtn::RtnQuantizer)
}

fn build_gptq(p: &QuantizerParams) -> Box<dyn Quantizer> {
    Box::new(gptq::GptqQuantizer { params: p.gptq })
}

fn build_smoothquant(p: &QuantizerParams) -> Box<dyn Quantizer> {
    Box::new(smoothquant::SmoothQuantizer { params: p.smooth })
}

fn build_awq(_p: &QuantizerParams) -> Box<dyn Quantizer> {
    Box::new(awq::AwqQuantizer)
}

fn build_omniquant(_p: &QuantizerParams) -> Box<dyn Quantizer> {
    Box::new(omniquant::OmniQuantizer)
}

/// The built-in plugins. Adding a method is one new row here.
pub const REGISTRY: &[Registration] = &[
    Registration {
        name: "rtn",
        summary: "round-to-nearest symmetric (the baseline primitive)",
        build: build_rtn,
    },
    Registration {
        name: "gptq",
        summary: "Hessian-based OBS reconstruction (Frantar et al. 2022)",
        build: build_gptq,
    },
    Registration {
        name: "smoothquant",
        summary: "activation-outlier migration into the preceding norm (W+A)",
        build: build_smoothquant,
    },
    Registration {
        name: "awq",
        summary: "activation-aware weight scaling, grid-searched per layer",
        build: build_awq,
    },
    Registration {
        name: "omniquant",
        summary: "grid-searched per-channel weight clipping (LWC-lite)",
        build: build_omniquant,
    },
];

/// All registered plugins.
pub fn registry() -> &'static [Registration] {
    REGISTRY
}

/// Registered plugin names, in registry order.
pub fn registered_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|r| r.name).collect()
}

/// Resolve a method spec (`"gptq"`, `"smoothquant+gptq"`, ...) into a
/// runnable plugin. Unknown names error with the registered list.
pub fn resolve(spec: &str, params: &QuantizerParams) -> Result<Box<dyn Quantizer>> {
    let mut parts: Vec<Box<dyn Quantizer>> = Vec::new();
    for raw in spec.split('+') {
        let name = raw.trim();
        if name.is_empty() {
            return Err(Error::Config(format!(
                "empty stage in quantizer spec `{spec}` (compose as `smoothquant+gptq`)"
            )));
        }
        let reg = REGISTRY.iter().find(|r| r.name == name).ok_or_else(|| {
            Error::Config(format!(
                "unknown quantizer `{name}` (registered: {}); compose with `+`, \
                 e.g. `smoothquant+gptq`",
                registered_names().join(", ")
            ))
        })?;
        parts.push((reg.build)(params));
    }
    if parts.len() > 1 {
        return Ok(Box::new(Composed::new(parts)?));
    }
    // the stage loop above pushed at least one quantizer or errored
    parts.pop().ok_or_else(|| {
        Error::Config(format!(
            "empty quantizer spec `{spec}` (compose as `smoothquant+gptq`)"
        ))
    })
}

/// Validate a spec and return its canonical name (used by `Config::method`).
pub fn validate_spec(spec: &str) -> Result<String> {
    let q = resolve(spec, &QuantizerParams::default())?;
    Ok(q.name().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(d: usize, ff: usize) -> (Vec<Tensor>, Vec<Tensor>) {
        // owned (weights+norms, taps); tests borrow a BlockWeights from it
        let weights = vec![
            Tensor::ones(&[d]),                   // ln1_g
            Tensor::zeros(&[d]),                  // ln1_b
            Tensor::randn(&[d, 3 * d], 1, 0.5),   // wqkv
            Tensor::zeros(&[3 * d]),              // bqkv
            Tensor::randn(&[d, d], 2, 0.5),       // wproj
            Tensor::zeros(&[d]),                  // bproj
            Tensor::ones(&[d]),                   // ln2_g
            Tensor::zeros(&[d]),                  // ln2_b
            Tensor::randn(&[d, ff], 3, 0.5),      // wfc1
            Tensor::zeros(&[ff]),                 // bfc1
            Tensor::randn(&[ff, d], 4, 0.5),      // wfc2
            Tensor::zeros(&[d]),                  // bfc2
        ];
        let taps = vec![
            Tensor::randn(&[8, d], 11, 1.0),
            Tensor::randn(&[8, d], 12, 1.0),
            Tensor::randn(&[8, d], 13, 1.0),
            Tensor::randn(&[8, ff], 14, 1.0),
        ];
        (weights, taps)
    }

    fn block_view(w: &[Tensor]) -> BlockWeights<'_> {
        BlockWeights {
            ln1_g: &w[0],
            ln1_b: Some(&w[1]),
            wqkv: &w[2],
            bqkv: &w[3],
            wproj: &w[4],
            bproj: &w[5],
            ln2_g: &w[6],
            ln2_b: Some(&w[7]),
            wfc1: &w[8],
            bfc1: &w[9],
            wfc2: &w[10],
            bfc2: &w[11],
        }
    }

    #[test]
    fn resolve_known_and_composed() {
        let p = QuantizerParams::default();
        assert_eq!(resolve("gptq", &p).unwrap().name(), "gptq");
        let c = resolve("smoothquant+gptq", &p).unwrap();
        assert_eq!(c.name(), "smoothquant+gptq");
        let req = c.requirements();
        assert!(req.hessians && req.act_taps);
    }

    #[test]
    fn resolve_rejects_unknown_and_empty() {
        let p = QuantizerParams::default();
        assert!(resolve("zap", &p).is_err());
        assert!(resolve("gptq+zap", &p).is_err());
        assert!(resolve("", &p).is_err());
        assert!(resolve("gptq+", &p).is_err());
        let msg = format!("{}", resolve("zap", &p).unwrap_err());
        assert!(msg.contains("rtn") && msg.contains("gptq"), "{msg}");
    }

    #[test]
    fn validate_spec_canonicalizes() {
        assert_eq!(validate_spec(" smoothquant + gptq ").unwrap(), "smoothquant+gptq");
        assert!(validate_spec("nope").is_err());
    }

    #[test]
    fn fold_rejects_non_norm_fed() {
        let (w, taps) = fixture(8, 16);
        let mut ctx = LayerContext::with_static_taps(
            block_view(&w),
            taps,
            QuantScheme::w4_perchannel(),
        );
        let s = vec![2.0f32; 8];
        assert!(ctx.fold_input_scales(Linear::Proj, &s).is_err());
        assert!(ctx.fold_input_scales(Linear::Qkv, &s).is_ok());
        assert_eq!(ctx.norms().ln1_g.as_f32().unwrap()[0], 0.5);
        assert_eq!(ctx.input_scales(Linear::Qkv).unwrap()[0], 2.0);
    }

    #[test]
    fn fold_rescales_taps_and_hessian() {
        let (w, taps) = fixture(8, 16);
        let raw0 = taps[0].as_f32().unwrap()[0];
        let mut ctx = LayerContext::with_static_taps(
            block_view(&w),
            taps,
            QuantScheme::w4_perchannel(),
        );
        let s = vec![4.0f32; 8];
        ctx.fold_input_scales(Linear::Qkv, &s).unwrap();
        let tap = ctx.tap(Linear::Qkv).unwrap();
        assert!((tap.as_f32().unwrap()[0] - raw0 / 4.0).abs() < 1e-6);
        // Hessian of scaled inputs shrinks by s² = 16
        let h = ctx.take_hessian(Linear::Qkv).unwrap();
        assert_eq!(h.k, 8);
        assert!(ctx.hessians_used() && ctx.taps_used());
    }

    #[test]
    fn usage_flags_start_clean_and_track() {
        let (w, taps) = fixture(8, 16);
        let mut ctx = LayerContext::with_static_taps(
            block_view(&w),
            taps,
            QuantScheme::w4_perchannel(),
        );
        assert!(!ctx.taps_used() && !ctx.hessians_used());
        ctx.take_hessian(Linear::Fc2).unwrap();
        // hessian consumption must not count as tap consumption
        assert!(ctx.hessians_used() && !ctx.taps_used());
    }

    #[test]
    fn weight_override_shadows_checkpoint_view() {
        let (w, taps) = fixture(8, 16);
        let mut ctx = LayerContext::with_static_taps(
            block_view(&w),
            taps,
            QuantScheme::w4_perchannel(),
        );
        let orig = ctx.weight(Linear::Qkv).clone();
        ctx.set_weight(Linear::Qkv, Tensor::zeros(&[8, 24]));
        assert_ne!(ctx.weight(Linear::Qkv), &orig);
        assert_eq!(ctx.weight(Linear::Qkv).as_f32().unwrap()[0], 0.0);
    }

    #[test]
    fn registry_names_are_unique() {
        let names = registered_names();
        for (i, a) in names.iter().enumerate() {
            for b in &names[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }
}

//! AWQ-lite (Lin et al. 2023): activation-aware per-channel weight scaling.
//!
//! AWQ's observation: the ~1% of weight channels fed by high-magnitude
//! activations matter most; scaling those channels up before quantization
//! (and folding the inverse into the activations) protects them.  We
//! implement the grid-searched power-law variant: `s_j = amax_j^α`, α swept
//! on a small grid against the layer reconstruction error on calibration
//! activations — the Table-10 "AWQ" comparison row.

use crate::error::Result;
use crate::tensor::Tensor;

use super::quantizer::{rtn_block, BlockQuant, LayerContext, Linear, Quantizer, Requirements};
use super::smoothquant::ActStats;
use super::{rtn, QuantScheme, QuantizedWeight};

/// AWQ-lite as a registry plugin. The grid search runs in preprocess: pick
/// the best per-channel scaling on the norm-fed linears, install the scaled
/// weight, fold `1/s` into the preceding norm. The terminal RTN then
/// reproduces the searched quantization exactly — and any composed terminal
/// (`awq+gptq`) reconstructs the same scaled weights instead.
pub struct AwqQuantizer;

impl Quantizer for AwqQuantizer {
    fn name(&self) -> &str {
        "awq"
    }

    fn requirements(&self) -> Requirements {
        Requirements { hessians: false, act_taps: true }
    }

    fn preprocess(&self, ctx: &mut LayerContext) -> Result<()> {
        for lin in [Linear::Qkv, Linear::Fc1] {
            let flat = ctx.tap(lin)?;
            let k = flat.shape[1];
            let mut stats = ActStats::new(k);
            stats.update(&flat)?;
            // subsample rows for the grid-search objective
            let rows = flat.shape[0].min(64);
            let sample = Tensor::f32(&[rows, k], flat.as_f32()?[..rows * k].to_vec());
            let r = quantize(ctx.weight(lin), &stats, &sample, &ctx.scheme)?;
            ctx.set_weight(lin, r.scaled_w);
            ctx.fold_input_scales(lin, &r.in_scales)?;
        }
        Ok(())
    }

    fn quantize_block(&self, ctx: &mut LayerContext) -> Result<BlockQuant> {
        rtn_block(ctx)
    }
}

/// Grid of migration strengths searched per layer (AWQ reference uses 20
/// points in [0,1]; 8 is enough at our scale).
pub const ALPHA_GRID: &[f32] = &[0.0, 0.15, 0.3, 0.45, 0.6, 0.75, 0.9, 1.0];

/// Result: the quantized weight *plus* the input-channel scales the runtime
/// must fold into the preceding op (same contract as SmoothQuant), and the
/// scaled float weight the search quantized (so callers composing AWQ as a
/// preprocess stage reuse it instead of rescaling).
#[derive(Debug, Clone)]
pub struct AwqResult {
    pub qw: QuantizedWeight,
    pub in_scales: Vec<f32>,
    pub alpha: f32,
    pub scaled_w: Tensor,
}

/// Quantize with the best activation-aware scaling found on the grid.
///
/// `x_sample` is a [rows, K] calibration activation slice used to score
/// reconstruction error `|| x W - x' Q ||²`.
pub fn quantize(
    w: &Tensor,
    act: &ActStats,
    x_sample: &Tensor,
    scheme: &QuantScheme,
) -> Result<AwqResult> {
    let k = w.shape[0];
    let n = w.shape[1];
    let wv = w.as_f32()?;
    let xv = x_sample.as_f32()?;
    let rows = x_sample.shape[0];

    let mut best: Option<AwqResult> = None;
    let mut best_err = f64::INFINITY;

    for &alpha in ALPHA_GRID {
        // s_j = amax_j^alpha, normalized so mean(s) == 1 (keeps scale sane)
        let mut s: Vec<f32> = act
            .amax
            .iter()
            .map(|&a| a.max(1e-5).powf(alpha))
            .collect();
        let mean = s.iter().sum::<f32>() / k as f32;
        for v in s.iter_mut() {
            *v /= mean;
            *v = v.max(1e-4);
        }

        // scaled weight
        let mut ws = vec![0.0f32; k * n];
        for j in 0..k {
            for col in 0..n {
                ws[j * n + col] = wv[j * n + col] * s[j];
            }
        }
        let scaled_w = Tensor::f32(&[k, n], ws);
        let qw = rtn::quantize(&scaled_w, scheme)?;
        let deq = qw.dequantize();

        // reconstruction error on the sample: x@W vs (x/s)@deq
        let mut err = 0.0f64;
        for r in 0..rows {
            let xrow = &xv[r * k..(r + 1) * k];
            for col in 0..n {
                let mut y0 = 0.0f64;
                let mut y1 = 0.0f64;
                for j in 0..k {
                    y0 += xrow[j] as f64 * wv[j * n + col] as f64;
                    y1 += (xrow[j] / s[j]) as f64 * deq[j * n + col] as f64;
                }
                let d = y0 - y1;
                err += d * d;
            }
        }
        if err < best_err {
            best_err = err;
            best = Some(AwqResult { qw, in_scales: s, alpha, scaled_w });
        }
    }
    Ok(best.expect("non-empty grid"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outlier_setup() -> (Tensor, ActStats, Tensor) {
        // channel 0 carries big activations
        let k = 16;
        let n = 8;
        let w = Tensor::randn(&[k, n], 3, 1.0);
        let mut xv = Tensor::randn(&[32, k], 4, 0.5).as_f32().unwrap().to_vec();
        for r in 0..32 {
            xv[r * k] *= 20.0;
        }
        let x = Tensor::f32(&[32, k], xv);
        let mut st = ActStats::new(k);
        st.update(&x).unwrap();
        (w, st, x)
    }

    #[test]
    fn picks_nonzero_alpha_for_outliers() {
        let (w, st, x) = outlier_setup();
        let r = quantize(&w, &st, &x, &QuantScheme::w2_g64()).unwrap();
        assert!(r.alpha > 0.0, "should protect outlier channels");
        assert!(r.in_scales[0] > r.in_scales[1]);
    }

    #[test]
    fn beats_plain_rtn_on_outliers() {
        let (w, st, x) = outlier_setup();
        let scheme = QuantScheme { bits: 2, group_size: Some(16) };
        let awq = quantize(&w, &st, &x, &scheme).unwrap();
        let plain = rtn::quantize(&w, &scheme).unwrap();

        let err = |deq: &[f32], s: Option<&[f32]>| -> f64 {
            let xv = x.as_f32().unwrap();
            let wv = w.as_f32().unwrap();
            let (k, n) = (16, 8);
            let mut e = 0.0f64;
            for r in 0..32 {
                for col in 0..n {
                    let mut y0 = 0.0f64;
                    let mut y1 = 0.0f64;
                    for j in 0..k {
                        y0 += xv[r * k + j] as f64 * wv[j * n + col] as f64;
                        let xs = match s {
                            Some(sv) => xv[r * k + j] / sv[j],
                            None => xv[r * k + j],
                        };
                        y1 += xs as f64 * deq[j * n + col] as f64;
                    }
                    e += (y0 - y1) * (y0 - y1);
                }
            }
            e
        };
        let e_awq = err(&awq.qw.dequantize(), Some(&awq.in_scales));
        let e_rtn = err(&plain.dequantize(), None);
        assert!(e_awq < e_rtn, "awq {e_awq:.3} vs rtn {e_rtn:.3}");
    }
}

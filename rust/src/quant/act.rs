//! Activation fake-quantization — the "A8"/"A4" half of W4A8 / W4A4 modes.
//!
//! Per-tensor dynamic symmetric quantization of activations, applied between
//! layers by the coordinator when a joint weight+activation mode is active
//! (Table 4's SmoothQuant rows and Table 10's W4A4 row).  Fake-quant
//! (quantize→dequantize in f32) matches what the paper's evaluation measures:
//! accuracy under the quantized numerics, independent of kernel dtype.

// Justified unwraps: fake-quant inputs are rank-checked by the callers
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use crate::error::Result;
use crate::tensor::Tensor;

/// Fake-quantize a tensor to `bits` with one symmetric per-tensor scale.
pub fn fake_quant_tensor(x: &Tensor, bits: u8) -> Result<Tensor> {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let v = x.as_f32()?;
    let amax = v.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    if amax == 0.0 {
        return Ok(x.clone());
    }
    let scale = amax / qmax;
    let out: Vec<f32> = v
        .iter()
        .map(|&x| (x / scale).round().clamp(-qmax, qmax) * scale)
        .collect();
    Ok(Tensor { shape: x.shape.clone(), data: crate::tensor::Tensor::f32(&x.shape, out).data })
}

/// Fake-quantize per row (token) — the dynamic per-token scheme SmoothQuant
/// deploys for activations.
pub fn fake_quant_per_row(x: &Tensor, bits: u8) -> Result<Tensor> {
    let c = *x.shape.last().unwrap();
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let v = x.as_f32()?;
    let mut out = vec![0.0f32; v.len()];
    for (orow, irow) in out.chunks_mut(c).zip(v.chunks_exact(c)) {
        let amax = irow.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        if amax == 0.0 {
            continue;
        }
        let scale = amax / qmax;
        for (o, &i) in orow.iter_mut().zip(irow) {
            *o = (i / scale).round().clamp(-qmax, qmax) * scale;
        }
    }
    Ok(Tensor::f32(&x.shape, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a8_error_small() {
        let x = Tensor::randn(&[16, 32], 2, 1.0);
        let q = fake_quant_tensor(&x, 8).unwrap();
        let amax = x.as_f32().unwrap().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let step = amax / 127.0;
        for (a, b) in x.as_f32().unwrap().iter().zip(q.as_f32().unwrap()) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6);
        }
    }

    #[test]
    fn per_row_scales_independently() {
        // row 2 has a big outlier; per-row quant keeps row 1 precise
        let x = Tensor::f32(&[2, 2], vec![0.1, 0.2, 100.0, 0.2]);
        let qt = fake_quant_tensor(&x, 4).unwrap();
        let qr = fake_quant_per_row(&x, 4).unwrap();
        let et = (qt.as_f32().unwrap()[0] - 0.1).abs();
        let er = (qr.as_f32().unwrap()[0] - 0.1).abs();
        assert!(er < et, "per-row {er} should beat per-tensor {et}");
    }

    #[test]
    fn zero_tensor_passthrough() {
        let x = Tensor::zeros(&[4, 4]);
        assert_eq!(fake_quant_tensor(&x, 8).unwrap(), x);
        assert_eq!(fake_quant_per_row(&x, 8).unwrap(), x);
    }
}

//! # normtweak
//!
//! Reproduction of **"Norm Tweaking: High-Performance Low-Bit Quantization of
//! Large Language Models"** (AAAI 2024) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`):
//!   dequant-matmul, channel stats, fused norms.
//! * **L2** — JAX graphs (`python/compile/model.py`), AOT-lowered to HLO text
//!   artifacts consumed by the Rust runtime.
//! * **L3** — this crate: the quantization pipeline coordinator (Algorithm 1
//!   of the paper), the open `Quantizer` plugin registry (RTN / GPTQ /
//!   SmoothQuant / AWQ-lite / OmniQuant-lite, plus `+`-compositions like
//!   `smoothquant+gptq` — see `quant::quantizer`), calibration-data
//!   generation, the norm-tweak engine, the sensitivity-driven
//!   mixed-precision policy (`policy`), the evaluation harness, and the
//!   multi-model serving engine (`engine`: scheduler, sessions,
//!   cancellation, warm-up — `serve` remains as a deprecated shim).
//!
//! Python never runs on the request path: `make artifacts` lowers all compute
//! graphs once; the Rust binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment index.

// Library code must surface failures as `Error` values with provenance, not
// panic: `unwrap()` is warned crate-wide (tests keep their unwraps — a panic
// *is* the failure report there).  Files that still carry justified unwraps
// opt out locally with a file-level `#![allow]` + rationale.
#![warn(clippy::unwrap_used)]
#![cfg_attr(test, allow(clippy::unwrap_used))]

pub mod analysis;
pub mod calib;
pub mod config;
pub mod util;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod eval;
pub mod model;
pub mod obs;
pub mod policy;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod tensor;
pub mod tweak;

pub use config::Config;
pub use error::{Error, Result};

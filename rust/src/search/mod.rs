//! Enumerative recipe search: candidates → staged pruning → escalated
//! scoring → a replayable `recipe.json`.
//!
//! Picking a deployment configuration by hand means juggling four coupled
//! axes — quantizer method, group grain, per-layer bit widths, and the
//! norm-tweak hyper-parameters — whose interactions the paper's ablations
//! show are not separable (Table 9's loss choice changes the best lr;
//! grain changes which layers are fragile).  This subsystem turns that
//! into a budgeted search with an auditable artifact:
//!
//! ```text
//!   SpaceConfig ──enumerate──▶ candidates (method × grain × tweak point)
//!        │
//!        ▼ stage 0  (profile table only — free)
//!   prune grains the SensitivityProfile never measured;
//!   plan per-layer widths per grain (BitBudgetPlanner @ target_bits);
//!   stage-0 score = Σ profile score at the allocated widths
//!        │
//!        ▼ stage 1  (trial quantization — CPU, no runtime)
//!   top-`budget` (method, grain) groups re-scored with the *real*
//!   quantizer on seeded synthetic taps (`tweak::loss` kernels);
//!   SearchState checkpointed after every group → kill-safe resume
//!        │
//!        ▼ stage 2  (optional `--ppl`: the only model-executing stage)
//!   the winning group's tweak-grid points ranked by held-out perplexity
//!        │
//!        ▼
//!   Recipe { winner, BitPlan, provenance, scored frontier } → recipe.json
//! ```
//!
//! # Space grammar
//!
//! [`SpaceConfig`] holds the three enumerated axes; candidate ids are
//! dense indexes in `methods × grains × tweak_grid` declaration order, and
//! that order is load-bearing: pruning tie-breaks, checkpoint resume, and
//! the recipe frontier all key on it.  The width axis is *planned*, not
//! enumerated — each grain gets one greedy allocation under
//! `target_bits`, so the space stays linear in the axis sizes.
//!
//! # Staging and escalation semantics
//!
//! The persisted profile is method-agnostic (it was measured with one
//! trial method), so stage 0 cannot separate methods.  The escalation
//! unit is therefore the `(method, grain)` **group**: `budget` counts
//! groups, groups are ranked by `(stage-0 score, lowest candidate id)`,
//! and raising the budget escalates a strict superset — a candidate that
//! survives at budget *N* survives at every larger budget (pruning
//! monotonicity, locked in by `tests/search_recipes.rs`).
//!
//! # Resume format
//!
//! [`SearchState`] (`normtweak.search-state.v1`) records the
//! `(space, seed)` fingerprint plus every finished group's stage-1 score,
//! and is rewritten after each trial.  `normtweak search --resume` (or a
//! re-run with the same `--out`) picks it up, refuses a fingerprint
//! mismatch, and re-runs only the unfinished groups; the final outcome is
//! identical to a never-interrupted run.
//!
//! # The recipe artifact
//!
//! [`Recipe`] (`normtweak.recipe.v1`) embeds the winner, its full
//! [`BitPlan`](crate::policy::BitPlan) (same `normtweak.plan.v1` shape
//! `plan --format json` prints), provenance (manifest hash, profile path
//! + content hash, exact space, seed, funnel counts), and the scored
//! frontier.  `quantize --recipe` replays it through the same
//! [`Recipe::to_pipeline_config`] the search used, and
//! `normtweak check --recipe` lints it against live artifacts (NT06xx —
//! see `crate::analysis`).

mod recipe;
mod runner;
mod space;

pub use recipe::{Recipe, RecipeProvenance, RECIPE_SCHEMA};
pub use runner::{
    CandidateStatus, Evaluator, FrontierEntry, PplFn, SearchConfig, SearchOutcome, SearchRunner,
    SearchState, SearchStats, STATE_SCHEMA,
};
pub use space::{
    default_tweak_grid, grain_group_size, tweak_from_json, tweak_to_json, Candidate, SpaceConfig,
};

//! The replayable search product: `recipe.json`.
//!
//! A [`Recipe`] is everything `quantize --recipe` needs to reproduce the
//! winning configuration bit-exactly — method, base scheme, tweak point,
//! the full per-layer allocation (embedded as a
//! [`BitPlan`](crate::policy::BitPlan) in the same
//! `normtweak.plan.v1` shape `plan --format json` prints) — plus the
//! provenance to audit or invalidate it: the manifest hash, the
//! sensitivity profile's path and content hash, the exact space and seed,
//! the stage funnel counts, and the scored frontier.  `normtweak check
//! --recipe` lints a recipe against live artifacts (NT06xx codes), so a
//! recipe written against last week's export fails loudly instead of
//! silently deploying a stale allocation.
//!
//! Both the search CLI and the replay path build their
//! [`PipelineConfig`] through [`Recipe::to_pipeline_config`], which is
//! what makes "replay is bit-exact" a structural guarantee rather than a
//! convention.

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::PipelineConfig;
use crate::error::{Error, Result};
use crate::policy::BitPlan;
use crate::quant::QuantScheme;
use crate::tweak::TweakConfig;
use crate::util::json::{arr, n, obj, s, Json};

use super::runner::{CandidateStatus, FrontierEntry, SearchStats};
use super::space::{grain_group_size, tweak_from_json, tweak_to_json, Candidate, SpaceConfig};

/// Schema tag for [`Recipe::to_json`].
pub const RECIPE_SCHEMA: &str = "normtweak.recipe.v1";

/// Everything needed to audit (or reject) a recipe later.
#[derive(Debug, Clone, PartialEq)]
pub struct RecipeProvenance {
    /// FNV-1a hex of `manifest.json` at search time (None when the search
    /// ran without artifacts — pure-profile offline mode).
    pub manifest_hash: Option<String>,
    /// Path of the sensitivity profile the search planned from, as given.
    pub profile_path: String,
    /// FNV-1a hex of the profile file's bytes at search time.
    pub profile_hash: String,
    /// The exact space that was enumerated.
    pub space: SpaceConfig,
    pub seed: u64,
    pub budget: usize,
    pub stats: SearchStats,
}

impl RecipeProvenance {
    fn to_json(&self) -> Json {
        obj(vec![
            (
                "manifest_hash",
                self.manifest_hash
                    .as_ref()
                    .map_or(Json::Null, |h| s(h.clone())),
            ),
            ("profile_path", s(self.profile_path.clone())),
            ("profile_hash", s(self.profile_hash.clone())),
            ("space", self.space.to_json()),
            ("seed", n(self.seed as f64)),
            ("budget", n(self.budget as f64)),
            (
                "stages",
                obj(vec![
                    ("enumerated", n(self.stats.enumerated as f64)),
                    ("pruned", n(self.stats.pruned as f64)),
                    ("escalated", n(self.stats.escalated as f64)),
                    ("scored", n(self.stats.scored as f64)),
                ]),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let bad = |m: &str| Error::Json(format!("recipe provenance: {m}"));
        let manifest_hash = match j.get("manifest_hash") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .map(String::from)
                    .ok_or_else(|| bad("`manifest_hash` must be a string or null"))?,
            ),
        };
        let get_str = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| bad(&format!("missing `{k}`")))
        };
        let get_count = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad(&format!("missing stage count `{k}`")))
        };
        let stages = j.get("stages").ok_or_else(|| bad("missing `stages`"))?;
        Ok(RecipeProvenance {
            manifest_hash,
            profile_path: get_str("profile_path")?,
            profile_hash: get_str("profile_hash")?,
            space: SpaceConfig::from_json(
                j.get("space").ok_or_else(|| bad("missing `space`"))?,
            )?,
            seed: j
                .get("seed")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad("missing `seed`"))? as u64,
            budget: j
                .get("budget")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad("missing `budget`"))?,
            stats: SearchStats {
                enumerated: get_count(stages, "enumerated")?,
                pruned: get_count(stages, "pruned")?,
                escalated: get_count(stages, "escalated")?,
                scored: get_count(stages, "scored")?,
            },
        })
    }
}

/// The persisted search product.
#[derive(Debug, Clone, PartialEq)]
pub struct Recipe {
    /// Model the recipe was searched for (checked against the checkpoint
    /// at replay: NT0603).
    pub model: String,
    pub method: String,
    /// Base scheme: the winning grain at the plan's smallest allocated
    /// width (every layer is overridden by `plan` anyway).
    pub scheme: QuantScheme,
    /// `None` = plain PTQ.
    pub tweak: Option<TweakConfig>,
    pub plan: BitPlan,
    pub provenance: RecipeProvenance,
    pub frontier: Vec<FrontierEntry>,
}

impl Recipe {
    /// The one way a recipe becomes a [`PipelineConfig`] — used by both
    /// the search CLI (to print/run what it found) and `quantize --recipe`
    /// (to replay it), so the two cannot drift.
    pub fn to_pipeline_config(&self) -> Result<PipelineConfig> {
        let mut cfg = PipelineConfig::new(&self.method, self.scheme);
        if let Some(t) = self.tweak {
            cfg = cfg.with_tweak(t);
        }
        for (&layer, &scheme) in &self.plan.schemes {
            cfg = cfg.with_layer_scheme(layer, scheme);
        }
        Ok(cfg.with_plan_note(format!(
            "recipe seed={} profile={} ({})",
            self.provenance.seed, self.provenance.profile_path, self.plan.provenance
        )))
    }

    /// The winning grain tag.
    pub fn group_tag(&self) -> String {
        self.scheme.group_tag()
    }

    pub fn to_json(&self) -> Json {
        let frontier = self
            .frontier
            .iter()
            .map(|e| {
                obj(vec![
                    ("id", n(e.candidate.id as f64)),
                    ("method", s(e.candidate.method.clone())),
                    ("grain", s(e.candidate.grain.clone())),
                    ("tweak", tweak_to_json(&e.candidate.tweak)),
                    ("status", s(e.status.as_str())),
                    ("stage0", e.stage0.map_or(Json::Null, |v| n(f64::from(v)))),
                    ("stage1", e.stage1.map_or(Json::Null, |v| n(f64::from(v)))),
                    ("stage2", e.stage2.map_or(Json::Null, |v| n(f64::from(v)))),
                ])
            })
            .collect();
        obj(vec![
            ("schema", s(RECIPE_SCHEMA)),
            ("model", s(self.model.clone())),
            ("method", s(self.method.clone())),
            (
                "scheme",
                obj(vec![
                    ("bits", n(f64::from(self.scheme.bits))),
                    (
                        "group",
                        self.scheme.group_size.map_or(Json::Null, |g| n(g as f64)),
                    ),
                ]),
            ),
            ("tweak", tweak_to_json(&self.tweak)),
            ("plan", self.plan.to_json()),
            ("provenance", self.provenance.to_json()),
            ("frontier", arr(frontier)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |m: &str| Error::Json(format!("recipe: {m}"));
        match j.get("schema").and_then(|v| v.as_str()) {
            Some(RECIPE_SCHEMA) => {}
            other => {
                return Err(bad(&format!(
                    "schema `{}` (expected `{RECIPE_SCHEMA}`)",
                    other.unwrap_or("<missing>")
                )))
            }
        }
        let get_str = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| bad(&format!("missing `{k}`")))
        };
        let sj = j.get("scheme").ok_or_else(|| bad("missing `scheme`"))?;
        let bits = sj
            .get("bits")
            .and_then(|v| v.as_usize())
            .filter(|&b| b > 0 && b <= u8::MAX as usize)
            .ok_or_else(|| bad("bad `scheme.bits`"))? as u8;
        let group_size = match sj.get("group") {
            None | Some(Json::Null) => None,
            Some(g) => Some(g.as_usize().ok_or_else(|| bad("bad `scheme.group`"))?),
        };
        let scheme = QuantScheme { bits, group_size };
        let tweak = tweak_from_json(j.get("tweak").unwrap_or(&Json::Null))?;
        let plan = BitPlan::from_json(j.get("plan").ok_or_else(|| bad("missing `plan`"))?)?;
        let provenance = RecipeProvenance::from_json(
            j.get("provenance").ok_or_else(|| bad("missing `provenance`"))?,
        )?;
        let mut frontier = Vec::new();
        for fj in j
            .get("frontier")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing `frontier` array"))?
        {
            let fbad = |m: &str| Error::Json(format!("recipe frontier entry: {m}"));
            let grain = fj
                .get("grain")
                .and_then(|v| v.as_str())
                .ok_or_else(|| fbad("missing `grain`"))?
                .to_string();
            grain_group_size(&grain)?;
            let opt_score = |k: &str| -> Result<Option<f32>> {
                match fj.get(k) {
                    None | Some(Json::Null) => Ok(None),
                    Some(v) => Ok(Some(
                        v.as_f64().ok_or_else(|| fbad(&format!("bad `{k}`")))? as f32,
                    )),
                }
            };
            frontier.push(FrontierEntry {
                candidate: Candidate {
                    id: fj
                        .get("id")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| fbad("missing `id`"))?,
                    method: fj
                        .get("method")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| fbad("missing `method`"))?
                        .to_string(),
                    grain,
                    tweak: tweak_from_json(fj.get("tweak").unwrap_or(&Json::Null))?,
                },
                status: CandidateStatus::from_str(
                    fj.get("status")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| fbad("missing `status`"))?,
                )?,
                stage0: opt_score("stage0")?,
                stage1: opt_score("stage1")?,
                stage2: opt_score("stage2")?,
            });
        }
        let recipe = Recipe {
            model: get_str("model")?,
            method: get_str("method")?,
            scheme,
            tweak,
            plan,
            provenance,
            frontier,
        };
        recipe.validate()?;
        Ok(recipe)
    }

    /// Internal consistency: the base scheme's grain must match every
    /// plan layer (the pipeline would reject the mismatch anyway, but a
    /// recipe should fail at load, not at replay).
    pub fn validate(&self) -> Result<()> {
        self.scheme.pack_bits()?;
        let tag = self.scheme.group_tag();
        for (layer, s) in &self.plan.schemes {
            if s.group_tag() != tag {
                return Err(Error::Json(format!(
                    "recipe: plan layer {layer} grain {} != winning grain {tag}",
                    s.group_tag()
                )));
            }
            s.pack_bits()?;
        }
        Ok(())
    }

    /// Per-layer scheme map as stable JSON — what `quantize --recipe
    /// --dry-run` prints, and what the CI smoke compares against the
    /// recipe's own `plan.layers`.
    pub fn layer_map_json(&self) -> Json {
        let cfg_layers: BTreeMap<String, Json> = self
            .plan
            .schemes
            .iter()
            .map(|(l, sch)| {
                (
                    l.to_string(),
                    obj(vec![
                        ("bits", n(f64::from(sch.bits))),
                        ("group", sch.group_size.map_or(Json::Null, |g| n(g as f64))),
                    ]),
                )
            })
            .collect();
        obj(vec![
            ("model", s(self.model.clone())),
            ("method", s(self.method.clone())),
            ("tweak", tweak_to_json(&self.tweak)),
            ("layers", Json::Obj(cfg_layers)),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().emit())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap as Map;

    fn recipe() -> Recipe {
        let mut schemes = Map::new();
        schemes.insert(0usize, QuantScheme { bits: 4, group_size: Some(64) });
        schemes.insert(1usize, QuantScheme { bits: 2, group_size: Some(64) });
        Recipe {
            model: "nt-tiny".into(),
            method: "gptq".into(),
            scheme: QuantScheme { bits: 2, group_size: Some(64) },
            tweak: Some(TweakConfig::default()),
            plan: BitPlan {
                schemes,
                mean_bits: 3.0,
                target_bits: 3.0,
                provenance: "model=nt-tiny method=gptq grain=g64 calib=gen-v2 loss=dist".into(),
            },
            provenance: RecipeProvenance {
                manifest_hash: Some("cbf29ce484222325".into()),
                profile_path: "sensitivity.json".into(),
                profile_hash: "af63dc4c8601ec8c".into(),
                space: SpaceConfig {
                    methods: vec!["rtn".into(), "gptq".into()],
                    grains: vec!["g64".into()],
                    tweak_grid: vec![Some(TweakConfig::default()), None],
                    target_bits: 3.0,
                },
                seed: 7,
                budget: 2,
                stats: SearchStats { enumerated: 4, pruned: 0, escalated: 2, scored: 0 },
            },
            frontier: vec![FrontierEntry {
                candidate: Candidate {
                    id: 2,
                    method: "gptq".into(),
                    grain: "g64".into(),
                    tweak: Some(TweakConfig::default()),
                },
                status: CandidateStatus::Escalated,
                stage0: Some(1.5),
                stage1: Some(0.75),
                stage2: None,
            }],
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let r = recipe();
        let back = Recipe::from_json(&Json::parse(&r.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(
            r.to_json().get("schema").and_then(|v| v.as_str()),
            Some(RECIPE_SCHEMA)
        );
    }

    #[test]
    fn disk_round_trip_and_replay_config() {
        let dir = std::env::temp_dir().join("nt_recipe_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("recipe.json");
        let r = recipe();
        r.save(&path).unwrap();
        let back = Recipe::load(&path).unwrap();
        assert_eq!(back, r);
        // replay config matches the search-side config field for field
        let a = r.to_pipeline_config().unwrap();
        let b = back.to_pipeline_config().unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.method, "gptq");
        assert_eq!(a.scheme_for(0).bits, 4);
        assert_eq!(a.scheme_for(1).bits, 2);
        assert!(a.tweak.is_some());
        assert!(a.plan_note.as_deref().unwrap_or("").contains("recipe"));
        a.validate(2).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn grain_drift_inside_the_recipe_is_rejected() {
        let mut r = recipe();
        r.plan
            .schemes
            .insert(1, QuantScheme { bits: 2, group_size: None });
        let err = Recipe::from_json(&Json::parse(&r.to_json().emit()).unwrap()).unwrap_err();
        assert!(format!("{err}").contains("grain"), "{err}");
    }

    #[test]
    fn unknown_schema_and_malformed_fields_fail_loudly() {
        assert!(Recipe::from_json(&Json::parse(r#"{"schema":"v0"}"#).unwrap()).is_err());
        let r = recipe();
        let txt = r.to_json().emit().replace("\"escalated\"", "\"esc\"");
        assert!(Recipe::from_json(&Json::parse(&txt).unwrap()).is_err());
    }

    #[test]
    fn layer_map_lists_every_planned_layer() {
        let m = recipe().layer_map_json();
        let layers = m.get("layers").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(
            layers["0"].get("bits").and_then(|v| v.as_usize()),
            Some(4)
        );
    }
}

//! The enumerable decision space: which (method, grain, tweak) assignments
//! the search considers.
//!
//! A [`SpaceConfig`] is a cartesian grammar over three axes:
//!
//! * **method** — quantizer specs resolved through the plugin registry
//!   (`quant::quantizer::REGISTRY`), including `+`-compositions;
//! * **grain** — group tags (`pc`, `g64`, ...) taken from the manifest's
//!   exported grain table (a grain the AOT export never compiled cannot be
//!   deployed, so it is never enumerated);
//! * **tweak** — norm-tweaking hyper-parameter points
//!   (`Option<TweakConfig>`, `None` = plain PTQ), normally built around the
//!   configured base with [`default_tweak_grid`].
//!
//! The per-layer **width** axis is not enumerated combinatorially: widths
//! come from the profiled candidate set through the greedy
//! [`BitBudgetPlanner`](crate::policy::BitBudgetPlanner) under the space's
//! `target_bits` budget, so each candidate resolves to one concrete
//! per-layer allocation instead of an exponential assignment family.
//!
//! Enumeration order is the artifact contract: methods × grains × tweak
//! points in declaration order, ids dense from 0.  Everything downstream
//! (pruning tie-breaks, resume, the recipe frontier) keys on that order,
//! which is why [`SpaceConfig`] round-trips through JSON and hashes
//! stably.

use crate::error::{Error, Result};
use crate::quant::quantizer::validate_spec;
use crate::quant::QuantScheme;
use crate::tweak::{LossKind, TweakConfig};
use crate::util::hash::fnv1a_hex;
use crate::util::json::{arr, n, obj, s, Json};

/// One point of the tweak axis serialized (`None` = plain PTQ).
pub fn tweak_to_json(t: &Option<TweakConfig>) -> Json {
    match t {
        None => Json::Null,
        Some(t) => obj(vec![
            ("iters", n(t.iters as f64)),
            ("lr0", n(f64::from(t.lr0))),
            ("lr_scale", n(f64::from(t.lr_scale))),
            ("loss", s(t.loss.as_str())),
        ]),
    }
}

/// Inverse of [`tweak_to_json`].
pub fn tweak_from_json(j: &Json) -> Result<Option<TweakConfig>> {
    if matches!(j, Json::Null) {
        return Ok(None);
    }
    let bad = |m: &str| Error::Json(format!("tweak point: {m}"));
    let iters = j
        .get("iters")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| bad("missing `iters`"))?;
    let lr0 = j
        .get("lr0")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| bad("missing `lr0`"))? as f32;
    let lr_scale = j
        .get("lr_scale")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| bad("missing `lr_scale`"))? as f32;
    let loss = LossKind::from_str(
        j.get("loss")
            .and_then(|v| v.as_str())
            .ok_or_else(|| bad("missing `loss`"))?,
    )?;
    Ok(Some(TweakConfig { iters, lr0, lr_scale, loss }))
}

/// Parse a group tag back to a group size (`"pc"` → `None`, `"g64"` →
/// `Some(64)`).  Inverse of [`QuantScheme::group_tag`].
pub fn grain_group_size(tag: &str) -> Result<Option<usize>> {
    if tag == "pc" {
        return Ok(None);
    }
    tag.strip_prefix('g')
        .and_then(|d| d.parse::<usize>().ok())
        .filter(|&g| g > 0)
        .map(Some)
        .ok_or_else(|| {
            Error::Config(format!("bad grain tag `{tag}` (expected `pc` or `g<N>`)"))
        })
}

/// The default tweak grid around a base configuration: the base point
/// first (the offline tie-break prefers earlier points), a hotter learning
/// rate, a longer schedule, and plain PTQ last as the control arm.
pub fn default_tweak_grid(base: TweakConfig) -> Vec<Option<TweakConfig>> {
    vec![
        Some(base),
        Some(TweakConfig { lr0: base.lr0 * 3.0, ..base }),
        Some(TweakConfig { iters: base.iters * 2, ..base }),
        None,
    ]
}

/// The enumerable space definition.  Validated and then frozen: the id of
/// every candidate is a pure function of this struct.
#[derive(Debug, Clone, PartialEq)]
pub struct SpaceConfig {
    /// Quantizer specs, in enumeration order.
    pub methods: Vec<String>,
    /// Exported group tags, in enumeration order.
    pub grains: Vec<String>,
    /// Tweak axis points, in enumeration order (`None` = plain PTQ).
    pub tweak_grid: Vec<Option<TweakConfig>>,
    /// Mean-bits budget handed to the planner per candidate.
    pub target_bits: f32,
}

/// One enumerated assignment: a method, a grain, and a tweak point.  The
/// per-layer widths are attached later by the planner (stage 0).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Dense enumeration index — the stable identity used by pruning
    /// tie-breaks, checkpoints, and the recipe frontier.
    pub id: usize,
    pub method: String,
    /// Group tag (`pc`, `g64`, ...).
    pub grain: String,
    pub tweak: Option<TweakConfig>,
}

impl Candidate {
    /// The candidate's scheme at a given width.
    pub fn scheme(&self, bits: u8) -> Result<QuantScheme> {
        Ok(QuantScheme { bits, group_size: grain_group_size(&self.grain)? })
    }
}

impl SpaceConfig {
    /// Reject a degenerate or unresolvable space before enumeration: every
    /// axis non-empty, every method registered, every grain tag parseable.
    pub fn validate(&self) -> Result<()> {
        if self.methods.is_empty() {
            return Err(Error::Config("search space has no methods".into()));
        }
        if self.grains.is_empty() {
            return Err(Error::Config("search space has no grains".into()));
        }
        if self.tweak_grid.is_empty() {
            return Err(Error::Config("search space has no tweak points".into()));
        }
        for m in &self.methods {
            validate_spec(m)?;
        }
        for g in &self.grains {
            grain_group_size(g)?;
        }
        if !self.target_bits.is_finite() || self.target_bits <= 0.0 {
            return Err(Error::Config(format!(
                "search space target_bits {} is not a positive number",
                self.target_bits
            )));
        }
        Ok(())
    }

    /// Deterministic enumeration: `methods × grains × tweak_grid` in
    /// declaration order, ids dense from 0.
    pub fn enumerate(&self) -> Vec<Candidate> {
        let mut out = Vec::with_capacity(self.len());
        let mut id = 0;
        for m in &self.methods {
            for g in &self.grains {
                for t in &self.tweak_grid {
                    out.push(Candidate {
                        id,
                        method: m.clone(),
                        grain: g.clone(),
                        tweak: *t,
                    });
                    id += 1;
                }
            }
        }
        out
    }

    /// Total candidate count.
    pub fn len(&self) -> usize {
        self.methods.len() * self.grains.len() * self.tweak_grid.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("methods", arr(self.methods.iter().map(|m| s(m.clone())).collect())),
            ("grains", arr(self.grains.iter().map(|g| s(g.clone())).collect())),
            ("tweak_grid", arr(self.tweak_grid.iter().map(tweak_to_json).collect())),
            ("target_bits", n(f64::from(self.target_bits))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |m: &str| Error::Json(format!("search space: {m}"));
        let strings = |k: &str| -> Result<Vec<String>> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| bad(&format!("missing `{k}` array")))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(String::from)
                        .ok_or_else(|| bad(&format!("`{k}` entries must be strings")))
                })
                .collect()
        };
        let tweak_grid = j
            .get("tweak_grid")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing `tweak_grid` array"))?
            .iter()
            .map(tweak_from_json)
            .collect::<Result<Vec<_>>>()?;
        let target_bits = j
            .get("target_bits")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| bad("missing `target_bits`"))? as f32;
        Ok(SpaceConfig {
            methods: strings("methods")?,
            grains: strings("grains")?,
            tweak_grid,
            target_bits,
        })
    }

    /// Stable identity of (space, seed): checkpoints refuse to resume into
    /// a differently-shaped search.
    pub fn fingerprint(&self, seed: u64) -> String {
        fnv1a_hex(format!("{}#{seed}", self.to_json().emit()).as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SpaceConfig {
        SpaceConfig {
            methods: vec!["rtn".into(), "gptq".into()],
            grains: vec!["g64".into(), "pc".into()],
            tweak_grid: vec![Some(TweakConfig::default()), None],
            target_bits: 2.5,
        }
    }

    #[test]
    fn enumeration_is_dense_and_ordered() {
        let cands = space().enumerate();
        assert_eq!(cands.len(), 8);
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        // method-major, then grain, then tweak
        assert_eq!(
            (cands[0].method.as_str(), cands[0].grain.as_str(), cands[0].tweak.is_some()),
            ("rtn", "g64", true)
        );
        assert_eq!((cands[1].grain.as_str(), cands[1].tweak.is_none()), ("g64", true));
        assert_eq!(cands[2].grain.as_str(), "pc");
        assert_eq!(cands[4].method.as_str(), "gptq");
    }

    #[test]
    fn json_round_trip_preserves_order_and_fingerprint() {
        let sp = space();
        let back = SpaceConfig::from_json(&Json::parse(&sp.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back, sp);
        assert_eq!(back.fingerprint(7), sp.fingerprint(7));
        assert_ne!(sp.fingerprint(7), sp.fingerprint(8));
        let mut other = sp.clone();
        other.methods.reverse();
        assert_ne!(other.fingerprint(7), sp.fingerprint(7));
    }

    #[test]
    fn validate_rejects_degenerate_axes() {
        let mut sp = space();
        sp.methods.clear();
        assert!(sp.validate().is_err());
        let mut sp = space();
        sp.methods = vec!["nope".into()];
        assert!(sp.validate().is_err());
        let mut sp = space();
        sp.grains = vec!["q64".into()];
        assert!(sp.validate().is_err());
        let mut sp = space();
        sp.target_bits = 0.0;
        assert!(sp.validate().is_err());
        assert!(space().validate().is_ok());
    }

    #[test]
    fn grain_tags_parse_both_ways() {
        assert_eq!(grain_group_size("pc").unwrap(), None);
        assert_eq!(grain_group_size("g64").unwrap(), Some(64));
        assert!(grain_group_size("g0").is_err());
        assert!(grain_group_size("64").is_err());
        for scheme in [QuantScheme::w2_g64(), QuantScheme::w4_perchannel()] {
            assert_eq!(
                grain_group_size(&scheme.group_tag()).unwrap(),
                scheme.group_size
            );
        }
    }

    #[test]
    fn tweak_points_round_trip() {
        for t in default_tweak_grid(TweakConfig::default()) {
            let back = tweak_from_json(&Json::parse(&tweak_to_json(&t).emit()).unwrap()).unwrap();
            assert_eq!(back, t);
        }
        assert!(tweak_from_json(&Json::parse(r#"{"iters":4}"#).unwrap()).is_err());
    }
}

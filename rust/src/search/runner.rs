//! The staged evaluator: prune cheaply, escalate survivors, checkpoint
//! after every expensive step.
//!
//! Stage 0 touches nothing but the persisted [`SensitivityProfile`]:
//! candidates whose grain the profile was not measured at are pruned
//! outright (their scores would not be commensurable), the rest get a
//! per-layer width allocation from the greedy
//! [`BitBudgetPlanner`](crate::policy::BitBudgetPlanner) and a stage-0
//! score read straight out of the profile table.  Because the profile is
//! method-agnostic, stage 0 cannot separate methods — so the **escalation
//! unit of stage 1 is the `(method, grain)` group**, and `budget` counts
//! groups, not candidates.  Ranking is by `(stage-0 score, lowest id)`,
//! which makes "raise the budget" strictly additive: a group escalated at
//! budget *N* is escalated at every budget > *N*.
//!
//! Stage 1 trial-quantizes every planned layer of each escalated group
//! with the group's real quantizer (CPU Gram Hessians, deterministic
//! seeded taps — still no runtime) and scores with the profile's loss.
//! The [`SearchState`] checkpoint is rewritten after **every** group, so a
//! killed run resumes without repeating finished trials.
//!
//! Stage 2 is optional and the only stage allowed to execute the model:
//! the caller injects a perplexity closure (the CLI wires `--ppl` to a
//! `FloatModel`-backed evaluator) and the winning group's tweak-grid
//! candidates are ranked by held-out perplexity.  Without it the winner is
//! the group's earliest candidate — the grid is ordered base-first, so
//! offline searches prefer the configured tweak over exotic points.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::ModelWeights;
use crate::obs::{global, TraceCollector};
use crate::policy::{BitBudgetPlanner, BitPlan, SensitivityProfile};
use crate::quant::quantizer::{resolve, QuantizerParams};
use crate::quant::QuantScheme;
use crate::tensor::Tensor;
use crate::tweak::LossKind;
use crate::util::json::{n, obj, s, Json};

use super::space::{grain_group_size, Candidate, SpaceConfig};

/// Schema tag for the on-disk [`SearchState`] checkpoint.
pub const STATE_SCHEMA: &str = "normtweak.search-state.v1";

/// Rows of synthetic calibration activations per tap (seeded, so every
/// run of the same (space, seed) scores identically).
const TAP_ROWS: usize = 64;

/// Stage-1 trial scoring against real weights: quantize every planned
/// layer with the actual method and sum the tweak-loss divergence on
/// deterministic synthetic taps.  Same measurement core as the profiler
/// ([`crate::policy::score_layer`]) — only the tap source differs.
pub struct Evaluator<'w> {
    weights: &'w ModelWeights,
    seed: u64,
}

impl<'w> Evaluator<'w> {
    pub fn new(weights: &'w ModelWeights, seed: u64) -> Self {
        Evaluator { weights, seed }
    }

    /// Seeded taps for one layer, in tap order (qkv/proj/fc1 read the
    /// d_model stream, fc2 reads the d_ff hidden).
    fn taps(&self, layer: usize) -> Vec<Tensor> {
        let d = self.weights.config.d_model;
        let ff = self.weights.config.d_ff;
        let base = self.seed.wrapping_add(1000 * layer as u64);
        vec![
            Tensor::randn(&[TAP_ROWS, d], base + 1, 1.0),
            Tensor::randn(&[TAP_ROWS, d], base + 2, 1.0),
            Tensor::randn(&[TAP_ROWS, d], base + 3, 1.0),
            Tensor::randn(&[TAP_ROWS, ff], base + 4, 1.0),
        ]
    }

    /// Trial-quantize every layer in `plan` with `method` and return the
    /// summed divergence under `loss`.
    pub fn trial_score(&self, method: &str, plan: &BitPlan, loss: LossKind) -> Result<f32> {
        let quantizer = resolve(method, &QuantizerParams::default())?;
        let n_layer = self.weights.config.n_layer;
        let mut total = 0.0f32;
        for (&layer, &scheme) in &plan.schemes {
            if layer >= n_layer {
                return Err(Error::Config(format!(
                    "plan allocates layer {layer}, model has {n_layer}"
                )));
            }
            let bw = self.weights.block(layer)?;
            let taps = self.taps(layer);
            total += crate::policy::score_layer(bw, &taps, scheme, quantizer.as_ref(), loss)?;
        }
        Ok(total)
    }
}

/// Where a candidate ended up in the staged funnel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CandidateStatus {
    /// dropped at stage 0 (grain not measured by the profile)
    Pruned,
    /// planned and stage-0 scored, but its group fell outside the budget
    Planned,
    /// its `(method, grain)` group was trial-quantized at stage 1
    Escalated,
    /// additionally ranked by held-out perplexity at stage 2
    Scored,
}

impl CandidateStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            CandidateStatus::Pruned => "pruned",
            CandidateStatus::Planned => "planned",
            CandidateStatus::Escalated => "escalated",
            CandidateStatus::Scored => "scored",
        }
    }

    pub fn from_str(v: &str) -> Result<Self> {
        match v {
            "pruned" => Ok(CandidateStatus::Pruned),
            "planned" => Ok(CandidateStatus::Planned),
            "escalated" => Ok(CandidateStatus::Escalated),
            "scored" => Ok(CandidateStatus::Scored),
            other => Err(Error::Json(format!("unknown candidate status `{other}`"))),
        }
    }
}

/// One candidate's scores through the funnel — the recipe's audit trail.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierEntry {
    pub candidate: Candidate,
    pub status: CandidateStatus,
    /// profile-table score of the planned allocation (absent when pruned)
    pub stage0: Option<f32>,
    /// stage-1 trial-quantization score of the candidate's group
    pub stage1: Option<f32>,
    /// held-out perplexity (stage 2, only with an injected evaluator)
    pub stage2: Option<f32>,
}

/// Funnel counts, echoed into metrics and recipe provenance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SearchStats {
    pub enumerated: usize,
    pub pruned: usize,
    pub escalated: usize,
    pub scored: usize,
}

/// A finished search: the winner, its allocation, and the whole scored
/// frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchOutcome {
    pub winner: Candidate,
    pub plan: BitPlan,
    pub frontier: Vec<FrontierEntry>,
    pub stats: SearchStats,
}

/// The resumable checkpoint: which `(method, grain)` groups have finished
/// stage 1, keyed by `method@grain`, plus the `(space, seed)` fingerprint
/// so a checkpoint can never leak scores into a differently-shaped search.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchState {
    pub fingerprint: String,
    pub escalated: BTreeMap<String, f32>,
}

impl SearchState {
    pub fn new(fingerprint: String) -> Self {
        SearchState { fingerprint, escalated: BTreeMap::new() }
    }

    pub fn to_json(&self) -> Json {
        let escalated: BTreeMap<String, Json> = self
            .escalated
            .iter()
            .map(|(k, v)| (k.clone(), n(f64::from(*v))))
            .collect();
        obj(vec![
            ("schema", s(STATE_SCHEMA)),
            ("fingerprint", s(self.fingerprint.clone())),
            ("escalated", Json::Obj(escalated)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |m: &str| Error::Json(format!("search state: {m}"));
        match j.get("schema").and_then(|v| v.as_str()) {
            Some(STATE_SCHEMA) => {}
            other => {
                return Err(bad(&format!(
                    "schema `{}` (expected `{STATE_SCHEMA}`)",
                    other.unwrap_or("<missing>")
                )))
            }
        }
        let fingerprint = j
            .get("fingerprint")
            .and_then(|v| v.as_str())
            .ok_or_else(|| bad("missing `fingerprint`"))?
            .to_string();
        let mut escalated = BTreeMap::new();
        for (k, v) in j
            .get("escalated")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| bad("missing `escalated` object"))?
        {
            let score = v
                .as_f64()
                .ok_or_else(|| bad(&format!("group `{k}` score is not a number")))?;
            escalated.insert(k.clone(), score as f32);
        }
        Ok(SearchState { fingerprint, escalated })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().emit())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Search knobs beyond the space itself.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub space: SpaceConfig,
    /// How many `(method, grain)` groups stage 1 may trial-quantize.
    pub budget: usize,
    /// Seeds the synthetic stage-1 taps and the space fingerprint.
    pub seed: u64,
}

/// Optional stage-2 scorer: candidate + its allocation → held-out
/// perplexity.  Injected by the CLI when `--ppl` is given; the runner
/// itself never constructs a runtime.
pub type PplFn<'a> = Box<dyn Fn(&Candidate, &BitPlan) -> Result<f32> + 'a>;

/// Drives the staged search.  Construct with [`SearchRunner::new`], then
/// chain the optional wirings (`state_path`, `trace`, `ppl`) and call
/// [`run`](SearchRunner::run).
pub struct SearchRunner<'a> {
    profile: &'a SensitivityProfile,
    weights: &'a ModelWeights,
    cfg: SearchConfig,
    state_path: Option<PathBuf>,
    trace: Option<Arc<TraceCollector>>,
    ppl: Option<PplFn<'a>>,
    /// Test hook: abort (checkpoint intact) after this many *fresh*
    /// stage-1 escalations, simulating a killed run.
    max_escalations: Option<usize>,
}

impl<'a> SearchRunner<'a> {
    pub fn new(
        profile: &'a SensitivityProfile,
        weights: &'a ModelWeights,
        cfg: SearchConfig,
    ) -> Self {
        SearchRunner {
            profile,
            weights,
            cfg,
            state_path: None,
            trace: None,
            ppl: None,
            max_escalations: None,
        }
    }

    /// Checkpoint stage-1 progress here (and resume from it if present).
    pub fn with_state_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.state_path = Some(path.into());
        self
    }

    pub fn with_trace(mut self, trace: Arc<TraceCollector>) -> Self {
        self.trace = Some(trace);
        self
    }

    pub fn with_ppl(mut self, ppl: PplFn<'a>) -> Self {
        self.ppl = Some(ppl);
        self
    }

    pub fn with_max_escalations(mut self, max: usize) -> Self {
        self.max_escalations = Some(max);
        self
    }

    fn group_key(c: &Candidate) -> String {
        format!("{}@{}", c.method, c.grain)
    }

    /// Run the staged search.  `Ok(None)` means the `max_escalations` hook
    /// aborted a partially-escalated run — the checkpoint at `state_path`
    /// holds every finished trial and a re-run resumes from it.
    pub fn run(&self) -> Result<Option<SearchOutcome>> {
        self.cfg.space.validate()?;
        if self.cfg.budget == 0 {
            return Err(Error::Config("search budget must be at least 1 group".into()));
        }
        let fingerprint = self.cfg.space.fingerprint(self.cfg.seed);
        let mut state = match &self.state_path {
            Some(p) if p.exists() => {
                let st = SearchState::load(p)?;
                if st.fingerprint != fingerprint {
                    return Err(Error::Config(format!(
                        "checkpoint {} was written by a different search \
                         (fingerprint {} != {fingerprint}); delete it or match the \
                         original space/seed",
                        p.display(),
                        st.fingerprint
                    )));
                }
                st
            }
            _ => SearchState::new(fingerprint),
        };
        let loss = LossKind::from_str(&self.profile.loss)?;
        let trace = self.trace.as_ref().map(|t| (t.clone(), t.track("policy")));

        // ---- stage 0: prune + plan + table score ------------------------
        let t0 = trace.as_ref().map(|(t, _)| t.now());
        let candidates = self.cfg.space.enumerate();
        let stats_enumerated = candidates.len();
        let mut plans: BTreeMap<String, BitPlan> = BTreeMap::new();
        let mut entries: Vec<FrontierEntry> = Vec::with_capacity(candidates.len());
        let mut pruned = 0usize;
        for c in candidates {
            if c.grain != self.profile.group_tag {
                global().counter("search.pruned").inc();
                pruned += 1;
                entries.push(FrontierEntry {
                    candidate: c,
                    status: CandidateStatus::Pruned,
                    stage0: None,
                    stage1: None,
                    stage2: None,
                });
                continue;
            }
            if !plans.contains_key(&c.grain) {
                let min_bits = *self
                    .profile
                    .candidate_bits
                    .iter()
                    .min()
                    .ok_or_else(|| Error::Config("profile has no candidate widths".into()))?;
                let base = QuantScheme { bits: min_bits, group_size: grain_group_size(&c.grain)? };
                let plan =
                    BitBudgetPlanner::new(base, self.cfg.space.target_bits).plan(self.profile)?;
                plans.insert(c.grain.clone(), plan);
            }
            let plan = &plans[&c.grain];
            let mut stage0 = 0.0f32;
            for l in &self.profile.layers {
                let bits = plan.schemes[&l.layer].bits;
                stage0 += l.score(bits).unwrap_or(f32::INFINITY);
            }
            entries.push(FrontierEntry {
                candidate: c,
                status: CandidateStatus::Planned,
                stage0: Some(stage0),
                stage1: None,
                stage2: None,
            });
        }
        if let Some((t, tid)) = &trace {
            t.complete(
                *tid,
                "search.stage0",
                t0.unwrap_or(0),
                vec![
                    ("enumerated", n(stats_enumerated as f64)),
                    ("pruned", n(pruned as f64)),
                ],
            );
        }
        if entries.iter().all(|e| e.status == CandidateStatus::Pruned) {
            return Err(Error::Config(format!(
                "every candidate was pruned: the profile was measured at grain `{}` \
                 but the space enumerates {:?}",
                self.profile.group_tag, self.cfg.space.grains
            )));
        }

        // ---- stage 1: escalate top-budget (method, grain) groups --------
        // group order: best stage-0 score, ties to the earliest id — so a
        // larger budget always escalates a superset of groups.
        let mut groups: Vec<(String, f32, usize)> = Vec::new(); // (key, stage0, min id)
        for e in &entries {
            if e.status == CandidateStatus::Pruned {
                continue;
            }
            let key = Self::group_key(&e.candidate);
            if !groups.iter().any(|(k, _, _)| *k == key) {
                // candidates within a group share the grain (hence plan and
                // stage-0 score); the first hit is also the lowest id
                let s0 = e.stage0.unwrap_or(f32::INFINITY);
                groups.push((key, s0, e.candidate.id));
            }
        }
        groups.sort_by(|a, b| {
            a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.2.cmp(&b.2))
        });
        let escalate: Vec<String> = groups
            .iter()
            .take(self.cfg.budget)
            .map(|(k, _, _)| k.clone())
            .collect();

        let evaluator = Evaluator::new(self.weights, self.cfg.seed);
        let mut fresh = 0usize;
        for key in &escalate {
            if state.escalated.contains_key(key) {
                continue; // finished in a previous (killed) run
            }
            if self.max_escalations.is_some_and(|m| fresh >= m) {
                if let Some(p) = &self.state_path {
                    state.save(p)?;
                }
                crate::log_warn!(
                    "search",
                    "escalation cap reached after {fresh} trials; checkpoint saved"
                );
                return Ok(None);
            }
            let (method, grain) = key
                .split_once('@')
                .ok_or_else(|| Error::Config(format!("bad group key `{key}`")))?;
            let plan = plans
                .get(grain)
                .ok_or_else(|| Error::Config(format!("no plan for grain `{grain}`")))?;
            let ts = trace.as_ref().map(|(t, _)| t.now());
            let score = evaluator.trial_score(method, plan, loss)?;
            if let Some((t, tid)) = &trace {
                t.complete(
                    *tid,
                    "search.escalate",
                    ts.unwrap_or(0),
                    vec![
                        ("group", s(key.clone())),
                        ("score", n(f64::from(score))),
                    ],
                );
            }
            global().counter("search.escalated").inc();
            crate::log_info!("search", "escalated {key}: trial score {score:.5}");
            state.escalated.insert(key.clone(), score);
            fresh += 1;
            // checkpoint after *every* trial: a kill between groups never
            // repeats finished work
            if let Some(p) = &self.state_path {
                state.save(p)?;
            }
        }
        for e in &mut entries {
            if e.status == CandidateStatus::Pruned {
                continue;
            }
            if let Some(&sc) = state.escalated.get(&Self::group_key(&e.candidate)) {
                e.status = CandidateStatus::Escalated;
                e.stage1 = Some(sc);
            }
        }

        // ---- pick the winning group -------------------------------------
        let (win_key, _) = escalate
            .iter()
            .filter_map(|k| state.escalated.get(k).map(|&sc| (k.clone(), sc)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .ok_or_else(|| Error::Config("no group survived escalation".into()))?;

        // ---- stage 2: optional held-out perplexity over the winner group
        let mut scored = 0usize;
        if let Some(ppl) = &self.ppl {
            let win_grain = win_key
                .split_once('@')
                .map(|(_, g)| g.to_string())
                .unwrap_or_default();
            for e in &mut entries {
                if e.status != CandidateStatus::Escalated
                    || Self::group_key(&e.candidate) != win_key
                {
                    continue;
                }
                let ts = trace.as_ref().map(|(t, _)| t.now());
                let p = ppl(&e.candidate, &plans[&win_grain])?;
                if let Some((t, tid)) = &trace {
                    t.complete(
                        *tid,
                        "search.score",
                        ts.unwrap_or(0),
                        vec![
                            ("id", n(e.candidate.id as f64)),
                            ("ppl", n(f64::from(p))),
                        ],
                    );
                }
                global().counter("search.scored").inc();
                e.status = CandidateStatus::Scored;
                e.stage2 = Some(p);
                scored += 1;
            }
        }

        // ---- winner: best stage-2 ppl if measured, else earliest id -----
        let winner_entry = entries
            .iter()
            .filter(|e| {
                matches!(e.status, CandidateStatus::Escalated | CandidateStatus::Scored)
                    && Self::group_key(&e.candidate) == win_key
            })
            .min_by(|a, b| {
                match (a.stage2, b.stage2) {
                    (Some(x), Some(y)) => x
                        .partial_cmp(&y)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.candidate.id.cmp(&b.candidate.id)),
                    (Some(_), None) => std::cmp::Ordering::Less,
                    (None, Some(_)) => std::cmp::Ordering::Greater,
                    (None, None) => a.candidate.id.cmp(&b.candidate.id),
                }
            })
            .ok_or_else(|| Error::Config("winning group has no candidates".into()))?
            .clone();
        let plan = plans
            .get(&winner_entry.candidate.grain)
            .ok_or_else(|| Error::Config("winner has no plan".into()))?
            .clone();

        Ok(Some(SearchOutcome {
            winner: winner_entry.candidate.clone(),
            plan,
            frontier: entries,
            stats: SearchStats {
                enumerated: stats_enumerated,
                pruned,
                escalated: state.escalated.len(),
                scored,
            },
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, NormKind};
    use crate::policy::LayerSensitivity;
    use crate::tweak::TweakConfig;

    fn tiny_weights() -> ModelWeights {
        ModelWeights::random(
            ModelConfig {
                name: "nt-tiny".into(),
                n_layer: 2,
                d_model: 16,
                n_head: 2,
                d_ff: 32,
                vocab: 64,
                seq: 16,
                norm: NormKind::LayerNorm,
            },
            42,
        )
    }

    fn profile() -> SensitivityProfile {
        SensitivityProfile {
            model: "nt-tiny".into(),
            method: "rtn".into(),
            group_tag: "g16".into(),
            calib_source: "gen-v2".into(),
            loss: "dist".into(),
            candidate_bits: vec![2, 4],
            layers: vec![
                LayerSensitivity {
                    layer: 0,
                    scores: [(2u8, 2.0f32), (4, 0.5)].into_iter().collect(),
                },
                LayerSensitivity {
                    layer: 1,
                    scores: [(2u8, 1.0f32), (4, 0.25)].into_iter().collect(),
                },
            ],
            ckpt_hash: None,
        }
    }

    fn space() -> SpaceConfig {
        SpaceConfig {
            methods: vec!["rtn".into(), "gptq".into()],
            grains: vec!["g16".into(), "pc".into()],
            tweak_grid: vec![Some(TweakConfig::default()), None],
            target_bits: 2.5,
        }
    }

    #[test]
    fn stage0_prunes_unprofiled_grains_and_stage1_ranks_groups() {
        let w = tiny_weights();
        let p = profile();
        let cfg = SearchConfig { space: space(), budget: 2, seed: 7 };
        let out = SearchRunner::new(&p, &w, cfg).run().unwrap().unwrap();
        assert_eq!(out.stats.enumerated, 8);
        assert_eq!(out.stats.pruned, 4); // every `pc` candidate
        assert_eq!(out.stats.escalated, 2); // rtn@g16 + gptq@g16
        assert_eq!(out.stats.scored, 0);
        assert_eq!(out.winner.grain, "g16");
        // offline winner is the earliest candidate of the best group: the
        // base tweak point, not plain PTQ
        assert!(out.winner.tweak.is_some());
        // frontier covers the whole space with consistent statuses
        assert_eq!(out.frontier.len(), 8);
        for e in &out.frontier {
            match e.status {
                CandidateStatus::Pruned => assert_eq!(e.candidate.grain, "pc"),
                CandidateStatus::Planned => unreachable!("budget covers both groups"),
                _ => assert!(e.stage0.is_some() && e.stage1.is_some()),
            }
        }
        // plan obeys the budget
        assert!(out.plan.mean_bits <= 2.5 + 1e-5);
    }

    #[test]
    fn search_is_deterministic() {
        let w = tiny_weights();
        let p = profile();
        let cfg = SearchConfig { space: space(), budget: 1, seed: 7 };
        let a = SearchRunner::new(&p, &w, cfg.clone()).run().unwrap().unwrap();
        let b = SearchRunner::new(&p, &w, cfg).run().unwrap().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn budget_one_leaves_second_group_planned() {
        let w = tiny_weights();
        let p = profile();
        let cfg = SearchConfig { space: space(), budget: 1, seed: 7 };
        let out = SearchRunner::new(&p, &w, cfg).run().unwrap().unwrap();
        assert_eq!(out.stats.escalated, 1);
        assert!(out
            .frontier
            .iter()
            .any(|e| e.status == CandidateStatus::Planned));
    }

    #[test]
    fn all_pruned_space_is_an_error() {
        let w = tiny_weights();
        let p = profile();
        let mut sp = space();
        sp.grains = vec!["pc".into()]; // profile measured g16 only
        let cfg = SearchConfig { space: sp, budget: 1, seed: 7 };
        let err = SearchRunner::new(&p, &w, cfg).run().unwrap_err();
        assert!(format!("{err}").contains("pruned"), "{err}");
    }

    #[test]
    fn checkpoint_resume_matches_uninterrupted_run() {
        let w = tiny_weights();
        let p = profile();
        let dir = std::env::temp_dir().join("nt_search_runner_test");
        std::fs::create_dir_all(&dir).unwrap();
        let state = dir.join("resume.state.json");
        let _ = std::fs::remove_file(&state);
        let cfg = SearchConfig { space: space(), budget: 2, seed: 7 };

        // killed after one fresh escalation: no outcome, checkpoint on disk
        let interrupted = SearchRunner::new(&p, &w, cfg.clone())
            .with_state_path(&state)
            .with_max_escalations(1)
            .run()
            .unwrap();
        assert!(interrupted.is_none());
        assert_eq!(SearchState::load(&state).unwrap().escalated.len(), 1);

        // resumed run completes and matches a never-interrupted run
        let resumed = SearchRunner::new(&p, &w, cfg.clone())
            .with_state_path(&state)
            .run()
            .unwrap()
            .unwrap();
        let straight = SearchRunner::new(&p, &w, cfg).run().unwrap().unwrap();
        assert_eq!(resumed, straight);
        let _ = std::fs::remove_file(&state);
    }

    #[test]
    fn foreign_checkpoint_is_rejected() {
        let w = tiny_weights();
        let p = profile();
        let dir = std::env::temp_dir().join("nt_search_runner_test");
        std::fs::create_dir_all(&dir).unwrap();
        let state = dir.join("foreign.state.json");
        SearchState::new("deadbeefdeadbeef".into())
            .save(&state)
            .unwrap();
        let cfg = SearchConfig { space: space(), budget: 1, seed: 7 };
        let err = SearchRunner::new(&p, &w, cfg)
            .with_state_path(&state)
            .run()
            .unwrap_err();
        assert!(format!("{err}").contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&state);
    }

    #[test]
    fn stage2_ppl_overrides_the_id_tiebreak() {
        let w = tiny_weights();
        let p = profile();
        let cfg = SearchConfig { space: space(), budget: 1, seed: 7 };
        // a scorer that prefers plain PTQ (no tweak): the winner must flip
        // away from the earliest-id default
        let out = SearchRunner::new(&p, &w, cfg)
            .with_ppl(Box::new(|c, _plan| {
                Ok(if c.tweak.is_none() { 10.0 } else { 20.0 })
            }))
            .run()
            .unwrap()
            .unwrap();
        assert!(out.stats.scored >= 2);
        assert!(out.winner.tweak.is_none());
        assert_eq!(
            out.frontier
                .iter()
                .filter(|e| e.status == CandidateStatus::Scored)
                .count(),
            out.stats.scored
        );
    }

    #[test]
    fn state_json_round_trips() {
        let mut st = SearchState::new("0123456789abcdef".into());
        st.escalated.insert("rtn@g16".into(), 1.25);
        st.escalated.insert("gptq@g16".into(), 0.5);
        let back = SearchState::from_json(&Json::parse(&st.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back, st);
        assert!(SearchState::from_json(&Json::parse(r#"{"schema":"v9"}"#).unwrap()).is_err());
    }
}

//! Architecture registry — mirror of `python/compile/configs.py::MODELS`.

use crate::error::{Error, Result};

/// Which normalization the model uses. LayerNorm covers the BLOOM/OPT/GLM
/// family of the paper; RMSNorm covers LLaMa.  Norm Tweaking updates gamma
/// and (for LayerNorm) beta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    LayerNorm,
    RmsNorm,
}

impl NormKind {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "layernorm" => Ok(NormKind::LayerNorm),
            "rmsnorm" => Ok(NormKind::RmsNorm),
            other => Err(Error::Config(format!("unknown norm kind {other}"))),
        }
    }

    /// Number of tweakable norm parameter vectors per block (g[, b] per norm × 2 norms).
    pub fn n_tweak_params(self) -> usize {
        match self {
            NormKind::LayerNorm => 4,
            NormKind::RmsNorm => 2,
        }
    }
}

/// One model architecture (mirrors the Python dataclass field-for-field).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub norm: NormKind,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    /// Total float parameter count (tied embeddings counted once).
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let ff = self.d_ff;
        let per_block = d * 3 * d + 3 * d     // qkv
            + d * d + d                        // proj
            + d * ff + ff + ff * d + d         // mlp
            + match self.norm {
                NormKind::LayerNorm => 4 * d,
                NormKind::RmsNorm => 2 * d,
            };
        self.vocab * d + self.seq * d
            + self.n_layer * per_block
            + match self.norm {
                NormKind::LayerNorm => 2 * d,
                NormKind::RmsNorm => d,
            }
    }

    /// The four quantizable linear layers of a block: (name, K, N).
    pub fn linear_shapes(&self) -> [(&'static str, usize, usize); 4] {
        let d = self.d_model;
        let ff = self.d_ff;
        [
            ("attn.wqkv", d, 3 * d),
            ("attn.wproj", d, d),
            ("mlp.wfc1", d, ff),
            ("mlp.wfc2", ff, d),
        ]
    }
}

/// The built-in registry (kept in sync with Python; `manifest.json` is the
/// cross-check — `Runtime::verify_model` compares both).
pub const MODEL_REGISTRY: &[(&str, usize, usize, usize, usize, &str)] = &[
    // name, n_layer, d_model, n_head, d_ff, norm
    ("nt-tiny", 2, 128, 4, 512, "layernorm"),
    ("nt-small", 4, 256, 8, 1024, "layernorm"),
    ("nt-small-rms", 4, 256, 8, 1024, "rmsnorm"),
    ("nt-medium", 6, 384, 8, 1536, "layernorm"),
];

pub const VOCAB_SIZE: usize = 2048;
pub const SEQ_LEN: usize = 128;

impl ModelConfig {
    /// Look up a built-in architecture by name.
    pub fn builtin(name: &str) -> Result<Self> {
        for &(n, l, d, h, ff, norm) in MODEL_REGISTRY {
            if n == name {
                return Ok(ModelConfig {
                    name: n.to_string(),
                    n_layer: l,
                    d_model: d,
                    n_head: h,
                    d_ff: ff,
                    vocab: VOCAB_SIZE,
                    seq: SEQ_LEN,
                    norm: NormKind::from_str(norm)?,
                });
            }
        }
        let names: Vec<&str> = MODEL_REGISTRY.iter().map(|r| r.0).collect();
        Err(Error::Config(format!(
            "unknown model `{name}` (registered: {})",
            names.join(", ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_lookup() {
        let c = ModelConfig::builtin("nt-small").unwrap();
        assert_eq!(c.n_layer, 4);
        assert_eq!(c.d_model, 256);
        assert_eq!(c.norm, NormKind::LayerNorm);
        assert!(ModelConfig::builtin("nope").is_err());
    }

    #[test]
    fn rms_variant() {
        let c = ModelConfig::builtin("nt-small-rms").unwrap();
        assert_eq!(c.norm, NormKind::RmsNorm);
        assert_eq!(c.norm.n_tweak_params(), 2);
    }

    #[test]
    fn param_count_sane() {
        // nt-small ≈ 3.8M params
        let c = ModelConfig::builtin("nt-small").unwrap();
        let n = c.n_params();
        assert!(n > 3_000_000 && n < 5_000_000, "{n}");
    }

    #[test]
    fn linear_shapes() {
        let c = ModelConfig::builtin("nt-tiny").unwrap();
        let ls = c.linear_shapes();
        assert_eq!(ls[0], ("attn.wqkv", 128, 384));
        assert_eq!(ls[3], ("mlp.wfc2", 512, 128));
    }
}

//! Float model weights: load/save `.ntz` checkpoints, canonical per-block
//! views matching the AOT graphs' argument order.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::{load_ntz, save_ntz, Tensor};

use super::config::{ModelConfig, NormKind};

/// The full float parameter set of a model, keyed by canonical names
/// (`tok_emb`, `pos_emb`, `block{i}.ln1.g`, ..., `lnf.g[, lnf.b]`).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

/// Borrowed view of one block's float weights in AOT argument order.
/// All fields are shared borrows, so the view is freely `Copy`able (the
/// pipeline hands one copy to the quantizer's `LayerContext` and keeps one
/// for assembling biases).
#[derive(Debug, Clone, Copy)]
pub struct BlockWeights<'a> {
    pub ln1_g: &'a Tensor,
    pub ln1_b: Option<&'a Tensor>,
    pub wqkv: &'a Tensor,
    pub bqkv: &'a Tensor,
    pub wproj: &'a Tensor,
    pub bproj: &'a Tensor,
    pub ln2_g: &'a Tensor,
    pub ln2_b: Option<&'a Tensor>,
    pub wfc1: &'a Tensor,
    pub bfc1: &'a Tensor,
    pub wfc2: &'a Tensor,
    pub bfc2: &'a Tensor,
}

impl<'a> BlockWeights<'a> {
    /// Flatten into the AOT `block_fwd` argument order.
    pub fn flat(&self) -> Vec<&'a Tensor> {
        let mut v = vec![self.ln1_g];
        if let Some(b) = self.ln1_b {
            v.push(b);
        }
        v.extend([self.wqkv, self.bqkv, self.wproj, self.bproj, self.ln2_g]);
        if let Some(b) = self.ln2_b {
            v.push(b);
        }
        v.extend([self.wfc1, self.bfc1, self.wfc2, self.bfc2]);
        v
    }
}

impl ModelWeights {
    /// Load `artifacts/weights_<model>.ntz` and validate the registry.
    pub fn load(config: ModelConfig, path: impl AsRef<Path>) -> Result<Self> {
        let tensors = load_ntz(path)?;
        let w = ModelWeights { config, tensors };
        w.validate()?;
        Ok(w)
    }

    /// Load by model name from an artifacts directory.
    pub fn load_from_dir(name: &str, artifacts: impl AsRef<Path>) -> Result<Self> {
        let config = ModelConfig::builtin(name)?;
        let path = artifacts.as_ref().join(format!("weights_{name}.ntz"));
        Self::load(config, path)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        save_ntz(path, &self.tensors)
    }

    /// Every expected tensor present with the right shape.
    pub fn validate(&self) -> Result<()> {
        let c = &self.config;
        let d = c.d_model;
        let expect = |name: &str, shape: &[usize]| -> Result<()> {
            let t = self
                .tensors
                .get(name)
                .ok_or_else(|| Error::Checkpoint(format!("missing tensor {name}")))?;
            if t.shape != shape {
                return Err(Error::Checkpoint(format!(
                    "{name}: shape {:?}, expected {shape:?}",
                    t.shape
                )));
            }
            Ok(())
        };
        expect("tok_emb", &[c.vocab, d])?;
        expect("pos_emb", &[c.seq, d])?;
        expect("lnf.g", &[d])?;
        if c.norm == NormKind::LayerNorm {
            expect("lnf.b", &[d])?;
        }
        for i in 0..c.n_layer {
            let p = format!("block{i}.");
            expect(&format!("{p}ln1.g"), &[d])?;
            expect(&format!("{p}ln2.g"), &[d])?;
            if c.norm == NormKind::LayerNorm {
                expect(&format!("{p}ln1.b"), &[d])?;
                expect(&format!("{p}ln2.b"), &[d])?;
            }
            expect(&format!("{p}attn.wqkv"), &[d, 3 * d])?;
            expect(&format!("{p}attn.bqkv"), &[3 * d])?;
            expect(&format!("{p}attn.wproj"), &[d, d])?;
            expect(&format!("{p}attn.bproj"), &[d])?;
            expect(&format!("{p}mlp.wfc1"), &[d, c.d_ff])?;
            expect(&format!("{p}mlp.bfc1"), &[c.d_ff])?;
            expect(&format!("{p}mlp.wfc2"), &[c.d_ff, d])?;
            expect(&format!("{p}mlp.bfc2"), &[d])?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Checkpoint(format!("missing tensor {name}")))
    }

    /// Borrowed per-block view.
    pub fn block(&self, i: usize) -> Result<BlockWeights<'_>> {
        let p = format!("block{i}.");
        let ln = self.config.norm == NormKind::LayerNorm;
        Ok(BlockWeights {
            ln1_g: self.get(&format!("{p}ln1.g"))?,
            ln1_b: if ln { Some(self.get(&format!("{p}ln1.b"))?) } else { None },
            wqkv: self.get(&format!("{p}attn.wqkv"))?,
            bqkv: self.get(&format!("{p}attn.bqkv"))?,
            wproj: self.get(&format!("{p}attn.wproj"))?,
            bproj: self.get(&format!("{p}attn.bproj"))?,
            ln2_g: self.get(&format!("{p}ln2.g"))?,
            ln2_b: if ln { Some(self.get(&format!("{p}ln2.b"))?) } else { None },
            wfc1: self.get(&format!("{p}mlp.wfc1"))?,
            bfc1: self.get(&format!("{p}mlp.bfc1"))?,
            wfc2: self.get(&format!("{p}mlp.wfc2"))?,
            bfc2: self.get(&format!("{p}mlp.bfc2"))?,
        })
    }

    /// Deterministic random weights for tests (valid registry, no training).
    pub fn random(config: ModelConfig, seed: u64) -> Self {
        let d = config.d_model;
        let ff = config.d_ff;
        let mut t = BTreeMap::new();
        let mut s = seed;
        let mut next = |shape: &[usize], scale: f32| {
            s += 1;
            Tensor::randn(shape, s, scale)
        };
        t.insert("tok_emb".into(), next(&[config.vocab, d], 0.02));
        t.insert("pos_emb".into(), next(&[config.seq, d], 0.02));
        t.insert("lnf.g".into(), Tensor::ones(&[d]));
        if config.norm == NormKind::LayerNorm {
            t.insert("lnf.b".into(), Tensor::zeros(&[d]));
        }
        for i in 0..config.n_layer {
            let p = format!("block{i}.");
            t.insert(format!("{p}ln1.g"), Tensor::ones(&[d]));
            t.insert(format!("{p}ln2.g"), Tensor::ones(&[d]));
            if config.norm == NormKind::LayerNorm {
                t.insert(format!("{p}ln1.b"), Tensor::zeros(&[d]));
                t.insert(format!("{p}ln2.b"), Tensor::zeros(&[d]));
            }
            t.insert(format!("{p}attn.wqkv"), next(&[d, 3 * d], 0.02));
            t.insert(format!("{p}attn.bqkv"), Tensor::zeros(&[3 * d]));
            t.insert(format!("{p}attn.wproj"), next(&[d, d], 0.02));
            t.insert(format!("{p}attn.bproj"), Tensor::zeros(&[d]));
            t.insert(format!("{p}mlp.wfc1"), next(&[d, ff], 0.02));
            t.insert(format!("{p}mlp.bfc1"), Tensor::zeros(&[ff]));
            t.insert(format!("{p}mlp.wfc2"), next(&[ff, d], 0.02));
            t.insert(format!("{p}mlp.bfc2"), Tensor::zeros(&[d]));
        }
        ModelWeights { config, tensors: t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let c = ModelConfig::builtin("nt-tiny").unwrap();
        let w = ModelWeights::random(c, 0);
        w.validate().unwrap();
        let b = w.block(0).unwrap();
        assert_eq!(b.flat().len(), 12);
    }

    #[test]
    fn rms_block_has_10_args() {
        let c = ModelConfig::builtin("nt-small-rms").unwrap();
        let w = ModelWeights::random(c, 0);
        assert_eq!(w.block(0).unwrap().flat().len(), 10);
    }

    #[test]
    fn validate_catches_missing() {
        let c = ModelConfig::builtin("nt-tiny").unwrap();
        let mut w = ModelWeights::random(c, 0);
        w.tensors.remove("block1.mlp.wfc2");
        assert!(w.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_shape() {
        let c = ModelConfig::builtin("nt-tiny").unwrap();
        let mut w = ModelWeights::random(c, 0);
        w.tensors.insert("lnf.g".into(), Tensor::zeros(&[7]));
        assert!(w.validate().is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let c = ModelConfig::builtin("nt-tiny").unwrap();
        let w = ModelWeights::random(c.clone(), 3);
        let dir = std::env::temp_dir().join("nt_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.ntz");
        w.save(&path).unwrap();
        let back = ModelWeights::load(c, &path).unwrap();
        assert_eq!(w.tensors, back.tensors);
    }
}

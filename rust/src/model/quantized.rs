//! Quantized model container: packed low-bit weights + float norms/biases.
//!
//! This is the deployable artifact Norm Tweaking produces — codes are stored
//! *bit-packed* (the real memory reduction), unpacked to i8 lazily when fed
//! to the PJRT `block_fwd_q` graphs (the CPU plugin has no sub-byte dtypes).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::quant::QuantScheme;
use crate::tensor::{load_ntz, pack_codes, save_ntz, unpack_codes, PackedCodes, Tensor};

use super::config::{ModelConfig, NormKind};
use super::weights::ModelWeights;

/// One quantized linear layer: packed codes + per-(group, out-channel) scales.
#[derive(Debug)]
pub struct QuantLinear {
    /// logical shape [K, N]
    pub k: usize,
    pub n: usize,
    pub packed: PackedCodes,
    /// f32 [G, N] where G = K / group_size
    pub scales: Tensor,
    pub bias: Tensor,
    /// lazily unpacked i8 codes — the packed form stays the storage truth,
    /// but the serving decode path feeds the unpacked tensor per generated
    /// token, so it is expanded once and reused (`OnceLock` keeps the
    /// container `Sync`)
    codes_cache: std::sync::OnceLock<Tensor>,
}

impl Clone for QuantLinear {
    fn clone(&self) -> Self {
        // the cache is not cloned: a clone re-unpacks on first use
        QuantLinear::new(self.k, self.n, self.packed.clone(), self.scales.clone(),
                         self.bias.clone())
    }
}

impl QuantLinear {
    pub fn new(k: usize, n: usize, packed: PackedCodes, scales: Tensor, bias: Tensor) -> Self {
        QuantLinear { k, n, packed, scales, bias, codes_cache: std::sync::OnceLock::new() }
    }

    /// The i8 codes tensor the AOT graphs expect — unpacked from the
    /// bit-packed storage on first use, then cached for the model's
    /// lifetime (the weights are immutable once quantized; the serving
    /// decode path feeds this per generated token).
    pub fn codes_tensor(&self) -> &Tensor {
        self.codes_cache.get_or_init(|| self.codes_tensor_owned())
    }

    /// A freshly unpacked, owned codes tensor that bypasses the cache —
    /// for one-shot consumers (the norm tweaker) that would otherwise
    /// leave a duplicate model-lifetime copy resident.
    pub fn codes_tensor_owned(&self) -> Tensor {
        Tensor::i8(&[self.k, self.n], unpack_codes(&self.packed))
    }

    /// Dequantize to a float weight matrix (tests / CPU fallback).
    pub fn dequantize(&self) -> Result<Tensor> {
        let codes = unpack_codes(&self.packed);
        let sc = self.scales.as_f32()?;
        let g = self.scales.shape[0];
        let group = self.k / g;
        let mut w = vec![0.0f32; self.k * self.n];
        for kk in 0..self.k {
            let gi = kk / group;
            for nn in 0..self.n {
                w[kk * self.n + nn] =
                    codes[kk * self.n + nn] as f32 * sc[gi * self.n + nn];
            }
        }
        Ok(Tensor::f32(&[self.k, self.n], w))
    }

    /// Packed memory footprint in bytes (codes + scales + bias).
    pub fn nbytes(&self) -> usize {
        self.packed.data.len() + self.scales.nbytes() + self.bias.nbytes()
    }
}

/// One quantized transformer block (norm params stay float — they are what
/// Norm Tweaking updates).
#[derive(Debug, Clone)]
pub struct QuantizedBlock {
    pub ln1_g: Tensor,
    pub ln1_b: Option<Tensor>,
    pub qkv: QuantLinear,
    pub proj: QuantLinear,
    pub ln2_g: Tensor,
    pub ln2_b: Option<Tensor>,
    pub fc1: QuantLinear,
    pub fc2: QuantLinear,
}

impl QuantizedBlock {
    /// The tweakable norm parameter vectors, in tweak_step argument order.
    pub fn norm_params(&self) -> Vec<&Tensor> {
        match (&self.ln1_b, &self.ln2_b) {
            (Some(b1), Some(b2)) => vec![&self.ln1_g, b1, &self.ln2_g, b2],
            _ => vec![&self.ln1_g, &self.ln2_g],
        }
    }

    /// Replace the tweakable norm params (inverse of [`norm_params`]).
    pub fn set_norm_params(&mut self, params: Vec<Tensor>) -> Result<()> {
        let has_beta = self.ln1_b.is_some();
        let need = if has_beta { 4 } else { 2 };
        if params.len() != need {
            return Err(Error::Quant(format!(
                "expected {need} norm params, got {}",
                params.len()
            )));
        }
        let mut it = params.into_iter();
        let mut take = |field: &str| {
            it.next().ok_or_else(|| {
                Error::Quant(format!("norm param `{field}` missing from a length-checked list"))
            })
        };
        self.ln1_g = take("ln1.g")?;
        if has_beta {
            self.ln1_b = Some(take("ln1.b")?);
        }
        self.ln2_g = take("ln2.g")?;
        if has_beta {
            self.ln2_b = Some(take("ln2.b")?);
        }
        Ok(())
    }
}

/// A fully quantized model: embeddings/head stay float (as in the paper —
/// only the transformer Linear layers are quantized).
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub scheme: QuantScheme,
    pub tok_emb: Tensor,
    pub pos_emb: Tensor,
    pub lnf_g: Tensor,
    pub lnf_b: Option<Tensor>,
    pub blocks: Vec<QuantizedBlock>,
}

impl QuantizedModel {
    /// Packed parameter bytes of the quantized weight matrices only.
    pub fn quantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.qkv.nbytes() + b.proj.nbytes() + b.fc1.nbytes() + b.fc2.nbytes())
            .sum()
    }

    /// Float bytes the same matrices would occupy.
    pub fn float_bytes(&self) -> usize {
        self.config
            .linear_shapes()
            .iter()
            .map(|(_, k, n)| k * n * 4)
            .sum::<usize>()
            * self.config.n_layer
    }

    /// Serialize to `.ntz` (codes packed as u8 + meta tensors).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut t = BTreeMap::new();
        t.insert("meta.bits".into(), Tensor::i32(&[1], vec![self.scheme.bits as i32]));
        t.insert(
            "meta.group".into(),
            Tensor::i32(&[1], vec![self.scheme.group_size.unwrap_or(0) as i32]),
        );
        t.insert("tok_emb".into(), self.tok_emb.clone());
        t.insert("pos_emb".into(), self.pos_emb.clone());
        t.insert("lnf.g".into(), self.lnf_g.clone());
        if let Some(b) = &self.lnf_b {
            t.insert("lnf.b".into(), b.clone());
        }
        for (i, blk) in self.blocks.iter().enumerate() {
            let p = format!("block{i}.");
            t.insert(format!("{p}ln1.g"), blk.ln1_g.clone());
            t.insert(format!("{p}ln2.g"), blk.ln2_g.clone());
            if let Some(b) = &blk.ln1_b {
                t.insert(format!("{p}ln1.b"), b.clone());
            }
            if let Some(b) = &blk.ln2_b {
                t.insert(format!("{p}ln2.b"), b.clone());
            }
            for (name, q) in [("attn.wqkv", &blk.qkv), ("attn.wproj", &blk.proj),
                              ("mlp.wfc1", &blk.fc1), ("mlp.wfc2", &blk.fc2)] {
                t.insert(format!("{p}{name}.packed"),
                         Tensor::u8(&[q.packed.data.len()], q.packed.data.clone()));
                t.insert(format!("{p}{name}.shape"),
                         Tensor::i32(&[2], vec![q.k as i32, q.n as i32]));
                // per-linear pack width: layers may override the model-level
                // bit width (mixed precision via `PipelineConfig::scheme_for`)
                t.insert(format!("{p}{name}.pbits"),
                         Tensor::i32(&[1], vec![q.packed.bits as i32]));
                t.insert(format!("{p}{name}.scales"), q.scales.clone());
                t.insert(format!("{p}{name}.bias"), q.bias.clone());
            }
        }
        save_ntz(path, &t)
    }

    /// Load a serialized quantized model.
    pub fn load(config: ModelConfig, path: impl AsRef<Path>) -> Result<Self> {
        let t = load_ntz(path)?;
        let get = |n: &str| -> Result<&Tensor> {
            t.get(n).ok_or_else(|| Error::Checkpoint(format!("missing {n}")))
        };
        let bits = get("meta.bits")?.as_i32()?[0] as u8;
        let group = get("meta.group")?.as_i32()?[0] as usize;
        let scheme = QuantScheme {
            bits,
            group_size: if group == 0 { None } else { Some(group) },
        };
        let ln = config.norm == NormKind::LayerNorm;
        let mut blocks = Vec::new();
        for i in 0..config.n_layer {
            let p = format!("block{i}.");
            let linear = |name: &str| -> Result<QuantLinear> {
                let shape = get(&format!("{p}{name}.shape"))?.as_i32()?;
                let (k, n) = (shape[0] as usize, shape[1] as usize);
                let data = get(&format!("{p}{name}.packed"))?.as_u8()?.to_vec();
                // pre-mixed-precision checkpoints have no pbits tensor: fall
                // back to the model-level *storage* width (3-bit codes pack
                // into 4-bit slots, so raw `bits` would misalign the unpack)
                let pbits = match t.get(&format!("{p}{name}.pbits")) {
                    Some(v) => v.as_i32()?[0] as u8,
                    None => scheme.pack_bits()?,
                };
                Ok(QuantLinear::new(
                    k,
                    n,
                    PackedCodes { bits: pbits, len: k * n, data },
                    get(&format!("{p}{name}.scales"))?.clone(),
                    get(&format!("{p}{name}.bias"))?.clone(),
                ))
            };
            blocks.push(QuantizedBlock {
                ln1_g: get(&format!("{p}ln1.g"))?.clone(),
                ln1_b: if ln { Some(get(&format!("{p}ln1.b"))?.clone()) } else { None },
                qkv: linear("attn.wqkv")?,
                proj: linear("attn.wproj")?,
                ln2_g: get(&format!("{p}ln2.g"))?.clone(),
                ln2_b: if ln { Some(get(&format!("{p}ln2.b"))?.clone()) } else { None },
                fc1: linear("mlp.wfc1")?,
                fc2: linear("mlp.wfc2")?,
            });
        }
        Ok(QuantizedModel {
            scheme,
            tok_emb: get("tok_emb")?.clone(),
            pos_emb: get("pos_emb")?.clone(),
            lnf_g: get("lnf.g")?.clone(),
            lnf_b: if ln { Some(get("lnf.b")?.clone()) } else { None },
            blocks,
            config,
        })
    }

    /// Carry the float (non-quantized) tensors over from a float checkpoint.
    pub fn scaffold(w: &ModelWeights, scheme: QuantScheme) -> Result<Self> {
        Ok(QuantizedModel {
            config: w.config.clone(),
            scheme,
            tok_emb: w.get("tok_emb")?.clone(),
            pos_emb: w.get("pos_emb")?.clone(),
            lnf_g: w.get("lnf.g")?.clone(),
            lnf_b: match w.config.norm {
                NormKind::LayerNorm => Some(w.get("lnf.b")?.clone()),
                NormKind::RmsNorm => None,
            },
            blocks: Vec::with_capacity(w.config.n_layer),
        })
    }
}

/// Helper for tests and external quantizers: build a [`QuantLinear`] from
/// raw codes (the pipeline's `to_quant_linear` constructs directly).
#[allow(dead_code)]
pub fn quant_linear_from(
    codes: &[i8],
    k: usize,
    n: usize,
    scales: Tensor,
    bias: Tensor,
    bits: u8,
) -> Result<QuantLinear> {
    Ok(QuantLinear::new(k, n, pack_codes(codes, bits)?, scales, bias))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantScheme;

    fn mk_linear(k: usize, n: usize, bits: u8) -> QuantLinear {
        let qmax = ((1i32 << (bits - 1)) - 1) as usize;
        let codes: Vec<i8> = (0..k * n)
            .map(|i| ((i % (2 * qmax + 1)) as i32 - qmax as i32) as i8)
            .collect();
        quant_linear_from(&codes, k, n, Tensor::ones(&[1, n]), Tensor::zeros(&[n]), bits).unwrap()
    }

    #[test]
    fn dequant_roundtrip_identity_scales() {
        let q = mk_linear(8, 4, 4);
        let w = q.dequantize().unwrap();
        let codes = q.codes_tensor();
        for i in 0..32 {
            assert_eq!(w.as_f32().unwrap()[i], codes.as_i8().unwrap()[i] as f32);
        }
    }

    #[test]
    fn memory_reduction() {
        let q2 = mk_linear(64, 64, 2);
        let q4 = mk_linear(64, 64, 4);
        // packed codes: 2-bit = numel/4 bytes, 4-bit = numel/2
        assert_eq!(q2.packed.data.len(), 64 * 64 / 4);
        assert_eq!(q4.packed.data.len(), 64 * 64 / 2);
    }

    #[test]
    fn quantized_model_save_load() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let w = ModelWeights::random(cfg.clone(), 5);
        let scheme = QuantScheme { bits: 4, group_size: None };
        let mut qm = QuantizedModel::scaffold(&w, scheme).unwrap();
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        for i in 0..cfg.n_layer {
            let b = w.block(i).unwrap();
            qm.blocks.push(QuantizedBlock {
                ln1_g: b.ln1_g.clone(),
                ln1_b: b.ln1_b.cloned(),
                qkv: mk_linear(d, 3 * d, 4),
                proj: mk_linear(d, d, 4),
                ln2_g: b.ln2_g.clone(),
                ln2_b: b.ln2_b.cloned(),
                fc1: mk_linear(d, ff, 4),
                fc2: mk_linear(ff, d, 4),
            });
        }
        let dir = std::env::temp_dir().join("nt_qmodel_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("q.ntz");
        qm.save(&path).unwrap();
        let back = QuantizedModel::load(cfg, &path).unwrap();
        assert_eq!(back.scheme.bits, 4);
        assert_eq!(back.blocks.len(), qm.blocks.len());
        assert_eq!(back.blocks[0].qkv.packed, qm.blocks[0].qkv.packed);
        assert_eq!(back.blocks[1].fc2.scales, qm.blocks[1].fc2.scales);
    }

    #[test]
    fn norm_param_roundtrip() {
        let cfg = ModelConfig::builtin("nt-tiny").unwrap();
        let w = ModelWeights::random(cfg.clone(), 5);
        let b = w.block(0).unwrap();
        let mut blk = QuantizedBlock {
            ln1_g: b.ln1_g.clone(),
            ln1_b: b.ln1_b.cloned(),
            qkv: mk_linear(cfg.d_model, 3 * cfg.d_model, 4),
            proj: mk_linear(cfg.d_model, cfg.d_model, 4),
            ln2_g: b.ln2_g.clone(),
            ln2_b: b.ln2_b.cloned(),
            fc1: mk_linear(cfg.d_model, cfg.d_ff, 4),
            fc2: mk_linear(cfg.d_ff, cfg.d_model, 4),
        };
        assert_eq!(blk.norm_params().len(), 4);
        let new: Vec<Tensor> = (0..4).map(|i| Tensor::randn(&[cfg.d_model], i, 1.0)).collect();
        blk.set_norm_params(new.clone()).unwrap();
        assert_eq!(blk.ln1_g, new[0]);
        assert_eq!(blk.ln2_b.as_ref().unwrap(), &new[3]);
        assert!(blk.set_norm_params(vec![Tensor::zeros(&[4])]).is_err());
    }
}

//! Model definition: architecture configs, the parameter registry
//! (canonical tensor naming shared with Python), float checkpoints, and the
//! quantized-model container.

mod config;
mod quantized;
mod weights;

pub use config::{ModelConfig, NormKind, MODEL_REGISTRY};
pub use quantized::{QuantLinear, QuantizedBlock, QuantizedModel};
pub use weights::{BlockWeights, ModelWeights};

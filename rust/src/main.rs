//! `normtweak` CLI — quantize, evaluate, generate, serve, and check.
//!
//! ```text
//! normtweak quantize [--config cfg.toml] [--model M] [--out path]
//! normtweak plan     --target-bits 2.25 [--candidates 2,3,4,8] [--out path]
//! normtweak eval     [--checkpoint path | --float] [--ppl a,b] [--tasks x,y]
//! normtweak generate [--n 4] [--len 48]
//! normtweak serve    [--checkpoint path | --models w4=a.ntz,w2=b.ntz]
//!                    [--requests 64] [--clients 4] [--deadline-ms 500] [--cache 256]
//! normtweak search   --target-bits 2.25 [--budget N] [--methods rtn,gptq]
//!                    [--resume state.json] [--out recipe.json] [--ppl]
//! normtweak check    [--manifest DIR] [--ckpt q.ntz] [--scheme gptq:w4g64]
//!                    [--recipe recipe.json] [--graphs] [--format human|json]
//!                    [--deny-warnings]
//! ```

// same discipline as the library crate: the binary reports failures as
// `Error` values, not panics (tests keep their unwraps)
#![warn(clippy::unwrap_used)]
#![cfg_attr(test, allow(clippy::unwrap_used))]

use std::sync::Arc;

use normtweak::analysis;
use normtweak::calib::vocab::BOS;
use normtweak::coordinator::{build_calib, quantize_model, FloatModel, PipelineConfig, QuantModel};
use normtweak::eval::{lambada, ppl, subjective, tasks};
use normtweak::model::{ModelConfig, ModelWeights, QuantizedModel};
use normtweak::obs::trace::TraceCollector;
use normtweak::policy::{
    BitBudgetPlanner, SensitivityConfig, SensitivityProfile, SensitivityProfiler,
};
use normtweak::report::{f2, f4, save_record, Table};
use normtweak::runtime::{ArtifactManifest, Runtime};
use normtweak::search::{
    default_tweak_grid, Recipe, RecipeProvenance, SearchConfig, SearchOutcome, SearchRunner,
    SpaceConfig,
};
use normtweak::tweak::LossKind;
use normtweak::util::hash::file_hex;
use normtweak::util::json;
use normtweak::Config;

/// Flags every subcommand accepts.
const GLOBAL_FLAGS: &[&str] = &["config", "model", "artifacts"];

/// Per-command flag allowlist; None = unknown command.
fn allowed_flags(cmd: &str) -> Option<&'static [&'static str]> {
    match cmd {
        "quantize" => Some(&["method", "bits", "group", "layer-bits", "no-tweak",
                             "calib", "out", "auto-bits", "profile", "deep-check",
                             "trace", "recipe", "dry-run"]),
        "plan" => Some(&["method", "bits", "group", "calib", "target-bits",
                         "candidates", "loss", "profile", "out", "format"]),
        "search" => Some(&["target-bits", "budget", "resume", "out", "profile",
                           "methods", "seed", "ppl", "trace"]),
        "eval" => Some(&["checkpoint", "float", "ppl", "tasks"]),
        "generate" => Some(&["n", "len"]),
        "serve" => Some(&["checkpoint", "requests", "clients", "models",
                          "deadline-ms", "cache", "deep-check", "trace"]),
        "check" => Some(&["ckpt", "manifest", "scheme", "layer-bits", "no-tweak",
                          "profile", "target-bits", "serve-config", "models",
                          "recipe", "graphs", "format", "deny-warnings"]),
        "help" | "--help" => Some(&[]),
        _ => None,
    }
}

/// Tiny flag parser: `--key value` pairs + a leading subcommand.
/// Strict: positional stragglers and flags outside the command's allowlist
/// are rejected with a pointer at `normtweak help` instead of being
/// silently dropped.
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> normtweak::Result<Self> {
        Self::from_iter(std::env::args().skip(1))
    }

    fn from_iter(argv: impl Iterator<Item = String>) -> normtweak::Result<Self> {
        let mut argv = argv;
        let cmd = argv.next().unwrap_or_else(|| "help".to_string());
        let mut flags = std::collections::HashMap::new();
        let mut key: Option<String> = None;
        for a in argv {
            if let Some(k) = a.strip_prefix("--") {
                // bare boolean flags get "true"
                if let Some(prev) = key.take() {
                    flags.insert(prev, "true".to_string());
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, a);
            } else {
                return Err(normtweak::Error::Config(format!(
                    "unexpected positional argument `{a}` (flags are `--key value`); \
                     see `normtweak help`"
                )));
            }
        }
        if let Some(prev) = key.take() {
            flags.insert(prev, "true".to_string());
        }
        let args = Args { cmd, flags };
        args.validate()?;
        Ok(args)
    }

    fn validate(&self) -> normtweak::Result<()> {
        let Some(allowed) = allowed_flags(&self.cmd) else {
            // unknown command: reported (with help) by the dispatch below
            return Ok(());
        };
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) && !GLOBAL_FLAGS.contains(&k.as_str()) {
                return Err(normtweak::Error::Config(format!(
                    "unknown flag `--{k}` for `normtweak {}`; see `normtweak help`",
                    self.cmd
                )));
            }
        }
        Ok(())
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn get_or(&self, k: &str, default: &str) -> String {
        self.get(k).unwrap_or(default).to_string()
    }

    fn get_usize(&self, k: &str, default: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

const HELP: &str = "normtweak — Norm Tweaking PTQ (AAAI 2024 reproduction)

USAGE:
  normtweak quantize [--config cfg.toml] [--model M] [--method gptq] [--bits 4]
                     [--group 0] [--layer-bits 0:8,11:8] [--no-tweak]
                     [--auto-bits 2.25] [--profile sensitivity.json]
                     [--recipe recipe.json] [--dry-run]
                     [--calib gen-v2] [--out path] [--deep-check]
                     [--trace trace.json]
  normtweak plan     --target-bits 2.25 [--model M] [--method gptq] [--bits 2]
                     [--group 64] [--candidates 2,3,4,8] [--loss dist]
                     [--calib gen-v2] [--profile path] [--out sensitivity.json]
                     [--format human|json]
  normtweak search   --target-bits 2.25 [--model M] [--budget 4]
                     [--methods rtn,gptq] [--profile sensitivity.json]
                     [--seed 7] [--resume state.json] [--out recipe.json]
                     [--ppl wiki-syn] [--trace trace.json]
  normtweak eval     [--checkpoint path | --float] [--model M]
                     [--ppl wiki-syn,c4-syn] [--tasks hellaswag-syn,...]
  normtweak generate [--model M] [--n 4] [--len 48]
  normtweak serve    [--checkpoint path | --models w4=a.ntz,w2=b.ntz]
                     [--requests 64] [--clients 4] [--deadline-ms 500]
                     [--cache 256] [--deep-check] [--trace trace.json]
  normtweak check    [--manifest DIR] [--ckpt quantized.ntz]
                     [--scheme gptq:w4g64] [--layer-bits 0:8,3:2] [--no-tweak]
                     [--profile sensitivity.json] [--target-bits 2.25]
                     [--serve-config max_batch=8,batch_window_ms=2]
                     [--models w4=a.ntz] [--recipe recipe.json] [--graphs]
                     [--format human|json] [--deny-warnings]
  normtweak help

MULTI-MODEL SERVING:
  `serve` hosts one or more quantized checkpoints behind the engine's
  deadline-aware batching scheduler. `--models` registers several variants
  of the architecture at once (e.g. a w2 fleet with a w4 fallback from
  `quantize --auto-bits`); `--deadline-ms` attaches a per-request answer-by
  budget (missed deadlines return an error, not silence) and `--cache N`
  enables an N-entry LRU response cache for repeated greedy prompts.

AUTOMATIC MIXED PRECISION:
  `plan` measures per-layer quantization sensitivity over the calibration
  set (trial-quantizing each block at every --candidates width with the
  configured --method), persists the profile to sensitivity.json (--out),
  and prints the greedy allocation whose mean width fits --target-bits.
  `quantize --auto-bits B` runs the same planner — reusing an existing
  sensitivity.json (or --profile PATH) instead of re-profiling — and feeds
  the resulting per-layer overrides straight into the pipeline. `plan
  --format json` prints the allocation as machine-clean normtweak.plan.v1
  JSON on stdout — the same schema a recipe embeds.

RECIPE SEARCH:
  `search` enumerates scheme assignments (--methods from the quantizer
  registry x the manifest's exported grains x a tweak hyper-parameter grid
  around the configured base), prunes the space against the persisted
  sensitivity profile without touching the model, escalates the surviving
  (method, grain) groups — at most --budget of them — to offline trial
  quantization scored with the tweak-loss kernels, and optionally (--ppl
  [corpus]) scores the winning group by held-out perplexity. Search state
  checkpoints after every escalation (--resume PATH picks the state file),
  so a killed run resumes without repeating finished trials. The winner
  plus the scored frontier persist as a replayable recipe.json (--out)
  with full provenance: manifest hash, profile path + content hash, the
  exact space and seed, and per-stage funnel counts.

  `quantize --recipe recipe.json` replays a recipe bit-exactly — the
  method, base scheme, tweak point, and every per-layer width come from
  the recipe (mutually exclusive with --method/--bits/--group/
  --layer-bits/--auto-bits/--no-tweak), after an NT06xx preflight against
  the live artifacts. `--dry-run` prints the recipe's per-layer scheme map
  as JSON and exits without loading anything. `check --recipe` runs the
  same NT06xx audit standalone: recipe grain vs manifest grain table,
  recipe model vs checkpoint architecture, tweak-loss graph presence, and
  sensitivity-profile provenance (path + content hash).

PRE-FLIGHT CHECK:
  `check` lints artifacts and configs offline — no XLA client, no model
  load. It cross-checks manifest.json schema and grain/bucket consistency,
  checkpoint tensors against the manifest and architecture, scheme/plan
  legality (--scheme [method:]w<bits><pc|g<N>>, --layer-bits overrides,
  --profile feasibility at --target-bits), and serve tunings
  (--serve-config key=value, --models entries). Unlike the fail-fast
  startup validation it backs, `check` reports every finding in one run as
  stable NTxxxx diagnostics (table in the `analysis` module docs). Exit is
  non-zero on any error — and on warnings too with --deny-warnings;
  --format json emits the machine-readable report for CI.

  --graphs adds the deep NT05xx pass: every graph's HLO ENTRY signature is
  parsed and checked against the manifest's recorded exporter intent and
  against the reconstructed pipeline dataflow (embed->block->head streams,
  quantized-block code/scale geometry per grain, prefill-KV caches vs the
  decode spec [H, S, dh], per-row pos i32[B] decode contracts, scalar
  tweak losses). `quantize --deep-check` and `serve --deep-check` run the
  same pass as an opt-in startup preflight.

OBSERVABILITY:
  Progress narration goes to stderr through a leveled logger; set
  NORMTWEAK_LOG=error|warn|info|debug to tune it (unset + NT_QUIET maps
  to warn). `quantize --trace out.json` records per-layer pipeline phase
  spans (float ref, quantize, pack, tweak — with per-iteration tweak-loss
  counter samples) plus per-graph XLA compile/execute timings;
  `serve --trace out.json` records the engine request lifecycle
  (submit -> admit -> prefill -> per-step decode -> retire, one track per
  lane). Exports are Chrome trace-event JSON: load them in
  chrome://tracing or ui.perfetto.dev. `normtweak check` diagnostics ride
  the same logger on stderr, so `--format json` stdout stays
  machine-clean.
";

/// A reused `sensitivity.json` must actually describe the model being
/// planned: a stale profile from another model would silently leave the
/// uncovered layers at the base scheme (grain mismatches are caught later
/// by the planner itself).
fn check_profile_matches(
    profile: &SensitivityProfile,
    path: &str,
    mcfg: &normtweak::model::ModelConfig,
) -> normtweak::Result<()> {
    if profile.model != mcfg.name {
        return Err(normtweak::Error::Config(format!(
            "profile {path} was measured on model `{}` but this run targets `{}`; \
             re-run `normtweak plan` (or delete the stale profile)",
            profile.model, mcfg.name
        )));
    }
    if profile.layers.len() != mcfg.n_layer {
        return Err(normtweak::Error::Config(format!(
            "profile {path} covers {} layers but `{}` has {}; re-profile",
            profile.layers.len(),
            mcfg.name,
            mcfg.n_layer
        )));
    }
    Ok(())
}

/// The float checkpoint whose bytes sensitivity profiles pin: profiles
/// record its hash at measure time, and `plan`/`search` preflights compare
/// it against the file on disk (NT0311) before reusing scores.
fn weights_file(cfg: &Config) -> std::path::PathBuf {
    std::path::Path::new(&cfg.run.artifacts).join(format!("weights_{}.ntz", cfg.run.model))
}

/// Parse `--candidates 2,3,4,8` into candidate bit widths.
fn parse_candidates(spec: &str) -> normtweak::Result<Vec<u8>> {
    spec.split(',')
        .map(|t| {
            t.trim().parse::<u8>().map_err(|_| {
                normtweak::Error::Config(format!(
                    "bad candidate bit width `{}` in --candidates",
                    t.trim()
                ))
            })
        })
        .collect()
}

/// The `--method` registry table, rendered from the live plugin registry.
fn print_method_table() {
    println!("METHODS (--method; compose stages with `+`, e.g. smoothquant+gptq):");
    for r in normtweak::quant::registry() {
        println!("  {:<14} {}", r.name, r.summary);
    }
    println!(
        "  a+b            run a's preprocessing, then quantize with b \
         (any registered names)"
    );
}

/// Build the `--trace` collector when the flag is present.  The same
/// collector threads through the runtime / engine; [`write_trace`] exports
/// it at command exit, so an accepted `--trace` flag always produces a
/// file.
fn init_trace(args: &Args) -> Option<(Arc<TraceCollector>, String)> {
    args.get("trace").map(|path| {
        (
            Arc::new(TraceCollector::new(normtweak::obs::trace::DEFAULT_CAPACITY)),
            path.to_string(),
        )
    })
}

/// Export the collected Chrome trace (global metrics snapshot embedded
/// under the viewer-ignored `metrics` key) to `path`.
fn write_trace(tc: &TraceCollector, path: &str) -> normtweak::Result<()> {
    tc.write_chrome(
        std::path::Path::new(path),
        Some(&normtweak::obs::global().snapshot()),
    )?;
    normtweak::log_info!("trace", "wrote {} events -> {path}", tc.len());
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        normtweak::log_error!("cli", "{e}");
        std::process::exit(1);
    }
}

fn run() -> normtweak::Result<()> {
    let args = Args::parse()?;
    if args.cmd == "help" || args.cmd == "--help" {
        print!("{HELP}");
        println!();
        print_method_table();
        return Ok(());
    }

    let mut cfg = match args.get("config") {
        Some(p) => Config::load(p)?,
        None => Config::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.run.model = m.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.run.artifacts = a.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.quant.method = m.to_string();
    }
    if let Some(b) = args.get("bits") {
        cfg.quant.bits = b.parse().map_err(|_| normtweak::Error::Config("bad --bits".into()))?;
    }
    if let Some(g) = args.get("group") {
        cfg.quant.group = g.parse().map_err(|_| normtweak::Error::Config("bad --group".into()))?;
    }
    if let Some(lb) = args.get("layer-bits") {
        cfg.quant.layer_bits = lb.split(',').map(String::from).collect();
    }
    if args.has("no-tweak") {
        cfg.tweak.enabled = false;
    }
    if let Some(c) = args.get("calib") {
        cfg.calib.source = c.to_string();
    }
    // `search` reuses --ppl as its stage-2 opt-in (value optional), so only
    // the eval-style commands treat it as the corpus list
    if let Some(p) = args.get("ppl").filter(|_| args.cmd != "search") {
        cfg.eval.ppl = p.split(',').map(String::from).collect();
    }
    if let Some(t) = args.get("tasks") {
        cfg.eval.tasks = t.split(',').map(String::from).collect();
    }

    // `serve` builds its per-model runtimes inside the engine thread (and
    // needs no float weights); everything else shares one runtime + the
    // float checkpoint, loaded lazily so a bad command doesn't pay for it
    let load_ctx = || -> normtweak::Result<(Runtime, ModelWeights)> {
        let runtime = Runtime::new(&cfg.run.artifacts)?;
        let weights = ModelWeights::load_from_dir(&cfg.run.model, &cfg.run.artifacts)?;
        Ok((runtime, weights))
    };

    match args.cmd.as_str() {
        "quantize" => {
            // --recipe replays a persisted search product instead of
            // assembling a config from flags; the two sources are mutually
            // exclusive so a replay can never be silently half-overridden
            let recipe = match args.get("recipe") {
                Some(rpath) => {
                    for f in ["method", "bits", "group", "layer-bits",
                              "auto-bits", "no-tweak", "profile"] {
                        if args.has(f) {
                            return Err(normtweak::Error::Config(format!(
                                "--{f} is mutually exclusive with --recipe: the \
                                 recipe pins the method, scheme, tweak, and \
                                 per-layer widths"
                            )));
                        }
                    }
                    if args.has("dry-run") {
                        // offline: print the per-layer scheme map and exit
                        // before any artifact or checkpoint loads
                        let r = Recipe::load(rpath)?;
                        println!("{}", r.layer_map_json().emit());
                        return Ok(());
                    }
                    // NT06xx preflight: the recipe must still describe the
                    // live artifacts (grain exported, model matches, tweak
                    // graph present, profile unchanged) before replay
                    analysis::preflight(&analysis::CheckContext {
                        manifest: ArtifactManifest::load(&cfg.run.artifacts).ok(),
                        model: ModelConfig::builtin(&cfg.run.model).ok(),
                        model_name: Some(cfg.run.model.clone()),
                        recipe_path: Some(std::path::PathBuf::from(rpath)),
                        ..Default::default()
                    })?;
                    Some(Recipe::load(rpath)?)
                }
                None => {
                    if args.has("dry-run") {
                        return Err(normtweak::Error::Config(
                            "--dry-run needs --recipe recipe.json (it prints the \
                             recipe's per-layer scheme map)"
                                .into(),
                        ));
                    }
                    None
                }
            };
            let (mut runtime, weights) = load_ctx()?;
            let trace_cfg = init_trace(&args);
            if let Some((tc, _)) = &trace_cfg {
                runtime.set_trace(tc.clone());
            }
            // opt-in deep preflight: the NT05xx graphs pass statically
            // verifies every exported HLO signature before any layer runs
            if args.has("deep-check") {
                analysis::preflight(&analysis::CheckContext {
                    manifest_dir: Some(std::path::PathBuf::from(&cfg.run.artifacts)),
                    manifest: ArtifactManifest::load(&cfg.run.artifacts).ok(),
                    graphs: true,
                    ..Default::default()
                })?;
            }
            let out = args.get_or("out", "artifacts/quantized.ntz");
            let calib = build_calib(&runtime, &weights, &cfg.calib.source,
                                    cfg.calib.n_samples, cfg.calib.seed)?;
            let mut pcfg;
            if let Some(r) = &recipe {
                normtweak::log_info!(
                    "quantize",
                    "replaying recipe for {}: {}{} across {} planned layer(s)",
                    r.model,
                    r.method,
                    if r.tweak.is_some() { "+NT" } else { "" },
                    r.plan.schemes.len()
                );
                pcfg = r.to_pipeline_config()?;
            } else {
                pcfg = PipelineConfig::new(cfg.method()?, cfg.scheme());
                for (layer, scheme) in cfg.layer_schemes()? {
                    pcfg = pcfg.with_layer_scheme(layer, scheme);
                }
                if let Some(budget) = args.get("auto-bits") {
                    if !cfg.quant.layer_bits.is_empty() {
                        return Err(normtweak::Error::Config(
                            "--auto-bits is mutually exclusive with --layer-bits / \
                             [quant] layer_bits: the planner emits the per-layer \
                             overrides itself"
                                .into(),
                        ));
                    }
                    let target: f32 = budget
                        .parse()
                        .map_err(|_| normtweak::Error::Config("bad --auto-bits".into()))?;
                    let default_profile = format!("{}/sensitivity.json", cfg.run.artifacts);
                    let ppath = args.get_or("profile", &default_profile);
                    let profile = if std::path::Path::new(&ppath).exists() {
                        let p = SensitivityProfile::load(&ppath)?;
                        check_profile_matches(&p, &ppath, &weights.config)?;
                        normtweak::log_info!(
                            "quantize",
                            "auto-bits: reusing profile {ppath} ({})",
                            p.provenance()
                        );
                        p
                    } else {
                        let mut scfg = SensitivityConfig::new(cfg.method()?, cfg.scheme());
                        scfg.loss = LossKind::from_str(&cfg.tweak.loss)?;
                        let mut p = SensitivityProfiler::new(&runtime, &weights, scfg)
                            .profile(&calib)?;
                        // pin the checkpoint the scores were measured on, so
                        // a later plan/search run can detect drift (NT0311)
                        p.ckpt_hash = file_hex(weights_file(&cfg)).ok();
                        p.save(&ppath)?;
                        normtweak::log_info!(
                            "quantize",
                            "auto-bits: profiled {} layers -> {ppath}",
                            p.layers.len()
                        );
                        p
                    };
                    let plan = BitBudgetPlanner::new(cfg.scheme(), target).plan(&profile)?;
                    normtweak::log_info!(
                        "quantize",
                        "auto-bits plan: mean {:.3} bits (target {target}); --layer-bits {}",
                        plan.mean_bits,
                        plan.layer_bits_string()
                    );
                    for (layer, scheme) in &plan.schemes {
                        pcfg = pcfg.with_layer_scheme(*layer, *scheme);
                    }
                    pcfg = pcfg.with_plan_note(format!(
                        "auto-bits {target}: mean {:.3} bits from {}",
                        plan.mean_bits,
                        profile.provenance()
                    ));
                }
                if let Some(t) = cfg.tweak_config()? {
                    pcfg = pcfg.with_tweak(t);
                }
            }
            let (qm, metrics) = quantize_model(&runtime, &weights, &calib, &pcfg)?;
            qm.save(&out)?;
            save_record(&cfg.run.artifacts, "last_quantize", &metrics.to_json())?;
            println!(
                "quantized {} with {}{} -> {out} ({}x compression, {} ms)",
                cfg.run.model,
                metrics.method,
                if metrics.tweaked { "+NT" } else { "" },
                f2(1.0 / metrics.compression_ratio),
                metrics.total_millis
            );
            if let Some((tc, path)) = &trace_cfg {
                write_trace(tc, path)?;
            }
        }
        "plan" => {
            let format = args.get_or("format", "human");
            if format != "human" && format != "json" {
                return Err(normtweak::Error::Config(format!(
                    "bad --format `{format}` (accepted: human, json)"
                )));
            }
            let (runtime, weights) = load_ctx()?;
            let target: f32 = args
                .get("target-bits")
                .ok_or_else(|| {
                    normtweak::Error::Config(
                        "plan needs --target-bits <avg bits>, e.g. --target-bits 2.25"
                            .into(),
                    )
                })?
                .parse()
                .map_err(|_| normtweak::Error::Config("bad --target-bits".into()))?;
            let base = cfg.scheme();
            let default_out = format!("{}/sensitivity.json", cfg.run.artifacts);
            let out = args.get_or("out", &default_out);
            let profile = match args.get("profile") {
                Some(p) => {
                    // the profiling knobs have no effect on a reused profile:
                    // reject them instead of silently planning under other
                    // settings than the user asked for
                    for flag in ["candidates", "loss", "calib", "out"] {
                        if args.has(flag) {
                            return Err(normtweak::Error::Config(format!(
                                "--{flag} has no effect when reusing --profile {p}; \
                                 drop --profile to re-measure with it"
                            )));
                        }
                    }
                    let prof = SensitivityProfile::load(p)?;
                    check_profile_matches(&prof, p, &weights.config)?;
                    normtweak::log_info!("plan", "loaded profile {p} ({})", prof.provenance());
                    prof
                }
                None => {
                    let mut scfg = SensitivityConfig::new(cfg.method()?, base);
                    scfg.loss = LossKind::from_str(&cfg.tweak.loss)?;
                    if let Some(l) = args.get("loss") {
                        scfg.loss = LossKind::from_str(l)?;
                    }
                    if let Some(c) = args.get("candidates") {
                        scfg.candidate_bits = parse_candidates(c)?;
                    }
                    let calib = build_calib(&runtime, &weights, &cfg.calib.source,
                                            cfg.calib.n_samples, cfg.calib.seed)?;
                    let mut prof = SensitivityProfiler::new(&runtime, &weights, scfg)
                        .profile(&calib)?;
                    // pin the checkpoint the scores were measured on, so a
                    // later plan/search run can detect drift (NT0311)
                    prof.ckpt_hash = file_hex(weights_file(&cfg)).ok();
                    prof.save(&out)?;
                    normtweak::log_info!(
                        "plan",
                        "profiled {} layers -> {out} ({})",
                        prof.layers.len(),
                        prof.provenance()
                    );
                    prof
                }
            };
            // lint-backed pre-flight: audit the persisted profile, the
            // budget's feasibility, and the base grain's exported graphs —
            // collecting every NT03xx finding — before the greedy planner
            // commits to an allocation
            analysis::preflight(&analysis::CheckContext {
                manifest: ArtifactManifest::load(&cfg.run.artifacts).ok(),
                model: Some(weights.config.clone()),
                model_name: Some(cfg.run.model.clone()),
                plan: Some(analysis::PlanSpec {
                    method: cfg.quant.method.clone(),
                    scheme: base,
                    layer_schemes: Vec::new(),
                    tweak_loss: None,
                }),
                profile_path: Some(std::path::PathBuf::from(args.get_or("profile", &out))),
                target_bits: Some(target),
                weights_path: Some(weights_file(&cfg)),
                ..Default::default()
            })?;
            let plan = BitBudgetPlanner::new(base, target).plan(&profile)?;
            if format == "json" {
                // machine-clean stdout: exactly the normtweak.plan.v1 tree a
                // recipe embeds (narration stays on stderr via the logger)
                println!("{}", plan.to_json().emit());
            } else {
                let table = normtweak::report::repro::plan_table(&profile, &plan, target);
                print!("{}", table.ascii());
                println!(
                    "mean {:.3} bits <= target {target}; --layer-bits {}",
                    plan.mean_bits,
                    plan.layer_bits_string()
                );
            }
            save_record(
                &cfg.run.artifacts,
                "last_plan",
                &json::obj(vec![
                    ("profile", json::s(profile.provenance())),
                    ("target_bits", json::n(target as f64)),
                    ("mean_bits", json::n(plan.mean_bits as f64)),
                    ("layer_bits", json::s(plan.layer_bits_string())),
                ]),
            )?;
        }
        "search" => {
            let target: f32 = args
                .get("target-bits")
                .ok_or_else(|| {
                    normtweak::Error::Config(
                        "search needs --target-bits <avg bits>, e.g. --target-bits 2.25"
                            .into(),
                    )
                })?
                .parse()
                .map_err(|_| normtweak::Error::Config("bad --target-bits".into()))?;
            let budget = args.get_usize("budget", 2).max(1);
            let seed: u64 = match args.get("seed") {
                Some(v) => v
                    .parse()
                    .map_err(|_| normtweak::Error::Config("bad --seed".into()))?,
                None => cfg.calib.seed,
            };
            let default_out = format!("{}/recipe.json", cfg.run.artifacts);
            let out = args.get_or("out", &default_out);
            let state_path = args.get_or("resume", &format!("{out}.state"));

            // the search itself is offline: it scores trial quantizations on
            // the float checkpoint directly, with no XLA client. A missing
            // checkpoint degrades to seeded synthetic weights so fixture-only
            // environments (CI) can still exercise the full funnel.
            let wfile = weights_file(&cfg);
            let weights = if wfile.exists() {
                ModelWeights::load_from_dir(&cfg.run.model, &cfg.run.artifacts)?
            } else {
                normtweak::log_warn!(
                    "search",
                    "no float checkpoint at {}; scoring trials on seeded \
                     synthetic weights",
                    wfile.display()
                );
                ModelWeights::random(ModelConfig::builtin(&cfg.run.model)?, seed)
            };

            // stage 0 needs a persisted profile — search never re-measures
            let default_profile = format!("{}/sensitivity.json", cfg.run.artifacts);
            let ppath = args.get_or("profile", &default_profile);
            if !std::path::Path::new(&ppath).exists() {
                return Err(normtweak::Error::Config(format!(
                    "search plans from a persisted sensitivity profile, and \
                     {ppath} does not exist; run `normtweak plan --target-bits \
                     {target}` first (or point --profile at one)"
                )));
            }
            let profile = SensitivityProfile::load(&ppath)?;
            check_profile_matches(&profile, &ppath, &weights.config)?;

            // axes: methods from the flag (default: the configured method),
            // grains from the manifest's exported grain table (a grain the
            // AOT export never compiled cannot be deployed), tweak grid
            // around the configured base point
            let manifest = ArtifactManifest::load(&cfg.run.artifacts).ok();
            let methods: Vec<String> = match args.get("methods") {
                Some(csv) => csv
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
                None => vec![cfg.quant.method.clone()],
            };
            let grains: Vec<String> = match &manifest {
                Some(m) => m.grain_tags().iter().map(|t| t.to_string()).collect(),
                None => vec![profile.group_tag.clone()],
            };
            let tweak_grid = match cfg.tweak_config()? {
                Some(t) => default_tweak_grid(t),
                None => vec![None],
            };
            let space = SpaceConfig { methods, grains, tweak_grid, target_bits: target };

            // lint-backed preflight: profile provenance (NT0307/NT0310/
            // NT0311), budget feasibility (NT0306) — before any trial runs
            analysis::preflight(&analysis::CheckContext {
                manifest,
                model: Some(weights.config.clone()),
                model_name: Some(cfg.run.model.clone()),
                profile_path: Some(std::path::PathBuf::from(&ppath)),
                target_bits: Some(target),
                weights_path: Some(wfile.clone()),
                ..Default::default()
            })?;

            // optional stage 2: held-out perplexity through the runtime —
            // the only part of search that constructs an XLA client
            let ppl_ctx = if args.has("ppl") {
                let runtime = Runtime::new(&cfg.run.artifacts)?;
                let calib = build_calib(&runtime, &weights, &cfg.calib.source,
                                        cfg.calib.n_samples, cfg.calib.seed)?;
                let corpus = match args.get("ppl") {
                    Some("true") | None => cfg
                        .eval
                        .ppl
                        .first()
                        .cloned()
                        .unwrap_or_else(|| "wiki-syn".to_string()),
                    Some(c) => c.to_string(),
                };
                Some((runtime, calib, corpus))
            } else {
                None
            };

            let trace_cfg = init_trace(&args);
            let scfg = SearchConfig { space: space.clone(), budget, seed };
            let mut runner =
                SearchRunner::new(&profile, &weights, scfg).with_state_path(&state_path);
            if let Some((tc, _)) = &trace_cfg {
                runner = runner.with_trace(tc.clone());
            }
            if let Some((runtime, calib, corpus)) = &ppl_ctx {
                let weights = &weights;
                let ppl_tokens = cfg.eval.ppl_tokens;
                runner = runner.with_ppl(Box::new(move |cand, plan| {
                    let min_bits = plan
                        .schemes
                        .values()
                        .map(|s| s.bits)
                        .min()
                        .ok_or_else(|| normtweak::Error::Config("empty plan".into()))?;
                    let mut pcfg = PipelineConfig::new(&cand.method, cand.scheme(min_bits)?);
                    if let Some(t) = cand.tweak {
                        pcfg = pcfg.with_tweak(t);
                    }
                    for (l, s) in &plan.schemes {
                        pcfg = pcfg.with_layer_scheme(*l, *s);
                    }
                    let (qm, _) = quantize_model(runtime, weights, calib, &pcfg)?;
                    let qr = QuantModel::new(runtime, &qm)?;
                    ppl::perplexity(&qr, corpus, ppl_tokens, 8)
                }));
            }

            let outcome = runner.run()?.ok_or_else(|| {
                normtweak::Error::Config(
                    "search stopped before completing stage 1; re-run to resume \
                     from the checkpoint"
                        .into(),
                )
            })?;
            let SearchOutcome { winner, plan, frontier, stats } = outcome;
            let min_bits = plan
                .schemes
                .values()
                .map(|s| s.bits)
                .min()
                .ok_or_else(|| normtweak::Error::Config("search plan is empty".into()))?;
            let recipe = Recipe {
                model: cfg.run.model.clone(),
                method: winner.method.clone(),
                scheme: winner.scheme(min_bits)?,
                tweak: winner.tweak,
                plan,
                provenance: RecipeProvenance {
                    manifest_hash: file_hex(
                        std::path::Path::new(&cfg.run.artifacts).join("manifest.json"),
                    )
                    .ok(),
                    profile_path: ppath.clone(),
                    profile_hash: file_hex(&ppath)?,
                    space,
                    seed,
                    budget,
                    stats,
                },
                frontier,
            };
            recipe.save(&out)?;
            println!(
                "search: winner {}@{}{} — mean {:.3} bits over {} layer(s); \
                 funnel {} enumerated -> {} pruned -> {} escalated -> {} scored",
                recipe.method,
                recipe.group_tag(),
                if recipe.tweak.is_some() { "+NT" } else { "" },
                recipe.plan.mean_bits,
                recipe.plan.schemes.len(),
                recipe.provenance.stats.enumerated,
                recipe.provenance.stats.pruned,
                recipe.provenance.stats.escalated,
                recipe.provenance.stats.scored,
            );
            println!(
                "recipe -> {out}; replay with `normtweak quantize --recipe {out}`"
            );
            if let Some((tc, path)) = &trace_cfg {
                write_trace(tc, path)?;
            }
        }
        "eval" => {
            let (runtime, weights) = load_ctx()?;
            let float = args.has("float");
            let checkpoint = args.get_or("checkpoint", "artifacts/quantized.ntz");
            let mut table = Table::new(
                &format!("eval: {} ({})", cfg.run.model,
                         if float { "fp32" } else { checkpoint.as_str() }),
                &["metric", "value"],
            );
            let run_evals = |m: &dyn normtweak::eval::LanguageModel,
                             table: &mut Table| -> normtweak::Result<()> {
                if cfg.eval.lambada {
                    let set = lambada::LambadaSet::standard(m.config().seq);
                    let acc = lambada::accuracy(m, &set, 8)?;
                    table.push(vec!["lambada-syn acc %".into(), f4(acc)]);
                }
                for corpus in &cfg.eval.ppl {
                    let p = ppl::perplexity(m, corpus, cfg.eval.ppl_tokens, 8)?;
                    table.push(vec![format!("ppl {corpus}"), f4(p)]);
                }
                for tname in &cfg.eval.tasks {
                    let t = tasks::build_task(tname, 64, 0xE7A1);
                    let acc = tasks::score_task(m, &t, 8)?;
                    table.push(vec![format!("{tname} acc %"), f2(acc)]);
                }
                Ok(())
            };
            if float {
                let fm = FloatModel::new(&runtime, &weights)?;
                run_evals(&fm, &mut table)?;
            } else {
                let mcfg = ModelConfig::builtin(&cfg.run.model)?;
                let qm = QuantizedModel::load(mcfg, &checkpoint)?;
                let qr = QuantModel::new(&runtime, &qm)?.with_act_bits(cfg.act_bits());
                run_evals(&qr, &mut table)?;
            }
            print!("{}", table.ascii());
        }
        "generate" => {
            let (runtime, weights) = load_ctx()?;
            let n = args.get_usize("n", 4);
            let len = args.get_usize("len", 48);
            let fm = FloatModel::new(&runtime, &weights)?;
            let prompt = vec![BOS, 42];
            for (text, rep) in subjective::subjective_eval(&fm, &prompt, n, len)? {
                println!("[succ {:.0}% viol {}] {}",
                         rep.successor_rate * 100.0, rep.bucket_violations, text);
            }
        }
        "serve" => {
            if args.has("models") && args.has("checkpoint") {
                return Err(normtweak::Error::Config(
                    "--models and --checkpoint are mutually exclusive; put the \
                     single checkpoint in --models name=path instead"
                        .into(),
                ));
            }
            let n_requests = args.get_usize("requests", 64);
            let n_clients = args.get_usize("clients", 4).max(1);
            let deadline_ms = match args.get("deadline-ms") {
                Some(v) => Some(v.parse::<u64>().map_err(|_| {
                    normtweak::Error::Config("bad --deadline-ms".into())
                })?),
                None => None,
            };
            let cache_cap = match args.get("cache") {
                Some(v) => v.parse::<usize>().map_err(|_| {
                    normtweak::Error::Config("bad --cache (expected an entry count)".into())
                })?,
                None => 0,
            };
            // lint-backed pre-flight (NT04xx): degenerate deadlines and
            // tunings the exported batch buckets cannot honor surface here,
            // before any engine thread spins up (warnings go to stderr)
            analysis::preflight(&analysis::CheckContext {
                // --deep-check adds the NT05xx graphs pass (HLO ENTRY
                // signatures vs recorded intent vs pipeline dataflow) to
                // the startup gate
                manifest_dir: if args.has("deep-check") {
                    Some(std::path::PathBuf::from(&cfg.run.artifacts))
                } else {
                    None
                },
                manifest: ArtifactManifest::load(&cfg.run.artifacts).ok(),
                graphs: args.has("deep-check"),
                serve: Some(analysis::ServeCheck {
                    spec: deadline_ms.map(|d| format!("deadline_ms={d}")),
                    models_spec: args.get("models").map(String::from),
                }),
                ..Default::default()
            })?;
            let entries: Vec<(String, String)> = match args.get("models") {
                Some(spec) => parse_models(spec)?,
                None => vec![(
                    cfg.run.model.clone(),
                    args.get_or("checkpoint", "artifacts/quantized.ntz"),
                )],
            };
            let trace_cfg = init_trace(&args);
            let mut builder = normtweak::engine::Engine::builder().cache(cache_cap);
            if let Some((tc, _)) = &trace_cfg {
                builder = builder.trace(tc.clone());
            }
            for (key, ckpt) in entries {
                let artifacts = cfg.run.artifacts.clone();
                let arch = cfg.run.model.clone();
                // honor [quant] act_bits so served outputs match what
                // `eval` scored (the W+A modes)
                let act_bits = cfg.act_bits();
                // same collector as the scheduler: XLA spans interleave
                // with the request lifecycle on one timeline
                let trace = trace_cfg.as_ref().map(|(tc, _)| tc.clone());
                builder = builder.model(key, move || {
                    let mut sm =
                        normtweak::engine::ServableModel::load(&artifacts, &arch, &ckpt)?
                            .with_act_bits(act_bits);
                    if let Some(tc) = trace {
                        sm = sm.with_trace(tc);
                    }
                    let m: Box<dyn normtweak::eval::LanguageModel> = Box::new(sm);
                    Ok(m)
                });
            }
            serve_demo(builder.build()?, n_requests, n_clients, deadline_ms)?;
            if let Some((tc, path)) = &trace_cfg {
                write_trace(tc, path)?;
            }
        }
        "check" => {
            let format = args.get_or("format", "human");
            if format != "human" && format != "json" {
                return Err(normtweak::Error::Config(format!(
                    "bad --format `{format}` (accepted: human, json)"
                )));
            }
            let deny = args.has("deny-warnings");
            let mdir = args.get_or("manifest", &cfg.run.artifacts);
            let mcfg = ModelConfig::builtin(&cfg.run.model)?;
            let mut ctx = analysis::CheckContext {
                // the raw manifest walk runs on the directory; the parsed
                // manifest (when it loads at all) feeds the cross-checks
                manifest_dir: Some(std::path::PathBuf::from(&mdir)),
                manifest: ArtifactManifest::load(&mdir).ok(),
                ckpt_path: args.get("ckpt").map(std::path::PathBuf::from),
                model_name: Some(mcfg.name.clone()),
                model: Some(mcfg),
                profile_path: args.get("profile").map(std::path::PathBuf::from),
                recipe_path: args.get("recipe").map(std::path::PathBuf::from),
                // lets the profile/recipe provenance audits compare recorded
                // checkpoint hashes against the file actually on disk
                weights_path: Some(weights_file(&cfg)),
                graphs: args.has("graphs"),
                ..Default::default()
            };
            if let Some(t) = args.get("target-bits") {
                ctx.target_bits = Some(t.parse().map_err(|_| {
                    normtweak::Error::Config("bad --target-bits".into())
                })?);
            }
            if args.has("scheme") || args.has("layer-bits") {
                let (method, scheme) = match args.get("scheme") {
                    Some(spec) => {
                        let (m, s) = analysis::parse_scheme_spec(spec)?;
                        (m.unwrap_or_else(|| cfg.quant.method.clone()), s)
                    }
                    None => (cfg.quant.method.clone(), cfg.scheme()),
                };
                let layer_schemes = match args.get("layer-bits") {
                    Some(lb) => analysis::parse_layer_bits(lb, scheme)?,
                    None => Vec::new(),
                };
                // --no-tweak (or [tweak] enabled=false) means no tweak_step
                // graph is needed
                let tweak_loss = if cfg.tweak.enabled {
                    Some(LossKind::from_str(&cfg.tweak.loss)?)
                } else {
                    None
                };
                ctx.plan = Some(analysis::PlanSpec { method, scheme, layer_schemes, tweak_loss });
            }
            if args.has("serve-config") || args.has("models") {
                ctx.serve = Some(analysis::ServeCheck {
                    spec: args.get("serve-config").map(String::from),
                    models_spec: args.get("models").map(String::from),
                });
            }
            let report = analysis::run_lints(&ctx);
            if format == "json" {
                println!("{}", report.to_json().emit());
            } else {
                print!("{}", report.render_human());
            }
            if report.should_fail(deny) {
                return Err(normtweak::Error::Config(format!(
                    "check found {} error(s), {} warning(s){}",
                    report.errors(),
                    report.warnings(),
                    if deny { " (--deny-warnings)" } else { "" }
                )));
            }
        }
        other => {
            normtweak::log_error!("cli", "unknown command `{other}`; see `normtweak help`");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Drive the serving engine with synthetic concurrent traffic (round-robin
/// across every registered model) and report latency percentiles,
/// throughput in requests and *generated* tokens, and per-model stats.
fn serve_demo(
    mut engine: normtweak::engine::Engine,
    n_requests: usize,
    n_clients: usize,
    deadline_ms: Option<u64>,
) -> normtweak::Result<()> {
    use normtweak::engine::GenRequest;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let client = engine.start()?; // models built + warm-up done after this
    let names: Vec<String> = client.models().to_vec();
    let t0 = std::time::Instant::now();
    let latencies = std::sync::Mutex::new(Vec::new());
    let new_tokens = AtomicUsize::new(0);
    let errors = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let client = client.clone();
            let (names, latencies) = (&names, &latencies);
            let (new_tokens, errors) = (&new_tokens, &errors);
            s.spawn(move || {
                for i in 0..n_requests / n_clients {
                    let model = &names[(c + i) % names.len()];
                    let prompt = vec![BOS, (8 + (c * 31 + i * 13) % 480) as i32];
                    let mut req = GenRequest::greedy(prompt, 16);
                    if let Some(ms) = deadline_ms {
                        req = req.with_deadline(std::time::Duration::from_millis(ms));
                    }
                    let t = std::time::Instant::now();
                    match client.generate(model, req) {
                        Ok(resp) => {
                            // a client thread that panicked mid-push poisons
                            // the lock but leaves the Vec usable
                            latencies
                                .lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .push(t.elapsed().as_micros());
                            // cache replays answered tokens but generated none
                            if !resp.cached {
                                new_tokens.fetch_add(resp.new_tokens().len(), Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let stats = engine.shutdown()?;

    let wall = t0.elapsed().as_secs_f64();
    let mut lat = latencies.into_inner().unwrap_or_else(|e| e.into_inner());
    lat.sort_unstable();
    if lat.is_empty() {
        return Err(normtweak::Error::Serve("no requests completed".into()));
    }
    let p50 = lat[lat.len() / 2] as f64 / 1000.0;
    let p99 = lat[(lat.len() * 99 / 100).min(lat.len() - 1)] as f64 / 1000.0;
    println!(
        "served {} requests in {:.1}s ({:.1} req/s, {:.1} tok/s generated): \
         p50 {:.0} ms, p99 {:.0} ms, {} errors",
        stats.total_served(),
        wall,
        stats.total_served() as f64 / wall,
        new_tokens.load(Ordering::Relaxed) as f64 / wall,
        p50,
        p99,
        errors.load(Ordering::Relaxed),
    );
    for (name, m) in &stats.models {
        println!(
            "  {name}: served {}, batches {} (mean {:.1}, max {}), mean queue {:.1} ms, \
             cache hits {}/{}, deadline misses {}, warmup batches {}, \
             prefill {:.0} tok/s, decode {:.0} tok/s",
            m.served,
            m.batches,
            m.mean_batch(),
            m.max_batch_seen,
            m.mean_queue_micros() / 1000.0,
            m.cache_hits,
            m.cache_hits + m.cache_misses,
            m.deadline_missed,
            m.warmup_batches,
            m.prefill_tok_per_s(),
            m.decode_tok_per_s(),
        );
    }
    Ok(())
}

/// Parse `--models w4=a.ntz,w2=b.ntz` into (engine key, checkpoint) pairs.
fn parse_models(spec: &str) -> normtweak::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        let (name, ckpt) = part.split_once('=').ok_or_else(|| {
            normtweak::Error::Config(format!(
                "bad --models entry `{part}`: expected name=checkpoint.ntz"
            ))
        })?;
        let (name, ckpt) = (name.trim(), ckpt.trim());
        if name.is_empty() || ckpt.is_empty() {
            return Err(normtweak::Error::Config(format!(
                "bad --models entry `{part}`: empty name or checkpoint path"
            )));
        }
        out.push((name.to_string(), ckpt.to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> normtweak::Result<Args> {
        Args::from_iter(argv.iter().map(|s| s.to_string()))
    }

    #[test]
    fn strict_parser_accepts_known_flags() {
        let a = parse(&["quantize", "--method", "smoothquant+gptq", "--bits", "4",
                        "--no-tweak"]).unwrap();
        assert_eq!(a.cmd, "quantize");
        assert_eq!(a.get("method"), Some("smoothquant+gptq"));
        assert!(a.has("no-tweak"));
    }

    #[test]
    fn strict_parser_rejects_unknown_flag() {
        let err = parse(&["quantize", "--frobnicate", "1"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--frobnicate") && msg.contains("normtweak help"), "{msg}");
        // a flag valid for one command is rejected for another
        assert!(parse(&["serve", "--method", "gptq"]).is_err());
    }

    #[test]
    fn strict_parser_rejects_positional_stragglers() {
        let err = parse(&["eval", "stray"]).unwrap_err();
        assert!(format!("{err}").contains("stray"));
        // value consumed by a pending key is not a straggler
        assert!(parse(&["eval", "--checkpoint", "q.ntz"]).is_ok());
    }

    #[test]
    fn unknown_command_defers_to_dispatch() {
        // unknown commands pass parsing (dispatch prints help + exits 2)
        assert!(parse(&["frob", "--config", "x"]).is_ok());
    }

    #[test]
    fn plan_and_auto_bits_flags_parse() {
        let a = parse(&["plan", "--target-bits", "2.25", "--candidates", "2,3,4,8",
                        "--loss", "mse"]).unwrap();
        assert_eq!(a.get("target-bits"), Some("2.25"));
        assert_eq!(a.get("loss"), Some("mse"));
        let a = parse(&["quantize", "--auto-bits", "2.5", "--profile", "p.json"]).unwrap();
        assert!(a.has("auto-bits"));
        // plan-only flags stay rejected elsewhere
        assert!(parse(&["eval", "--target-bits", "2"]).is_err());
        assert!(parse(&["serve", "--auto-bits", "2"]).is_err());
    }

    #[test]
    fn candidates_parse_and_reject() {
        assert_eq!(parse_candidates("2,3, 4,8").unwrap(), vec![2, 3, 4, 8]);
        assert!(parse_candidates("2,zap").is_err());
        assert!(parse_candidates("").is_err());
    }

    #[test]
    fn serve_engine_flags_parse() {
        let a = parse(&["serve", "--models", "w4=a.ntz,w2=b.ntz",
                        "--deadline-ms", "250", "--cache", "64"]).unwrap();
        assert_eq!(a.get("models"), Some("w4=a.ntz,w2=b.ntz"));
        assert_eq!(a.get("deadline-ms"), Some("250"));
        assert_eq!(a.get_usize("cache", 0), 64);
        // serve-only flags stay rejected elsewhere
        assert!(parse(&["eval", "--models", "a=x.ntz"]).is_err());
        assert!(parse(&["quantize", "--deadline-ms", "5"]).is_err());
    }

    #[test]
    fn models_spec_parses_and_rejects() {
        assert_eq!(
            parse_models("w4=a.ntz, w2=b.ntz").unwrap(),
            vec![("w4".to_string(), "a.ntz".to_string()),
                 ("w2".to_string(), "b.ntz".to_string())]
        );
        assert!(parse_models("w4").is_err());
        assert!(parse_models("=a.ntz").is_err());
        assert!(parse_models("w4=").is_err());
        assert!(parse_models("").is_err());
    }

    #[test]
    fn help_documents_engine_serving() {
        assert!(HELP.contains("--models"));
        assert!(HELP.contains("--deadline-ms"));
        assert!(HELP.contains("--cache"));
    }

    #[test]
    fn check_flags_parse() {
        let a = parse(&["check", "--ckpt", "q.ntz", "--manifest", "artifacts",
                        "--scheme", "gptq:w4g64", "--layer-bits", "0:8,3:2",
                        "--profile", "s.json", "--target-bits", "2.25",
                        "--serve-config", "max_batch=8", "--models", "w4=a.ntz",
                        "--graphs", "--format", "json", "--deny-warnings"]).unwrap();
        assert_eq!(a.cmd, "check");
        assert_eq!(a.get("format"), Some("json"));
        assert!(a.has("deny-warnings"));
        assert!(a.has("graphs"));
        // check-only flags stay rejected elsewhere
        assert!(parse(&["quantize", "--deny-warnings"]).is_err());
        assert!(parse(&["serve", "--format", "json"]).is_err());
        assert!(parse(&["eval", "--scheme", "w4g64"]).is_err());
    }

    #[test]
    fn deep_check_flag_parses_where_it_preflights() {
        assert!(parse(&["quantize", "--deep-check"]).unwrap().has("deep-check"));
        assert!(parse(&["serve", "--deep-check"]).unwrap().has("deep-check"));
        // check spells the deep pass --graphs instead
        assert!(parse(&["check", "--deep-check"]).is_err());
        assert!(parse(&["eval", "--deep-check"]).is_err());
    }

    #[test]
    fn help_documents_check() {
        assert!(HELP.contains("normtweak check"));
        assert!(HELP.contains("--deny-warnings"));
        assert!(HELP.contains("--format human|json"));
        assert!(HELP.contains("NTxxxx"));
        assert!(HELP.contains("--graphs"));
        assert!(HELP.contains("--deep-check"));
        assert!(HELP.contains("NT05xx"));
    }

    #[test]
    fn trace_flag_parses_where_it_records() {
        assert_eq!(
            parse(&["quantize", "--trace", "t.json"]).unwrap().get("trace"),
            Some("t.json")
        );
        assert_eq!(
            parse(&["serve", "--trace", "t.json"]).unwrap().get("trace"),
            Some("t.json")
        );
        // no collector pipeline behind eval/plan/check: flag rejected
        assert!(parse(&["eval", "--trace", "t.json"]).is_err());
        assert!(parse(&["plan", "--trace", "t.json"]).is_err());
        assert!(parse(&["check", "--trace", "t.json"]).is_err());
    }

    #[test]
    fn trace_flag_initializes_and_exports() {
        // golden path: an accepted --trace flag must produce a collector
        // and a loadable Chrome trace file — the flag can never no-op
        assert!(init_trace(&parse(&["quantize"]).unwrap()).is_none());
        let a = parse(&["quantize", "--trace", "t.json"]).unwrap();
        let (tc, path) = init_trace(&a).unwrap();
        assert_eq!(path, "t.json");
        let tid = tc.track("scheduler");
        tc.instant(tid, "submit", vec![]);
        let file = std::env::temp_dir().join("nt_trace_golden.json");
        let file_str = file.to_str().unwrap();
        write_trace(&tc, file_str).unwrap();
        let text = std::fs::read_to_string(&file).unwrap();
        let _ = std::fs::remove_file(&file);
        let j = normtweak::util::json::Json::parse(&text).unwrap();
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // thread_name metadata + the instant event
        assert_eq!(evs.len(), 2);
        assert!(j.get("metrics").is_some(), "metrics snapshot embedded");
    }

    #[test]
    fn help_documents_observability() {
        assert!(HELP.contains("--trace"));
        assert!(HELP.contains("NORMTWEAK_LOG"));
        assert!(HELP.contains("chrome://tracing"));
    }

    #[test]
    fn search_flags_parse() {
        let a = parse(&["search", "--target-bits", "2.5", "--budget", "2",
                        "--methods", "rtn,gptq", "--seed", "7",
                        "--resume", "s.json", "--out", "r.json", "--ppl"]).unwrap();
        assert_eq!(a.cmd, "search");
        assert_eq!(a.get("target-bits"), Some("2.5"));
        assert_eq!(a.get("methods"), Some("rtn,gptq"));
        assert!(a.has("ppl"));
        // the trace collector threads through search's policy spans too
        assert!(parse(&["search", "--trace", "t.json"]).is_ok());
        // search-only flags stay rejected elsewhere
        assert!(parse(&["quantize", "--budget", "2"]).is_err());
        assert!(parse(&["eval", "--methods", "rtn"]).is_err());
        assert!(parse(&["plan", "--resume", "s.json"]).is_err());
    }

    #[test]
    fn recipe_flags_parse_where_they_replay() {
        let a = parse(&["quantize", "--recipe", "r.json", "--dry-run"]).unwrap();
        assert_eq!(a.get("recipe"), Some("r.json"));
        assert!(a.has("dry-run"));
        assert!(parse(&["check", "--recipe", "r.json"]).is_ok());
        // no replay path behind eval/serve/plan
        assert!(parse(&["eval", "--recipe", "r.json"]).is_err());
        assert!(parse(&["serve", "--recipe", "r.json"]).is_err());
        assert!(parse(&["plan", "--dry-run"]).is_err());
    }

    #[test]
    fn plan_format_flag_parses() {
        let a = parse(&["plan", "--target-bits", "2.25", "--format", "json"]).unwrap();
        assert_eq!(a.get("format"), Some("json"));
        // format is a plan/check notion, not an eval one
        assert!(parse(&["eval", "--format", "json"]).is_err());
    }

    #[test]
    fn help_documents_search_and_recipes() {
        assert!(HELP.contains("normtweak search"));
        assert!(HELP.contains("--budget"));
        assert!(HELP.contains("--resume"));
        assert!(HELP.contains("recipe.json"));
        assert!(HELP.contains("--dry-run"));
        assert!(HELP.contains("NT06xx"));
        assert!(HELP.contains("--ppl"));
    }

    #[test]
    fn help_documents_plan_and_auto_bits() {
        assert!(HELP.contains("normtweak plan"));
        assert!(HELP.contains("--target-bits"));
        assert!(HELP.contains("--auto-bits"));
        assert!(HELP.contains("sensitivity.json"));
    }
}

//! NT05xx — the `graphs` lint: static HLO signature dataflow verification.
//!
//! Deep mode (`normtweak check --graphs`, or the `--deep-check` preflight
//! of `quantize`/`serve`).  Where the shallow `manifest` lint treats
//! `.hlo.txt` files as opaque blobs, this rule parses every graph's ENTRY
//! signature ([`super::hlo::parse_signature`]) and reconstructs the typed
//! dataflow of the whole pipeline from the manifest's model record:
//!
//! * the embed → block → head activation stream agrees on `[B, S, D]` /
//!   `[B, S, V]` at every hop, and every bucket suffix names an exported
//!   bucket (NT0504);
//! * quantized-block argument lists match the packed-code / scale tensor
//!   geometry of their grain — `codes i8[K, N]`, `scales f32[K/g, N]`
//!   (NT0503);
//! * prefill-KV results carry caches matching the manifest `decode` spec
//!   `[H, S, dh]` (NT0505);
//! * decode step graphs take per-row `pos i32[B]` and thread their carried
//!   caches last, in and out (NT0506);
//! * tweak-loss graphs end in a `f32[1]` loss (NT0507).
//!
//! Exporter intent vs lowered reality is its own axis: the manifest records
//! what `aot.py` *meant* to lower (`inputs`/`outputs`), and any
//! disagreement with the HLO text's actual entry signature is NT0502,
//! reported down to the offending parameter index.  Unreadable, empty, or
//! signature-free HLO files are NT0501 (the deep-mode escalation of the
//! shallow NT0108 presence warning).

use std::collections::BTreeMap;

use crate::runtime::manifest::{GraphEntry, ManifestModel};

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::hlo::{parse_signature, HloSignature, SigDType, TensorSig};
use super::{CheckContext, Lint};

pub struct GraphLint;

fn f32s(dims: &[usize]) -> TensorSig {
    TensorSig::new(SigDType::F32, dims.to_vec())
}

fn i32s(dims: &[usize]) -> TensorSig {
    TensorSig::new(SigDType::I32, dims.to_vec())
}

fn i8s(dims: &[usize]) -> TensorSig {
    TensorSig::new(SigDType::I8, dims.to_vec())
}

/// The architecture numbers one model record pins down, pre-validated
/// (`d_head` only exists when `n_head` divides `d_model`).
struct Arch {
    d: usize,
    ff: usize,
    v: usize,
    s: usize,
    h: usize,
    dh: usize,
    layernorm: bool,
    cb: usize,
}

impl Arch {
    fn from_record(m: &ManifestModel, cb: usize) -> Option<Self> {
        if m.n_head == 0 || m.d_model % m.n_head != 0 || m.d_model == 0 {
            return None;
        }
        Some(Arch {
            d: m.d_model,
            ff: m.d_ff,
            v: m.vocab,
            s: m.seq,
            h: m.n_head,
            dh: m.d_model / m.n_head,
            layernorm: m.norm == "layernorm",
            cb,
        })
    }

    /// Norm parameters per block (ln1/ln2 gains + biases for layernorm).
    fn n_np(&self) -> usize {
        if self.layernorm {
            4
        } else {
            2
        }
    }
}

/// Mirrors `aot.py float_weight_args`: the flat per-block float weight list.
fn float_weight_args(a: &Arch) -> Vec<(String, TensorSig)> {
    let (d, ff) = (a.d, a.ff);
    let mut out = vec![("ln1.g".to_string(), f32s(&[d]))];
    if a.layernorm {
        out.push(("ln1.b".to_string(), f32s(&[d])));
    }
    out.push(("attn.wqkv".to_string(), f32s(&[d, 3 * d])));
    out.push(("attn.bqkv".to_string(), f32s(&[3 * d])));
    out.push(("attn.wproj".to_string(), f32s(&[d, d])));
    out.push(("attn.bproj".to_string(), f32s(&[d])));
    out.push(("ln2.g".to_string(), f32s(&[d])));
    if a.layernorm {
        out.push(("ln2.b".to_string(), f32s(&[d])));
    }
    out.push(("mlp.wfc1".to_string(), f32s(&[d, ff])));
    out.push(("mlp.bfc1".to_string(), f32s(&[ff])));
    out.push(("mlp.wfc2".to_string(), f32s(&[ff, d])));
    out.push(("mlp.bfc2".to_string(), f32s(&[d])));
    out
}

/// Mirrors `aot.py qweight_args`: packed codes ride as `i8[K, N]`, scales
/// as `f32[K/group, N]` (one group spanning K for per-channel).
fn qweight_args(a: &Arch, group: usize) -> Vec<(String, TensorSig)> {
    let (d, ff) = (a.d, a.ff);
    let g_of = |k: usize| if group == 0 { 1 } else { k / group };
    let mut out = vec![("ln1.g".to_string(), f32s(&[d]))];
    if a.layernorm {
        out.push(("ln1.b".to_string(), f32s(&[d])));
    }
    out.push(("attn.wqkv.codes".to_string(), i8s(&[d, 3 * d])));
    out.push(("attn.wqkv.scales".to_string(), f32s(&[g_of(d), 3 * d])));
    out.push(("attn.bqkv".to_string(), f32s(&[3 * d])));
    out.push(("attn.wproj.codes".to_string(), i8s(&[d, d])));
    out.push(("attn.wproj.scales".to_string(), f32s(&[g_of(d), d])));
    out.push(("attn.bproj".to_string(), f32s(&[d])));
    out.push(("ln2.g".to_string(), f32s(&[d])));
    if a.layernorm {
        out.push(("ln2.b".to_string(), f32s(&[d])));
    }
    out.push(("mlp.wfc1.codes".to_string(), i8s(&[d, ff])));
    out.push(("mlp.wfc1.scales".to_string(), f32s(&[g_of(d), ff])));
    out.push(("mlp.bfc1".to_string(), f32s(&[ff])));
    out.push(("mlp.wfc2.codes".to_string(), i8s(&[ff, d])));
    out.push(("mlp.wfc2.scales".to_string(), f32s(&[g_of(ff), d])));
    out.push(("mlp.bfc2".to_string(), f32s(&[d])));
    out
}

/// Mirrors `aot.py norm_param_args` (the Adam state vectors of the tweak).
fn norm_param_args(a: &Arch, prefix: &str) -> Vec<(String, TensorSig)> {
    let names: &[&str] = if a.layernorm {
        &["ln1.g", "ln1.b", "ln2.g", "ln2.b"]
    } else {
        &["ln1.g", "ln2.g"]
    };
    names.iter().map(|n| (format!("{prefix}{n}"), f32s(&[a.d]))).collect()
}

/// Which bucket list a graph's `b{B}` suffix must name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BucketDomain {
    /// eval/gen bucket — `manifest.buckets`
    Main,
    /// one-token step / prefill-KV bucket — `decode.buckets`
    Decode,
    /// calibration-batch graph — must equal `calib_batch`
    Calib,
}

/// How to classify *output* mismatches of a graph family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OutKind {
    /// plain activation stream → NT0504
    Plain,
    /// prefill-KV: results 1.. are the emitted caches → NT0505
    Kv,
    /// decode step: trailing two results are the carried caches → NT0506
    DecBlock,
    /// tweak iteration: the last result is the scalar-shaped loss → NT0507
    Tweak,
}

/// The reconstructed contract of one graph.
struct Expected {
    inputs: Vec<(String, TensorSig)>,
    outputs: Vec<TensorSig>,
    /// code used for input-*count* mismatches (NT0503 for quantized
    /// families, NT0506 for decode steps, NT0504 otherwise)
    arity_code: &'static str,
    out_kind: OutKind,
    bucket: Option<(usize, BucketDomain)>,
}

enum Build {
    Ok(Expected),
    /// NT0508 info: can't (or shouldn't) reconstruct — skip with a note
    Skip(String),
    /// NT0503 error: the grain itself is broken for this architecture
    BadGrain(String),
}

fn bucket_of(part: &str) -> Option<usize> {
    part.strip_prefix('b')?.parse().ok()
}

/// Reconstruct the expected ENTRY signature of a graph from its name, the
/// model record, the exported grains, and the decode cache spec
/// (`kv = [H, S, dh]`) — the Rust mirror of `aot.py graph_defs`.
fn expected_for(
    name: &str,
    a: &Arch,
    groups: &BTreeMap<String, usize>,
    kv: &[usize],
) -> Build {
    let (d, ff, v, s, cb) = (a.d, a.ff, a.v, a.s, a.cb);
    let grain = |tag: &str| -> std::result::Result<usize, Build> {
        let Some(&g) = groups.get(tag) else {
            return Err(Build::BadGrain(format!(
                "grain `{tag}` is not in the manifest `groups` record \
                 (exported: {})",
                groups.keys().cloned().collect::<Vec<_>>().join(", ")
            )));
        };
        if g != 0 && (d % g != 0 || ff % g != 0) {
            return Err(Build::BadGrain(format!(
                "grain `{tag}` (group={g}) does not divide the matmul K dims \
                 (d_model={d}, d_ff={ff})"
            )));
        }
        Ok(g)
    };
    let parts: Vec<&str> = name.split('.').collect();
    let exp = match parts.as_slice() {
        ["embed", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            Expected {
                inputs: vec![
                    ("tokens".to_string(), i32s(&[b, s])),
                    ("tok_emb".to_string(), f32s(&[v, d])),
                    ("pos_emb".to_string(), f32s(&[s, d])),
                ],
                outputs: vec![f32s(&[b, s, d])],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Main)),
            }
        }
        ["block_fwd", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let mut inputs = vec![("x".to_string(), f32s(&[b, s, d]))];
            inputs.extend(float_weight_args(a));
            Expected {
                inputs,
                outputs: vec![f32s(&[b, s, d])],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Main)),
            }
        }
        ["head", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let mut inputs =
                vec![("x".to_string(), f32s(&[b, s, d])), ("lnf.g".to_string(), f32s(&[d]))];
            if a.layernorm {
                inputs.push(("lnf.b".to_string(), f32s(&[d])));
            }
            inputs.push(("tok_emb".to_string(), f32s(&[v, d])));
            Expected {
                inputs,
                outputs: vec![f32s(&[b, s, v])],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Main)),
            }
        }
        ["block_fwd_q", g, b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let group = match grain(g) {
                Ok(g) => g,
                Err(build) => return build,
            };
            let mut inputs = vec![("x".to_string(), f32s(&[b, s, d]))];
            inputs.extend(qweight_args(a, group));
            Expected {
                inputs,
                outputs: vec![f32s(&[b, s, d])],
                arity_code: codes::GRAPH_QARGS,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Main)),
            }
        }
        ["block_fwd_kv", b] | ["block_fwd_q_kv", _, b] => {
            let Some(bn) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let quantized = parts[0] == "block_fwd_q_kv";
            let mut inputs = vec![("x".to_string(), f32s(&[bn, s, d]))];
            if quantized {
                let group = match grain(parts[1]) {
                    Ok(g) => g,
                    Err(build) => return build,
                };
                inputs.extend(qweight_args(a, group));
            } else {
                inputs.extend(float_weight_args(a));
            }
            let mut cache = vec![bn];
            cache.extend_from_slice(kv);
            Expected {
                inputs,
                outputs: vec![f32s(&[bn, s, d]), f32s(&cache), f32s(&cache)],
                arity_code: if quantized {
                    codes::GRAPH_QARGS
                } else {
                    codes::GRAPH_DATAFLOW
                },
                out_kind: OutKind::Kv,
                bucket: Some((bn, BucketDomain::Decode)),
            }
        }
        ["embed_dec", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            Expected {
                inputs: vec![
                    ("tokens".to_string(), i32s(&[b, 1])),
                    ("pos".to_string(), i32s(&[b])),
                    ("tok_emb".to_string(), f32s(&[v, d])),
                    ("pos_emb".to_string(), f32s(&[s, d])),
                ],
                outputs: vec![f32s(&[b, 1, d])],
                arity_code: codes::GRAPH_DECODE_STEP,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Decode)),
            }
        }
        ["head_dec", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let mut inputs =
                vec![("x".to_string(), f32s(&[b, 1, d])), ("lnf.g".to_string(), f32s(&[d]))];
            if a.layernorm {
                inputs.push(("lnf.b".to_string(), f32s(&[d])));
            }
            inputs.push(("tok_emb".to_string(), f32s(&[v, d])));
            Expected {
                inputs,
                outputs: vec![f32s(&[b, 1, v])],
                arity_code: codes::GRAPH_DECODE_STEP,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Decode)),
            }
        }
        ["block_dec", b] | ["block_dec_q", _, b] => {
            let Some(bn) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let quantized = parts[0] == "block_dec_q";
            let mut inputs =
                vec![("x".to_string(), f32s(&[bn, 1, d])), ("pos".to_string(), i32s(&[bn]))];
            if quantized {
                let group = match grain(parts[1]) {
                    Ok(g) => g,
                    Err(build) => return build,
                };
                inputs.extend(qweight_args(a, group));
            } else {
                inputs.extend(float_weight_args(a));
            }
            let mut cache = vec![bn];
            cache.extend_from_slice(kv);
            inputs.push(("k_cache".to_string(), f32s(&cache)));
            inputs.push(("v_cache".to_string(), f32s(&cache)));
            Expected {
                inputs,
                outputs: vec![f32s(&[bn, 1, d]), f32s(&cache), f32s(&cache)],
                arity_code: if quantized {
                    codes::GRAPH_QARGS
                } else {
                    codes::GRAPH_DECODE_STEP
                },
                out_kind: OutKind::DecBlock,
                bucket: Some((bn, BucketDomain::Decode)),
            }
        }
        ["block_taps", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            let mut inputs = vec![("x".to_string(), f32s(&[b, s, d]))];
            inputs.extend(float_weight_args(a));
            Expected {
                inputs,
                outputs: vec![
                    f32s(&[b, s, d]),
                    f32s(&[b, s, d]),
                    f32s(&[b, s, d]),
                    f32s(&[b, s, ff]),
                ],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Calib)),
            }
        }
        ["channel_stats", b] => {
            let Some(b) = bucket_of(b) else {
                return Build::Skip(format!("unrecognized bucket suffix in `{name}`"));
            };
            Expected {
                inputs: vec![("x".to_string(), f32s(&[b, s, d]))],
                outputs: vec![f32s(&[d]), f32s(&[d])],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: Some((b, BucketDomain::Calib)),
            }
        }
        ["tweak_step", g] => {
            let group = match grain(g) {
                Ok(g) => g,
                Err(build) => return build,
            };
            let mut inputs = vec![("x".to_string(), f32s(&[cb, s, d]))];
            inputs.extend(qweight_args(a, group));
            inputs.extend(norm_param_args(a, "m."));
            inputs.extend(norm_param_args(a, "v."));
            inputs.push(("mu_f".to_string(), f32s(&[d])));
            inputs.push(("var_f".to_string(), f32s(&[d])));
            inputs.push(("lr".to_string(), f32s(&[1])));
            inputs.push(("t".to_string(), f32s(&[1])));
            let mut outputs = vec![f32s(&[d]); 3 * a.n_np()];
            outputs.push(f32s(&[1]));
            Expected {
                inputs,
                outputs,
                arity_code: codes::GRAPH_QARGS,
                out_kind: OutKind::Tweak,
                bucket: None,
            }
        }
        ["tweak_step_mse", g] | ["tweak_step_kl", g] => {
            let group = match grain(g) {
                Ok(g) => g,
                Err(build) => return build,
            };
            let mut inputs = vec![("x".to_string(), f32s(&[cb, s, d]))];
            inputs.extend(qweight_args(a, group));
            inputs.extend(norm_param_args(a, "m."));
            inputs.extend(norm_param_args(a, "v."));
            inputs.push(("y_f".to_string(), f32s(&[cb, s, d])));
            inputs.push(("lr".to_string(), f32s(&[1])));
            inputs.push(("t".to_string(), f32s(&[1])));
            let mut outputs = vec![f32s(&[d]); 3 * a.n_np()];
            outputs.push(f32s(&[1]));
            Expected {
                inputs,
                outputs,
                arity_code: codes::GRAPH_QARGS,
                out_kind: OutKind::Tweak,
                bucket: None,
            }
        }
        ["xtx", k] => {
            let Some(k) = k.strip_prefix('k').and_then(|k| k.parse::<usize>().ok()) else {
                return Build::Skip(format!("unrecognized K suffix in `{name}`"));
            };
            Expected {
                inputs: vec![("x".to_string(), f32s(&[cb * s, k]))],
                outputs: vec![f32s(&[k, k])],
                arity_code: codes::GRAPH_DATAFLOW,
                out_kind: OutKind::Plain,
                bucket: None,
            }
        }
        _ => {
            return Build::Skip(format!(
                "unknown graph family `{}`",
                parts.first().copied().unwrap_or(name)
            ))
        }
    };
    Build::Ok(exp)
}

/// Code for one *input* position, by the role its name encodes.
fn input_code(name: &str) -> &'static str {
    if name.ends_with(".codes") || name.ends_with(".scales") {
        codes::GRAPH_QARGS
    } else if name == "pos" || name == "k_cache" || name == "v_cache" {
        codes::GRAPH_DECODE_STEP
    } else {
        codes::GRAPH_DATAFLOW
    }
}

/// Code for one *output* position, by the family's result layout.
fn output_code(kind: OutKind, idx: usize, n: usize) -> &'static str {
    match kind {
        OutKind::Plain => codes::GRAPH_DATAFLOW,
        OutKind::Kv => {
            if idx == 0 {
                codes::GRAPH_DATAFLOW
            } else {
                codes::GRAPH_KV_SPEC
            }
        }
        OutKind::DecBlock => {
            if idx + 2 >= n {
                codes::GRAPH_DECODE_STEP
            } else {
                codes::GRAPH_DATAFLOW
            }
        }
        OutKind::Tweak => {
            if idx + 1 == n {
                codes::GRAPH_TWEAK_LOSS
            } else {
                codes::GRAPH_DATAFLOW
            }
        }
    }
}

fn arity_out_code(kind: OutKind) -> &'static str {
    match kind {
        OutKind::Plain => codes::GRAPH_DATAFLOW,
        OutKind::Kv => codes::GRAPH_KV_SPEC,
        OutKind::DecBlock => codes::GRAPH_DECODE_STEP,
        OutKind::Tweak => codes::GRAPH_TWEAK_LOSS,
    }
}

fn render_spec(spec: &crate::runtime::manifest::IoSpec) -> String {
    match spec.sig() {
        Ok(sig) => sig.render(),
        Err(_) => format!("{}[?] (unsupported dtype `{}`)", spec.dtype, spec.dtype),
    }
}

/// Compare the recorded input list against the reconstructed contract.
fn check_inputs(
    exp: &Expected,
    g: &GraphEntry,
    gi: usize,
    gid: &str,
    origin: &str,
    report: &mut Report,
) {
    if g.inputs.len() != exp.inputs.len() {
        report.push(
            Diagnostic::error(
                exp.arity_code,
                format!(
                    "graph `{gid}`: {} inputs recorded but the {} contract \
                     expects {} — argument-list drift",
                    g.inputs.len(),
                    g.name.split('.').next().unwrap_or(&g.name),
                    exp.inputs.len()
                ),
            )
            .at(origin)
            .field(format!("graphs[{gi}].inputs"))
            .fix("re-run the AOT export (`make artifacts`)"),
        );
    }
    for (j, ((want_name, want), got)) in exp.inputs.iter().zip(&g.inputs).enumerate() {
        let matches = got.sig().map(|sig| sig == *want).unwrap_or(false);
        if !matches {
            report.push(
                Diagnostic::error(
                    input_code(want_name),
                    format!(
                        "graph `{gid}` parameter {j} (`{want_name}`): \
                         recorded {} but the pipeline contract expects {}",
                        render_spec(got),
                        want.render()
                    ),
                )
                .at(origin)
                .field(format!("graphs[{gi}].inputs[{j}]"))
                .fix("re-run the AOT export (`make artifacts`)"),
            );
        }
    }
}

/// Compare the effective (lowered or recorded) result list against the
/// reconstructed contract.
fn check_outputs(
    exp: &Expected,
    effective: &[TensorSig],
    source: &str,
    gi: usize,
    gid: &str,
    origin: &str,
    report: &mut Report,
) {
    if effective.len() != exp.outputs.len() {
        report.push(
            Diagnostic::error(
                arity_out_code(exp.out_kind),
                format!(
                    "graph `{gid}`: {} results in the {source} signature but \
                     the contract expects {}",
                    effective.len(),
                    exp.outputs.len()
                ),
            )
            .at(origin)
            .field(format!("graphs[{gi}].outputs"))
            .fix("re-run the AOT export (`make artifacts`)"),
        );
    }
    let n = exp.outputs.len();
    for (j, (want, got)) in exp.outputs.iter().zip(effective).enumerate() {
        if got != want {
            report.push(
                Diagnostic::error(
                    output_code(exp.out_kind, j, n),
                    format!(
                        "graph `{gid}` result {j}: {source} signature has {} \
                         but the pipeline contract expects {}",
                        got.render(),
                        want.render()
                    ),
                )
                .at(origin)
                .field(format!("graphs[{gi}].outputs[{j}]"))
                .fix("re-run the AOT export (`make artifacts`)"),
            );
        }
    }
}

/// Exporter-intent vs lowered-HLO drift (NT0502), per parameter index.
fn check_recorded_vs_hlo(
    g: &GraphEntry,
    hlo: &HloSignature,
    gi: usize,
    gid: &str,
    hlo_origin: &str,
    report: &mut Report,
) {
    let drift = |msg: String, field: String| {
        Diagnostic::error(codes::GRAPH_SIG_DRIFT, msg)
            .at(hlo_origin)
            .field(field)
            .fix("re-run the AOT export; manifest record and lowered HLO must agree")
    };
    if g.inputs.len() != hlo.params.len() {
        report.push(drift(
            format!(
                "graph `{gid}`: manifest records {} inputs but the lowered HLO \
                 takes {} parameters",
                g.inputs.len(),
                hlo.params.len()
            ),
            format!("graphs[{gi}].inputs"),
        ));
    }
    for (j, (rec, low)) in g.inputs.iter().zip(&hlo.params).enumerate() {
        let agree = rec.sig().map(|sig| sig == *low).unwrap_or(false);
        if !agree {
            report.push(drift(
                format!(
                    "graph `{gid}` parameter {j} (`{}`): recorded as {} but \
                     lowered as {}",
                    rec.name,
                    render_spec(rec),
                    low.render()
                ),
                format!("graphs[{gi}].inputs[{j}]"),
            ));
        }
    }
    if g.outputs.is_empty() {
        return; // pre-signature-recording manifest — NT0509 covers it
    }
    if g.outputs.len() != hlo.results.len() {
        report.push(drift(
            format!(
                "graph `{gid}`: manifest records {} outputs but the lowered \
                 HLO returns {} results",
                g.outputs.len(),
                hlo.results.len()
            ),
            format!("graphs[{gi}].outputs"),
        ));
    }
    for (j, (rec, low)) in g.outputs.iter().zip(&hlo.results).enumerate() {
        let agree = rec.sig().map(|sig| sig == *low).unwrap_or(false);
        if !agree {
            report.push(drift(
                format!(
                    "graph `{gid}` result {j} (`{}`): recorded as {} but \
                     lowered as {}",
                    rec.name,
                    render_spec(rec),
                    low.render()
                ),
                format!("graphs[{gi}].outputs[{j}]"),
            ));
        }
    }
}

impl Lint for GraphLint {
    fn name(&self) -> &'static str {
        "graphs"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        if !ctx.graphs {
            return;
        }
        let Some(man) = &ctx.manifest else { return };
        let origin = man.dir.join("manifest.json").display().to_string();
        let mut no_outputs = 0usize;
        let mut first_no_out: Option<String> = None;

        for (gi, g) in man.graphs.iter().enumerate() {
            let gid = format!("{}.{}", g.model, g.name);
            let path = man.dir.join(&g.file);
            let hlo_origin = path.display().to_string();

            // --- NT0501: the deep-mode file audit ------------------------
            // (a *missing* file stays the shallow NT0108 warning; present
            // but unreadable/empty/signature-free escalates to an error)
            let hlo: Option<HloSignature> = if !path.exists() {
                None
            } else {
                match std::fs::read_to_string(&path) {
                    Err(e) => {
                        report.push(
                            Diagnostic::error(
                                codes::GRAPH_HLO_INVALID,
                                format!("graph `{gid}`: HLO file unreadable ({e})"),
                            )
                            .at(hlo_origin.clone())
                            .field(format!("graphs[{gi}].file"))
                            .fix("re-run `make artifacts` to regenerate the HLO files"),
                        );
                        None
                    }
                    Ok(text) if text.trim().is_empty() => {
                        report.push(
                            Diagnostic::error(
                                codes::GRAPH_HLO_INVALID,
                                format!("graph `{gid}`: HLO file is empty"),
                            )
                            .at(hlo_origin.clone())
                            .field(format!("graphs[{gi}].file"))
                            .fix("re-run `make artifacts` to regenerate the HLO files"),
                        );
                        None
                    }
                    Ok(text) => match parse_signature(&text) {
                        Err(e) => {
                            report.push(
                                Diagnostic::error(
                                    codes::GRAPH_HLO_INVALID,
                                    format!(
                                        "graph `{gid}`: no parseable ENTRY \
                                         signature in the HLO text ({e})"
                                    ),
                                )
                                .at(hlo_origin.clone())
                                .field(format!("graphs[{gi}].file"))
                                .fix("re-run `make artifacts`; the file is not HLO text"),
                            );
                            None
                        }
                        Ok(sig) => Some(sig),
                    },
                }
            };

            // --- NT0502: exporter intent vs lowered reality --------------
            if let Some(sig) = &hlo {
                check_recorded_vs_hlo(g, sig, gi, &gid, &hlo_origin, report);
            }
            if g.outputs.is_empty() {
                no_outputs += 1;
                if first_no_out.is_none() {
                    first_no_out = Some(gid.clone());
                }
            }

            // --- NT0503–NT0507: the reconstructed pipeline contract ------
            let Some(m) = man.models.get(&g.model) else {
                report.push(
                    Diagnostic::info(
                        codes::GRAPH_SKIPPED,
                        format!(
                            "graph `{gid}` skipped: model `{}` has no `models` \
                             record to reconstruct the contract from",
                            g.model
                        ),
                    )
                    .at(origin.clone())
                    .field(format!("graphs[{gi}]")),
                );
                continue;
            };
            let Some(arch) = Arch::from_record(m, man.calib_batch) else {
                report.push(
                    Diagnostic::info(
                        codes::GRAPH_SKIPPED,
                        format!(
                            "graph `{gid}` skipped: model record is not usable \
                             (n_head must divide d_model)"
                        ),
                    )
                    .at(origin.clone())
                    .field(format!("models.{}", g.model)),
                );
                continue;
            };
            // the decode record is the source of truth for cache geometry
            // (NT0505 is exactly "prefill results match the manifest spec");
            // without a record, fall back to the architecture-derived shape
            let kv = man
                .decode_for(&g.model)
                .map(|spec| spec.shape.clone())
                .unwrap_or_else(|| vec![arch.h, arch.s, arch.dh]);

            let exp = match expected_for(&g.name, &arch, &man.groups, &kv) {
                Build::Skip(why) => {
                    report.push(
                        Diagnostic::info(
                            codes::GRAPH_SKIPPED,
                            format!("graph `{gid}` skipped: {why}"),
                        )
                        .at(origin.clone())
                        .field(format!("graphs[{gi}]")),
                    );
                    continue;
                }
                Build::BadGrain(msg) => {
                    report.push(
                        Diagnostic::error(
                            codes::GRAPH_QARGS,
                            format!("graph `{gid}`: {msg}"),
                        )
                        .at(origin.clone())
                        .field(format!("graphs[{gi}]"))
                        .fix("re-run the AOT export with a consistent `--groups`"),
                    );
                    continue;
                }
                Build::Ok(exp) => exp,
            };

            // bucket suffix must name an exported bucket of its domain
            if let Some((b, domain)) = exp.bucket {
                let (ok, listed) = match domain {
                    BucketDomain::Main => (
                        man.buckets.contains(&b),
                        man.buckets.iter().map(|b| b.to_string()).collect::<Vec<_>>(),
                    ),
                    BucketDomain::Decode => match &man.decode {
                        Some(d) => (
                            d.buckets.contains(&b),
                            d.buckets.iter().map(|b| b.to_string()).collect::<Vec<_>>(),
                        ),
                        None => (
                            man.buckets.contains(&b),
                            man.buckets.iter().map(|b| b.to_string()).collect::<Vec<_>>(),
                        ),
                    },
                    BucketDomain::Calib => {
                        (b == man.calib_batch, vec![man.calib_batch.to_string()])
                    }
                };
                if !ok {
                    report.push(
                        Diagnostic::error(
                            codes::GRAPH_DATAFLOW,
                            format!(
                                "graph `{gid}`: bucket {b} is not an exported \
                                 bucket of its domain (expected one of: {})",
                                listed.join(", ")
                            ),
                        )
                        .at(origin.clone())
                        .field(format!("graphs[{gi}]"))
                        .fix("re-run the AOT export with consistent bucket sets"),
                    );
                }
            }

            check_inputs(&exp, g, gi, &gid, &origin, report);

            // prefer the lowered truth; fall back to the recorded intent
            let recorded: Option<Vec<TensorSig>> = if g.outputs.is_empty() {
                None
            } else {
                g.outputs.iter().map(|s| s.sig().ok()).collect()
            };
            match (hlo.map(|s| s.results), recorded) {
                (Some(eff), _) => {
                    check_outputs(&exp, &eff, "lowered", gi, &gid, &hlo_origin, report)
                }
                (None, Some(eff)) => {
                    check_outputs(&exp, &eff, "recorded", gi, &gid, &origin, report)
                }
                (None, None) => {}
            }
        }

        if no_outputs > 0 {
            let example = first_no_out.unwrap_or_default();
            report.push(
                Diagnostic::warn(
                    codes::GRAPH_NO_OUTPUTS,
                    format!(
                        "{no_outputs} graph entr{} (e.g. `{example}`) record no \
                         output signature — manifest predates the \
                         signature-recording exporter, so result dataflow can \
                         only be checked where the HLO text parses",
                        if no_outputs == 1 { "y" } else { "ies" }
                    ),
                )
                .at(origin)
                .field("graphs")
                .fix("re-run the AOT export to record `outputs` per graph"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lints;
    use crate::runtime::ArtifactManifest;

    /// One-graph manifest + HLO stub on disk, loaded into a deep context.
    fn ctx_for(name: &str, graph_json: &str, hlo: Option<&str>) -> CheckContext {
        let dir = std::env::temp_dir().join(format!("nt_graph_lint_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = format!(
            r#"{{"format": 1, "calib_batch": 32, "buckets": [8, 32],
                 "groups": {{"pc": 0, "g64": 64}},
                 "decode": {{"buckets": [8, 32],
                             "caches": {{"nt-tiny": {{"n_layer": 2,
                                                      "shape": [4, 128, 32]}}}}}},
                 "models": {{"nt-tiny": {{"n_layer": 2, "d_model": 128,
                             "n_head": 4, "d_ff": 512, "vocab": 2048,
                             "seq": 128, "norm": "layernorm"}}}},
                 "graphs": [{graph_json}]}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        if let Some(text) = hlo {
            std::fs::write(dir.join("g.hlo.txt"), text).unwrap();
        }
        CheckContext {
            manifest_dir: Some(dir.clone()),
            manifest: ArtifactManifest::load(&dir).ok(),
            graphs: true,
            ..CheckContext::default()
        }
    }

    const EMBED_GOOD: &str = r#"{"model": "nt-tiny", "name": "embed.b8",
        "file": "g.hlo.txt",
        "inputs": [{"name": "tokens", "shape": [8, 128], "dtype": "i32"},
                   {"name": "tok_emb", "shape": [2048, 128], "dtype": "f32"},
                   {"name": "pos_emb", "shape": [128, 128], "dtype": "f32"}],
        "outputs": [{"name": "out0", "shape": [8, 128, 128], "dtype": "f32"}]}"#;

    const EMBED_HLO: &str = "HloModule m, entry_computation_layout=\
        {(s32[8,128]{1,0}, f32[2048,128]{1,0}, f32[128,128]{1,0})\
        ->(f32[8,128,128]{2,1,0})}";

    #[test]
    fn clean_graph_is_clean() {
        let report = run_lints(&ctx_for("clean", EMBED_GOOD, Some(EMBED_HLO)));
        assert!(report.is_empty(), "{:?}", report.codes());
    }

    #[test]
    fn shallow_mode_skips_the_deep_pass() {
        let mut ctx = ctx_for("shallow", EMBED_GOOD, None);
        ctx.graphs = false;
        // only the shallow NT0108 missing-file warning fires
        assert_eq!(run_lints(&ctx).codes(), vec![codes::GRAPH_FILE_MISSING]);
    }

    #[test]
    fn garbage_and_empty_hlo_is_nt0501() {
        let report = run_lints(&ctx_for("garbage", EMBED_GOOD, Some("not hlo at all")));
        assert!(report.codes().contains(&codes::GRAPH_HLO_INVALID), "{:?}", report.codes());
        let report = run_lints(&ctx_for("empty", EMBED_GOOD, Some("  \n")));
        assert!(report.codes().contains(&codes::GRAPH_HLO_INVALID), "{:?}", report.codes());
    }

    #[test]
    fn recorded_vs_lowered_drift_is_nt0502() {
        // the HLO lowered tokens as s32[8,64]: exporter-intent drift
        let hlo = "HloModule m, entry_computation_layout=\
            {(s32[8,64]{1,0}, f32[2048,128]{1,0}, f32[128,128]{1,0})\
            ->(f32[8,128,128]{2,1,0})}";
        let report = run_lints(&ctx_for("drift", EMBED_GOOD, Some(hlo)));
        let codes_seen = report.codes();
        assert!(codes_seen.contains(&codes::GRAPH_SIG_DRIFT), "{codes_seen:?}");
        let d = report
            .diagnostics
            .iter()
            .find(|d| d.code == codes::GRAPH_SIG_DRIFT)
            .unwrap();
        // provenance down to the parameter index
        assert!(d.message.contains("parameter 0"), "{}", d.message);
        assert_eq!(d.field.as_deref(), Some("graphs[0].inputs[0]"));
    }

    #[test]
    fn wrong_qarg_geometry_is_nt0503() {
        // g64 scales recorded with the pc geometry ([1, 384] not [2, 384])
        let graph = r#"{"model": "nt-tiny", "name": "block_fwd_q.g64.b8",
            "file": "missing.hlo.txt",
            "inputs": [{"name": "x", "shape": [8, 128, 128], "dtype": "f32"},
                       {"name": "ln1.g", "shape": [128], "dtype": "f32"},
                       {"name": "ln1.b", "shape": [128], "dtype": "f32"},
                       {"name": "attn.wqkv.codes", "shape": [128, 384], "dtype": "i8"},
                       {"name": "attn.wqkv.scales", "shape": [1, 384], "dtype": "f32"}]}"#;
        let report = run_lints(&ctx_for("qargs", graph, None));
        let seen = report.codes();
        // wrong arity (5 of 17) and wrong scales geometry, both NT0503
        assert!(seen.contains(&codes::GRAPH_QARGS), "{seen:?}");
        let scales = report
            .diagnostics
            .iter()
            .find(|d| d.message.contains("attn.wqkv.scales"))
            .unwrap();
        assert_eq!(scales.code, codes::GRAPH_QARGS);
        assert!(scales.message.contains("f32[2,384]"), "{}", scales.message);
    }

    #[test]
    fn drifted_kv_cache_shape_is_nt0505() {
        // prefill emits caches of [8, 4, 64, 32] but the decode record
        // promises [H, S, dh] = [4, 128, 32]
        let mut inputs = vec![r#"{"name": "x", "shape": [8, 128, 128], "dtype": "f32"}"#
            .to_string()];
        for (n, s) in [
            ("ln1.g", "[128]"), ("ln1.b", "[128]"),
            ("attn.wqkv", "[128, 384]"), ("attn.bqkv", "[384]"),
            ("attn.wproj", "[128, 128]"), ("attn.bproj", "[128]"),
            ("ln2.g", "[128]"), ("ln2.b", "[128]"),
            ("mlp.wfc1", "[128, 512]"), ("mlp.bfc1", "[512]"),
            ("mlp.wfc2", "[512, 128]"), ("mlp.bfc2", "[128]"),
        ] {
            inputs.push(format!(
                r#"{{"name": "{n}", "shape": {s}, "dtype": "f32"}}"#
            ));
        }
        let graph = format!(
            r#"{{"model": "nt-tiny", "name": "block_fwd_kv.b8",
                 "file": "missing.hlo.txt",
                 "inputs": [{}],
                 "outputs": [
                   {{"name": "out0", "shape": [8, 128, 128], "dtype": "f32"}},
                   {{"name": "out1", "shape": [8, 4, 64, 32], "dtype": "f32"}},
                   {{"name": "out2", "shape": [8, 4, 64, 32], "dtype": "f32"}}]}}"#,
            inputs.join(",\n")
        );
        let report = run_lints(&ctx_for("kvdrift", &graph, None));
        let kv: Vec<_> = report
            .diagnostics
            .iter()
            .filter(|d| d.code == codes::GRAPH_KV_SPEC)
            .collect();
        assert_eq!(kv.len(), 2, "{:?}", report.codes());
        assert!(kv[0].message.contains("f32[8,4,128,32]"), "{}", kv[0].message);
    }

    #[test]
    fn wrong_pos_dtype_is_nt0506_and_nonscalar_tweak_loss_is_nt0507() {
        let graph = r#"{"model": "nt-tiny", "name": "embed_dec.b8",
            "file": "missing.hlo.txt",
            "inputs": [{"name": "tokens", "shape": [8, 1], "dtype": "i32"},
                       {"name": "pos", "shape": [8], "dtype": "f32"},
                       {"name": "tok_emb", "shape": [2048, 128], "dtype": "f32"},
                       {"name": "pos_emb", "shape": [128, 128], "dtype": "f32"}]}"#;
        let report = run_lints(&ctx_for("pos", graph, None));
        let d = report
            .diagnostics
            .iter()
            .find(|d| d.code == codes::GRAPH_DECODE_STEP)
            .unwrap();
        assert!(d.message.contains("`pos`") && d.message.contains("i32[8]"), "{}", d.message);

        // a tweak graph whose last result is not the f32[1] loss
        let graph = r#"{"model": "nt-tiny", "name": "tweak_step.g64",
            "file": "missing.hlo.txt", "inputs": [],
            "outputs": [{"name": "out0", "shape": [32, 128, 128],
                         "dtype": "f32"}]}"#;
        let report = run_lints(&ctx_for("loss", graph, None));
        assert!(report.codes().contains(&codes::GRAPH_TWEAK_LOSS), "{:?}", report.codes());
    }

    #[test]
    fn unknown_family_is_nt0508_info_and_missing_outputs_is_nt0509() {
        let graph = r#"{"model": "nt-tiny", "name": "mystery.b8",
            "file": "missing.hlo.txt", "inputs": []}"#;
        let report = run_lints(&ctx_for("skip", graph, None));
        let seen = report.codes();
        assert!(seen.contains(&codes::GRAPH_SKIPPED), "{seen:?}");
        assert!(seen.contains(&codes::GRAPH_NO_OUTPUTS), "{seen:?}");
        assert_eq!(report.errors(), 0, "{seen:?}");
    }

    #[test]
    fn bucket_drift_is_nt0504() {
        let graph = r#"{"model": "nt-tiny", "name": "embed.b16",
            "file": "missing.hlo.txt",
            "inputs": [{"name": "tokens", "shape": [16, 128], "dtype": "i32"},
                       {"name": "tok_emb", "shape": [2048, 128], "dtype": "f32"},
                       {"name": "pos_emb", "shape": [128, 128], "dtype": "f32"}],
            "outputs": [{"name": "out0", "shape": [16, 128, 128],
                         "dtype": "f32"}]}"#;
        let report = run_lints(&ctx_for("bucket", graph, None));
        let d = report
            .diagnostics
            .iter()
            .find(|d| d.code == codes::GRAPH_DATAFLOW)
            .unwrap();
        assert!(d.message.contains("bucket 16"), "{}", d.message);
    }
}

//! NT04xx — engine/serve configuration sanity (the `serve` lint).
//!
//! Validates batching tunings before a scheduler thread exists: degenerate
//! knobs (zero `max_batch`, zero window), tunings that cannot be honored
//! by the exported artifacts (`max_batch` above the largest batch bucket),
//! and deadlines shorter than the dispatch window.
//! [`crate::engine::ModelTuning::validate`] delegates to [`tuning_diags`],
//! so the engine builder and `normtweak check` can never drift apart on
//! what counts as degenerate.

use std::time::Duration;

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::{CheckContext, Lint};

pub struct ServeLint;

const ACCEPTED_KEYS: &str = "max_batch, batch_window_ms, deadline_ms";

/// The degenerate-tuning checks shared with
/// `crate::engine::ModelTuning::validate` — message text is the contract
/// (the engine maps the first diagnostic straight into `Error::Config`).
pub fn tuning_diags(name: &str, max_batch: usize, batch_window: Duration) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if max_batch == 0 {
        out.push(
            Diagnostic::error(
                codes::ZERO_MAX_BATCH,
                format!("model `{name}`: max_batch must be >= 1 (0 disables batching entirely)"),
            )
            .field("max_batch")
            .fix("use max_batch >= 1"),
        );
    }
    if batch_window.is_zero() {
        out.push(
            Diagnostic::error(
                codes::ZERO_BATCH_WINDOW,
                format!(
                    "model `{name}`: batch_window must be non-zero (a zero window \
                     degenerates to single-request batches; use >= 1ms)"
                ),
            )
            .field("batch_window")
            .fix("use a batch window >= 1ms"),
        );
    }
    out
}

impl Lint for ServeLint {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        let Some(serve) = &ctx.serve else { return };
        let defaults = crate::engine::ModelTuning::default();
        let mut max_batch = defaults.max_batch;
        let mut window_ms = defaults.batch_window.as_millis() as u64;
        let mut deadline_ms: Option<u64> = None;

        if let Some(spec) = &serve.spec {
            for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
                let part = part.trim();
                let Some((key, value)) = part.split_once('=') else {
                    report.push(
                        Diagnostic::error(
                            codes::BAD_SERVE_SPEC,
                            format!(
                                "bad --serve-config entry `{part}`: expected key=value \
                                 (accepted keys: {ACCEPTED_KEYS})"
                            ),
                        )
                        .at("--serve-config")
                        .field(part.to_string())
                        .fix("write entries as key=value, comma-separated"),
                    );
                    continue;
                };
                let (key, value) = (key.trim(), value.trim());
                let parsed: Option<u64> = value.parse().ok();
                match (key, parsed) {
                    ("max_batch", Some(v)) => max_batch = v as usize,
                    ("batch_window_ms", Some(v)) => window_ms = v,
                    ("deadline_ms", Some(v)) => deadline_ms = Some(v),
                    ("max_batch" | "batch_window_ms" | "deadline_ms", None) => {
                        report.push(
                            Diagnostic::error(
                                codes::BAD_SERVE_SPEC,
                                format!(
                                    "bad --serve-config value for `{key}`: `{value}` is \
                                     not a number"
                                ),
                            )
                            .at("--serve-config")
                            .field(key.to_string())
                            .fix("use a non-negative integer"),
                        );
                    }
                    (other, _) => {
                        report.push(
                            Diagnostic::error(
                                codes::BAD_SERVE_SPEC,
                                format!(
                                    "unknown --serve-config key `{other}` (accepted \
                                     keys: {ACCEPTED_KEYS})"
                                ),
                            )
                            .at("--serve-config")
                            .field(other.to_string())
                            .fix("pick one of the accepted keys"),
                        );
                    }
                }
            }
        }

        for d in tuning_diags("serve", max_batch, Duration::from_millis(window_ms)) {
            report.push(d.at("--serve-config"));
        }
        if let Some(deadline) = deadline_ms {
            if deadline < window_ms {
                report.push(
                    Diagnostic::warn(
                        codes::DEADLINE_WINDOW,
                        format!(
                            "deadline of {deadline} ms is shorter than the batch window \
                             ({window_ms} ms) — requests can expire while waiting for \
                             batch-mates"
                        ),
                    )
                    .at("--serve-config")
                    .field("deadline_ms")
                    .fix("raise deadline_ms or shrink batch_window_ms"),
                );
            }
        }
        if let Some(manifest) = &ctx.manifest {
            if let Some(bucket) = manifest.max_bucket() {
                if max_batch > bucket {
                    let listed = manifest
                        .buckets
                        .iter()
                        .map(|b| b.to_string())
                        .collect::<Vec<_>>()
                        .join(", ");
                    report.push(
                        Diagnostic::warn(
                            codes::BATCH_OVER_BUCKET,
                            format!(
                                "max_batch {max_batch} exceeds the largest exported \
                                 batch bucket {bucket} (exported: {listed}) — graph \
                                 calls will be chunked to {bucket}"
                            ),
                        )
                        .at("--serve-config")
                        .field("max_batch")
                        .fix(format!(
                            "lower max_batch to {bucket}, or re-export with a larger \
                             bucket"
                        )),
                    );
                }
            }
        }
        if let Some(models) = &serve.models_spec {
            for part in models.split(',').filter(|p| !p.trim().is_empty()) {
                let part = part.trim();
                let ok = part
                    .split_once('=')
                    .is_some_and(|(n, c)| !n.trim().is_empty() && !c.trim().is_empty());
                if !ok {
                    report.push(
                        Diagnostic::error(
                            codes::BAD_SERVE_SPEC,
                            format!("bad --models entry `{part}`: expected name=checkpoint.ntz"),
                        )
                        .at("--models")
                        .field(part.to_string())
                        .fix("write entries as name=checkpoint.ntz, comma-separated"),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{run_lints, ServeCheck};

    fn ctx_with(spec: &str) -> CheckContext {
        CheckContext {
            serve: Some(ServeCheck {
                spec: Some(spec.to_string()),
                models_spec: None,
            }),
            ..CheckContext::default()
        }
    }

    #[test]
    fn default_tuning_is_clean() {
        let ctx = CheckContext {
            serve: Some(ServeCheck::default()),
            ..CheckContext::default()
        };
        assert!(run_lints(&ctx).is_empty());
    }

    #[test]
    fn degenerate_knobs_and_bad_entries_collected() {
        let report =
            run_lints(&ctx_with("max_batch=0,batch_window_ms=0,nope=3,deadline_ms=abc,solo"));
        let seen = report.codes();
        assert!(seen.contains(&codes::ZERO_MAX_BATCH), "{seen:?}");
        assert!(seen.contains(&codes::ZERO_BATCH_WINDOW), "{seen:?}");
        assert_eq!(
            seen.iter().filter(|c| **c == codes::BAD_SERVE_SPEC).count(),
            3,
            "{seen:?}"
        );
    }

    #[test]
    fn short_deadline_warns_but_does_not_fail() {
        let report = run_lints(&ctx_with("batch_window_ms=10,deadline_ms=5"));
        assert_eq!(report.codes(), vec![codes::DEADLINE_WINDOW]);
        assert!(!report.should_fail(false));
        assert!(report.should_fail(true));
    }

    #[test]
    fn bad_models_entries_are_nt0405() {
        let ctx = CheckContext {
            serve: Some(ServeCheck {
                spec: None,
                models_spec: Some("w4=a.ntz,broken,=b.ntz".to_string()),
            }),
            ..CheckContext::default()
        };
        let report = run_lints(&ctx);
        assert_eq!(report.codes(), vec![codes::BAD_SERVE_SPEC, codes::BAD_SERVE_SPEC]);
    }
}

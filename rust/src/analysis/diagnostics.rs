//! Structured diagnostics: the [`Diagnostic`] record every lint rule emits
//! and the [`Report`] that collects them.
//!
//! Unlike the crate's `validate()` functions — which return on the first
//! problem — a report keeps collecting, so one `normtweak check` run over a
//! corrupted artifact set surfaces *every* finding.  A report converts back
//! into the crate's fail-fast world through [`Report::into_result`], which
//! preserves the old first-error behavior (an `Err` carrying the full
//! message list) for the pipeline call sites that still gate on it.

use crate::error::{Error, Result};
use crate::util::json::{arr, n, obj, s, Json};

/// How bad a finding is.  `Error` aborts the consuming command; `Warn`
/// aborts only under `--deny-warnings`; `Info` never aborts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    /// The JSON / human-render name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warning",
            Severity::Info => "info",
        }
    }
}

/// One finding: a stable code (`NT0103`), a severity, provenance (which
/// file, which JSON path / config field), the message, and a suggested fix.
///
/// Codes are stable across releases so CI can gate on them; the full table
/// lives in the [`crate::analysis`] module docs.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable diagnostic code (`"NT0103"`); see the module-level table.
    pub code: &'static str,
    pub severity: Severity,
    /// Where the finding came from: a file path or a CLI flag.
    pub origin: Option<String>,
    /// JSON path / config field inside the origin (`"decode.caches.m.shape"`).
    pub field: Option<String>,
    pub message: String,
    /// Suggested fix, when one is mechanical enough to state.
    pub fix: Option<String>,
}

impl Diagnostic {
    pub fn new(code: &'static str, severity: Severity, message: impl Into<String>) -> Self {
        Diagnostic { code, severity, origin: None, field: None, message: message.into(), fix: None }
    }

    pub fn error(code: &'static str, message: impl Into<String>) -> Self {
        Diagnostic::new(code, Severity::Error, message)
    }

    pub fn warn(code: &'static str, message: impl Into<String>) -> Self {
        Diagnostic::new(code, Severity::Warn, message)
    }

    pub fn info(code: &'static str, message: impl Into<String>) -> Self {
        Diagnostic::new(code, Severity::Info, message)
    }

    /// Attach the originating file path / CLI flag.
    pub fn at(mut self, origin: impl Into<String>) -> Self {
        self.origin = Some(origin.into());
        self
    }

    /// Attach the offending JSON path / config field.
    pub fn field(mut self, field: impl Into<String>) -> Self {
        self.field = Some(field.into());
        self
    }

    /// Attach a suggested fix.
    pub fn fix(mut self, fix: impl Into<String>) -> Self {
        self.fix = Some(fix.into());
        self
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("code", s(self.code)),
            ("severity", s(self.severity.as_str())),
            ("message", s(self.message.clone())),
        ];
        if let Some(o) = &self.origin {
            pairs.push(("origin", s(o.clone())));
        }
        if let Some(f) = &self.field {
            pairs.push(("field", s(f.clone())));
        }
        if let Some(f) = &self.fix {
            pairs.push(("fix", s(f.clone())));
        }
        obj(pairs)
    }
}

/// An ordered collection of findings (rule order, then emission order —
/// deterministic for golden tests).
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    /// Add a finding.  Identical `(code, origin, field)` findings collapse
    /// to the first one pushed — two rules reporting the same defect at the
    /// same location (e.g. the shallow and deep graph passes) must not
    /// inflate the error count or the CI-visible report.
    pub fn push(&mut self, d: Diagnostic) {
        let dup = self
            .diagnostics
            .iter()
            .any(|e| e.code == d.code && e.origin == d.origin && e.field == d.field);
        if !dup {
            self.diagnostics.push(d);
        }
    }

    pub fn extend(&mut self, ds: Vec<Diagnostic>) {
        for d in ds {
            self.push(d);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn infos(&self) -> usize {
        self.count(Severity::Info)
    }

    fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    /// The emitted codes, in emission order (golden-test hook).
    pub fn codes(&self) -> Vec<&'static str> {
        self.diagnostics.iter().map(|d| d.code).collect()
    }

    /// Whether the consuming command should abort.
    pub fn should_fail(&self, deny_warnings: bool) -> bool {
        self.errors() > 0 || (deny_warnings && self.warnings() > 0)
    }

    /// Machine-readable report (the `--format json` payload); emits through
    /// the in-tree `util::json` and round-trips through `Json::parse`.
    /// `schema_version` 2 = the deduplicating, NT05xx-aware report (v1 had
    /// a `format` key and no dedupe).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("tool", s("normtweak-check")),
            ("schema_version", n(2.0)),
            ("errors", n(self.errors() as f64)),
            ("warnings", n(self.warnings() as f64)),
            ("infos", n(self.infos() as f64)),
            ("diagnostics", arr(self.diagnostics.iter().map(|d| d.to_json()).collect())),
        ])
    }

    /// Compiler-style human rendering, one block per finding plus a
    /// one-line summary.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}[{}]: {}\n", d.severity.as_str(), d.code, d.message));
            match (&d.origin, &d.field) {
                (Some(o), Some(f)) => out.push_str(&format!("  --> {o}: {f}\n")),
                (Some(o), None) => out.push_str(&format!("  --> {o}\n")),
                (None, Some(f)) => out.push_str(&format!("  --> {f}\n")),
                (None, None) => {}
            }
            if let Some(fix) = &d.fix {
                out.push_str(&format!("  fix: {fix}\n"));
            }
        }
        out.push_str(&format!(
            "check: {} error(s), {} warning(s), {} info\n",
            self.errors(),
            self.warnings(),
            self.infos()
        ));
        out
    }

    /// Convert back into the crate's fail-fast world: `Ok(())` when no
    /// `Error`-severity finding was collected, otherwise `Err` through the
    /// given variant constructor (e.g. `Error::Artifact`), carrying *every*
    /// error message — the first-error call sites keep aborting, but with
    /// the full list instead of just the first finding.
    pub fn into_result(self, wrap: fn(String) -> Error) -> Result<()> {
        let msgs: Vec<String> = self
            .diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| format!("[{}] {}", d.code, d.message))
            .collect();
        if msgs.is_empty() {
            return Ok(());
        }
        Err(wrap(msgs.join("; ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_should_fail() {
        let mut r = Report::new();
        assert!(!r.should_fail(true));
        r.push(Diagnostic::warn("NT0403", "w"));
        assert!(!r.should_fail(false));
        assert!(r.should_fail(true));
        r.push(Diagnostic::error("NT0101", "e"));
        assert!(r.should_fail(false));
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 1);
        assert_eq!(r.codes(), vec!["NT0403", "NT0101"]);
    }

    #[test]
    fn json_roundtrips() {
        let mut r = Report::new();
        r.push(
            Diagnostic::error("NT0103", "missing key `calib_batch`")
                .at("artifacts/manifest.json")
                .field("calib_batch")
                .fix("re-run `make artifacts`"),
        );
        let j = r.to_json();
        let back = Json::parse(&j.emit()).unwrap();
        assert_eq!(j, back);
        assert_eq!(back.get("schema_version").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("errors").unwrap().as_usize().unwrap(), 1);
        let d = &back.get("diagnostics").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("code").unwrap().as_str().unwrap(), "NT0103");
        assert_eq!(d.get("field").unwrap().as_str().unwrap(), "calib_batch");
    }

    #[test]
    fn into_result_collects_all_errors() {
        let mut r = Report::new();
        r.push(Diagnostic::error("NT0104", "first"));
        r.push(Diagnostic::warn("NT0403", "not included"));
        r.push(Diagnostic::error("NT0105", "second"));
        let err = r.into_result(Error::Artifact).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("first") && msg.contains("second"), "{msg}");
        assert!(!msg.contains("not included"), "{msg}");
        assert!(Report::new().into_result(Error::Artifact).is_ok());
    }

    #[test]
    fn identical_findings_dedupe() {
        let mut r = Report::new();
        let d = || Diagnostic::error("NT0501", "empty").at("a/g.hlo.txt").field("graphs[0].file");
        r.push(d());
        r.push(d());
        assert_eq!(r.errors(), 1);
        // same code, different field — both kept
        r.push(Diagnostic::error("NT0501", "empty").at("a/h.hlo.txt").field("graphs[1].file"));
        assert_eq!(r.errors(), 2);
        // extend routes through the same dedupe
        r.extend(vec![d(), d()]);
        assert_eq!(r.errors(), 2);
    }

    #[test]
    fn human_render_shows_provenance() {
        let mut r = Report::new();
        r.push(Diagnostic::warn("NT0403", "batch too big").at("--serve-config").field("max_batch"));
        let text = r.render_human();
        assert!(text.contains("warning[NT0403]"), "{text}");
        assert!(text.contains("--serve-config: max_batch"), "{text}");
        assert!(text.contains("0 error(s), 1 warning(s)"), "{text}");
    }
}

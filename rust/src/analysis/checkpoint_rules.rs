//! NT02xx — quantized checkpoint audit (the `checkpoint` lint).
//!
//! Cross-checks a `.ntz` checkpoint against itself (every tensor
//! `QuantizedModel::load` would touch, pack-width round-trips), against the
//! target architecture (linear/scale geometry), and against the manifest
//! (exported grains, model record drift, decode cache spec) — all without
//! constructing a runtime.  `QuantizedModel::load` fail-fasts on the first
//! missing tensor; this rule reports every problem in one pass.

use std::path::Path;

use crate::model::{ModelConfig, NormKind};
use crate::quant::QuantScheme;
use crate::tensor::{load_ntz, packed_len, Tensor};

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::{CheckContext, Lint};

pub struct CheckpointLint;

/// First element of a small i32 meta tensor, if well-formed.
fn meta_i32(t: Option<&Tensor>) -> Option<i32> {
    t.and_then(|v| v.as_i32().ok()).and_then(|s| s.first()).copied()
}

fn missing(origin: &str, key: &str) -> Diagnostic {
    Diagnostic::error(
        codes::CKPT_TENSOR,
        format!("checkpoint: missing or mistyped tensor `{key}`"),
    )
    .at(origin)
    .field(key)
    .fix("re-run `normtweak quantize` to regenerate the checkpoint")
}

/// Audit one packed linear: shape vs architecture, pack width, byte
/// length, scale geometry, bias presence.
#[allow(clippy::too_many_arguments)]
fn check_linear(
    tensors: &std::collections::BTreeMap<String, Tensor>,
    prefix: &str,
    name: &str,
    want_k: usize,
    want_n: usize,
    scheme: Option<QuantScheme>,
    origin: &str,
    report: &mut Report,
) {
    let key = |suffix: &str| format!("{prefix}{name}.{suffix}");

    // logical shape [K, N]
    let shape = tensors.get(&key("shape")).and_then(|t| t.as_i32().ok()).and_then(|s| {
        (s.len() == 2).then(|| (s[0] as usize, s[1] as usize))
    });
    let (k, n) = match shape {
        None => {
            report.push(missing(origin, &key("shape")));
            (want_k, want_n)
        }
        Some((k, n)) => {
            if (k, n) != (want_k, want_n) {
                report.push(
                    Diagnostic::error(
                        codes::CKPT_GEOMETRY,
                        format!(
                            "checkpoint: `{}` is [{k}, {n}] but the architecture \
                             expects [{want_k}, {want_n}]",
                            key("shape")
                        ),
                    )
                    .at(origin)
                    .field(key("shape"))
                    .fix("re-quantize against the deployed model architecture"),
                );
            }
            (k, n)
        }
    };

    // per-linear storage width; absent falls back to the model-level width
    // (mixed precision writes pbits explicitly)
    let pbits = match tensors.get(&key("pbits")) {
        Some(t) => meta_i32(Some(t)).map(|b| b as u8),
        None => scheme.and_then(|s| s.pack_bits().ok()),
    };
    match pbits {
        Some(b) if [2, 4, 8].contains(&b) => {
            if let Some(t) = tensors.get(&key("packed")) {
                match t.as_u8() {
                    Err(_) => report.push(missing(origin, &key("packed"))),
                    Ok(data) => {
                        let want = packed_len(k * n, b);
                        if data.len() != want {
                            report.push(
                                Diagnostic::error(
                                    codes::CKPT_PACK,
                                    format!(
                                        "checkpoint: `{}` has {} bytes but [{k}, {n}] \
                                         at {b}-bit storage packs to {want} — the codes \
                                         would not round-trip",
                                        key("packed"),
                                        data.len()
                                    ),
                                )
                                .at(origin)
                                .field(key("packed"))
                                .fix("re-quantize; packed bytes and shape disagree"),
                            );
                        }
                    }
                }
            } else {
                report.push(missing(origin, &key("packed")));
            }
        }
        Some(b) => report.push(
            Diagnostic::error(
                codes::CKPT_PACK,
                format!(
                    "checkpoint: `{}` records pack width {b}, which has no packed \
                     storage (supported: 2, 4, 8)",
                    key("pbits")
                ),
            )
            .at(origin)
            .field(key("pbits"))
            .fix("re-quantize; 3-bit codes must be stored in 4-bit slots"),
        ),
        // no pbits and no usable model-level scheme: the meta check
        // already reported why
        None => {}
    }

    // scales are f32 [G, N]
    match tensors.get(&key("scales")) {
        None => report.push(missing(origin, &key("scales"))),
        Some(sc) => {
            let want_g = match scheme.and_then(|s| s.group_size) {
                None => Some(1),
                Some(g) if g > 0 && k % g == 0 => Some(k / g),
                Some(_) => None, // indivisible group: reported via meta/grain
            };
            let ok = sc.shape.len() == 2
                && sc.shape[1] == n
                && want_g.map_or(true, |g| sc.shape[0] == g);
            if !ok {
                report.push(
                    Diagnostic::error(
                        codes::CKPT_GEOMETRY,
                        format!(
                            "checkpoint: `{}` has shape {:?} but the scheme expects \
                             [{}, {n}] (groups x out-channels)",
                            key("scales"),
                            sc.shape,
                            want_g.map_or("G".to_string(), |g| g.to_string()),
                        ),
                    )
                    .at(origin)
                    .field(key("scales"))
                    .fix("re-quantize at the deployed grain"),
                );
            }
        }
    }
    if !tensors.contains_key(&key("bias")) {
        report.push(missing(origin, &key("bias")));
    }
}

impl Lint for CheckpointLint {
    fn name(&self) -> &'static str {
        "checkpoint"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        let Some(path) = &ctx.ckpt_path else { return };
        let origin = path.display().to_string();
        let tensors = match load_ntz(path) {
            Ok(t) => t,
            Err(e) => {
                report.push(
                    Diagnostic::error(
                        codes::CKPT_UNREADABLE,
                        format!("checkpoint unreadable: {e}"),
                    )
                    .at(origin)
                    .fix("re-run `normtweak quantize --out <ckpt>.ntz`"),
                );
                return;
            }
        };

        // model-level scheme from the meta tensors
        let bits = meta_i32(tensors.get("meta.bits"));
        let group = meta_i32(tensors.get("meta.group"));
        if bits.is_none() {
            report.push(missing(&origin, "meta.bits"));
        }
        if group.is_none() {
            report.push(missing(&origin, "meta.group"));
        }
        let scheme = bits.map(|b| QuantScheme {
            bits: b as u8,
            group_size: match group {
                Some(g) if g > 0 => Some(g as usize),
                _ => None,
            },
        });
        if let Some(s) = scheme {
            if let Err(e) = s.pack_bits() {
                report.push(
                    Diagnostic::error(codes::CKPT_PACK, format!("checkpoint: {e}"))
                        .at(&origin)
                        .field("meta.bits")
                        .fix("re-quantize at a supported width (2, 3, 4, or 8 bits)"),
                );
            }
        }

        // cross-checks against the manifest
        if let Some(manifest) = &ctx.manifest {
            if let Some(s) = scheme {
                let tag = s.group_tag();
                if let Err(e) = manifest.validate_grain(&tag) {
                    report.push(
                        Diagnostic::error(codes::CKPT_GRAIN, format!("checkpoint: {e}"))
                            .at(&origin)
                            .field("meta.group")
                            .fix(format!(
                                "re-run the AOT export with `--groups` including `{tag}`, \
                                 or re-quantize at an exported grain"
                            )),
                    );
                }
            }
            if let Some(cfg) = &ctx.model {
                match manifest.model_field_mismatches(cfg) {
                    None => report.push(
                        Diagnostic::error(
                            codes::MODEL_UNKNOWN,
                            format!(
                                "model `{}` not in manifest (manifest records: {})",
                                cfg.name,
                                manifest.model_names().join(", ")
                            ),
                        )
                        .at(&origin)
                        .field(format!("models.{}", cfg.name))
                        .fix("re-run the AOT export including this model"),
                    ),
                    Some(diffs) => {
                        for (field, manifest_val, registry_val) in diffs {
                            report.push(
                                Diagnostic::error(
                                    codes::MODEL_DRIFT,
                                    format!(
                                        "model `{}` config mismatch between Rust registry \
                                         and manifest: `{field}` is {manifest_val} in the \
                                         manifest but {registry_val} in the registry",
                                        cfg.name
                                    ),
                                )
                                .at(&origin)
                                .field(format!("models.{}.{field}", cfg.name))
                                .fix("re-run the AOT export or fix the Rust registry"),
                            );
                        }
                    }
                }
                if let Err(e) = manifest.verify_decode(cfg) {
                    report.push(
                        Diagnostic::error(codes::DECODE_CACHE_DRIFT, format!("{e}"))
                            .at(&origin)
                            .field(format!("decode.caches.{}", cfg.name))
                            .fix("re-run the AOT export so the decode caches match"),
                    );
                }
            }
        }

        // architecture checks need a model config
        let Some(cfg) = &ctx.model else { return };
        for key in ["tok_emb", "pos_emb", "lnf.g"] {
            if !tensors.contains_key(key) {
                report.push(missing(&origin, key));
            }
        }
        let ln = cfg.norm == NormKind::LayerNorm;
        if ln && !tensors.contains_key("lnf.b") {
            report.push(missing(&origin, "lnf.b"));
        }
        for i in 0..cfg.n_layer {
            let prefix = format!("block{i}.");
            for norm in ["ln1", "ln2"] {
                if !tensors.contains_key(&format!("{prefix}{norm}.g")) {
                    report.push(missing(&origin, &format!("{prefix}{norm}.g")));
                }
                if ln && !tensors.contains_key(&format!("{prefix}{norm}.b")) {
                    report.push(missing(&origin, &format!("{prefix}{norm}.b")));
                }
            }
            for (name, k, n) in cfg.linear_shapes() {
                check_linear(&tensors, &prefix, name, k, n, scheme, &origin, report);
            }
        }
    }
}

/// Convenience for callers that only have a checkpoint on disk.
#[allow(dead_code)]
pub fn check_checkpoint(path: &Path, model: Option<ModelConfig>) -> Report {
    let ctx = CheckContext {
        ckpt_path: Some(path.to_path_buf()),
        model,
        ..CheckContext::default()
    };
    let mut report = Report::new();
    CheckpointLint.run(&ctx, &mut report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lints;

    #[test]
    fn missing_checkpoint_is_nt0201() {
        let ctx = CheckContext {
            ckpt_path: Some(std::path::PathBuf::from("/definitely/missing.ntz")),
            ..CheckContext::default()
        };
        assert_eq!(run_lints(&ctx).codes(), vec![codes::CKPT_UNREADABLE]);
    }

    #[test]
    fn empty_archive_reports_meta_and_structure() {
        let dir = std::env::temp_dir().join("nt_ckpt_lint_empty");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.ntz");
        crate::tensor::save_ntz(&path, &std::collections::BTreeMap::new()).unwrap();
        let report =
            check_checkpoint(&path, Some(ModelConfig::builtin("nt-tiny").unwrap()));
        let codes_seen = report.codes();
        // meta.bits, meta.group, tok_emb, ... all missing — collected, not
        // first-error
        assert!(codes_seen.iter().filter(|c| **c == codes::CKPT_TENSOR).count() > 5);
    }
}
